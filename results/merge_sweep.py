"""Merge results/fix/*.json re-runs into the master sweep JSON."""
import glob
import json
import os

base_path = os.path.join(os.path.dirname(__file__), "dryrun_sweep.json")
records = json.load(open(base_path))
index = {(r["arch"], r["shape"], r["mesh"]): i for i, r in enumerate(records)}

n = 0
for path in sorted(glob.glob(os.path.join(os.path.dirname(__file__), "fix", "*.json"))):
    for r in json.load(open(path)):
        key = (r["arch"], r["shape"], r["mesh"])
        if key in index:
            records[index[key]] = r
        else:
            records.append(r)
        n += 1

with open(base_path, "w") as f:
    json.dump(records, f, indent=1)
ok = sum(r["status"] == "ok" for r in records)
skip = sum(r["status"] == "skip" for r in records)
fail = sum(r["status"] == "fail" for r in records)
print(f"merged {n} re-run cells -> {ok} ok / {skip} skip / {fail} fail (total {len(records)})")
for r in records:
    if r["status"] == "fail":
        print("STILL FAILING:", r["arch"], r["shape"], r["mesh"], r["error"][:100])
