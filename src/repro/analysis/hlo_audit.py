"""HLO auditor: the post-SPMD collective census as an analysis pass.

``launch/hlo_stats.parse_collectives`` (the dry-run helper) supplies the
parser and the ring byte model; this pass compiles a step, parses the
compiled module's text, and pins the post-SPMD census against the jaxpr-level
census and the VoteWire ledger.

Tolerance: the jaxpr census and the ledger are built from the same padded
canonical-view buffers, so they agree exactly; the compiler may additionally
pad/fuse collective operands (tile alignment, scalar widening to the minimum
transfer granule), so HLO-vs-ledger agreement is pinned within
``PAD_TOLERANCE`` (documented relative slack, matching the padding caveat in
launch/hlo_stats.py). On a 1-device tier-1 build all ring terms are zero on
both sides — the math itself is pinned by synthetic-HLO tests in
tests/test_analysis.py.
"""

from __future__ import annotations

import jax

from repro.analysis.framework import Rule
from repro.launch.hlo_stats import CollectiveStats, parse_collectives

#: relative slack for HLO-vs-ledger byte agreement: compiler-side operand
#: padding/widening only — structural disagreement (a missing or extra
#: collective) is orders of magnitude larger
PAD_TOLERANCE = 0.05


def hlo_collective_stats(fn, *args, default_group: int = 1) -> CollectiveStats:
    """Compile ``fn(*args)`` (jit if not already) and parse the post-SPMD
    collective census out of the compiled HLO text."""
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    return parse_collectives(compiled.as_text(), default_group=default_group)


class HloJaxprAgreement(Rule):
    """Post-SPMD HLO collective bytes must agree with the jaxpr census and
    the VoteWire ledger within PAD_TOLERANCE."""

    name = "hlo-jaxpr-agreement"
    description = "compiled-HLO census == jaxpr census == ledger (± padding)"

    def __init__(self, tolerance: float = PAD_TOLERANCE):
        self.tolerance = float(tolerance)

    def _close(self, a: float, b: float) -> bool:
        return abs(a - b) <= self.tolerance * max(abs(a), abs(b), 1.0)

    def check(self, label: str, *, hlo_bytes: float, jaxpr_bytes: float,
              ledger_bytes: float) -> list:
        findings = []
        if not self._close(hlo_bytes, jaxpr_bytes):
            findings.append(self.finding(
                label,
                f"post-SPMD HLO collective bytes {hlo_bytes:.1f} disagree "
                f"with the jaxpr census {jaxpr_bytes:.1f} beyond the "
                f"{self.tolerance:.0%} padding tolerance"))
        if not self._close(hlo_bytes, ledger_bytes):
            findings.append(self.finding(
                label,
                f"post-SPMD HLO collective bytes {hlo_bytes:.1f} disagree "
                f"with the VoteWire ledger {ledger_bytes:.1f} beyond the "
                f"{self.tolerance:.0%} padding tolerance"))
        return findings
