"""Shared drivers for the analysis CLI and tests: the tiny-model step builds
whose traced collectives the census/HLO passes pin against the VoteWire ledger.

One definition serves both ``python -m repro.analysis`` and
tests/test_analysis.py, so the blocking CI gate and the tier-1 suite audit the
SAME programs.

Census-at-hypothetical-M mechanics: the step is built and traced on a 1-device
mesh (tier-1 has no multi-device hardware), but the equation *structure* —
which collectives run, over which named axes, with what operand shapes — is
independent of the axis size, so the ring byte model is evaluated at
``HYPOTHETICAL_M`` workers to make every term non-vacuous. Two constraints
make this sound:

  * M <= 127 keeps the hypothetical worker count in the same int8
    ``_sum_dtype`` bucket as the M=1 build, so the traced psum payload dtype
    is the one a real M-worker build would use;
  * the step is built with ``backend="interpret"`` — the jnp backend of the
    gather wires SKIPS the all-gather (it is the fp32-psum oracle program),
    so only the kernel backends trace the honest wire.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.analysis.hlo_audit import HloJaxprAgreement, hlo_collective_stats
from repro.analysis.jaxpr_audit import (CollectiveCensus, CollectiveCountBudget,
                                        DtypePromotionDrift, EntropyWireBudget,
                                        GatherHbmBudget, MaskedPayloadZero,
                                        check_fused_uplink, collective_census)

#: hypothetical worker count the census ring model is costed at: > 1 so every
#: ring term is non-vacuous, <= 127 so the int8 _sum_dtype bucket still holds
HYPOTHETICAL_M = 16

#: plan-time nonzero fraction of the golomb setup: the paper-regime 5%
#: sparsity — doubles as the setup's target_sparsity budget, so
#: ``engine.resolve_golomb_p`` sizes the wire capacity from the SAME number
GOLOMB_P = 0.05

#: wire setup -> (compressor, server, vote_impl, budget): one representative
#: registry row per setup (engine.wire_mode must resolve to
#: ``wire_mode_of(key)``). "golomb" is the entropy-coded PAYLOAD format of
#: the votes mode (engine.wire_payload_format), not a wire mode of its own —
#: it gets its own setup row because its wire object, ledger arithmetic and
#: bucket plan all differ from the flat 2-bit votes wire.
MODE_SETUPS = {
    "votes": ("sparsign", "majority_vote", "psum", 2.0),
    "scaled_votes": ("terngrad", "mean", "psum", 1.0),
    "pack8": ("qsgd8", "mean", "allgather_packed", 1.0),
    "decoded": ("qsgd8", "mean", "psum", 1.0),
    "golomb": ("sparsign_golomb", "majority_vote", "allgather_packed",
               GOLOMB_P),
}

#: chunk size (payload rows) the ring setups sweep with: deliberately tiny —
#: one sublane tile — so the tiny-model BUCKETED plans split into many chunks
#: and the census sees a genuinely multi-chunk ring (at the production
#: default of collectives.DEFAULT_RING_CHUNK_ROWS the tiny model would be
#: one chunk everywhere and the chunk loop would go untested)
RING_SWEEP_CHUNK_ROWS = 32

#: ring-gather setups: the three gather wires again, exchanged over the
#: chunked ppermute ring instead of the monolithic all_gather. Kept in their
#: own table (not MODE_SETUPS) so the monolithic pins keep their exact
#: parametrization; every census/count driver sweeps both tables.
RING_SETUPS = {
    "ring_pack2": ("sparsign", "majority_vote", "allgather_packed", 2.0),
    "ring_pack8": ("qsgd8", "mean", "allgather_packed", 1.0),
    "ring_golomb": ("sparsign_golomb", "majority_vote", "allgather_packed",
                    GOLOMB_P),
}


def _setup_of(mode: str) -> tuple:
    """(compressor, server, vote_impl, budget) row of either setup table."""
    return MODE_SETUPS[mode] if mode in MODE_SETUPS else RING_SETUPS[mode]


def wire_mode_of(mode: str) -> str:
    """The engine wire mode one setup's negotiation resolves to — identity
    except for the golomb setups (which ride the votes mode on an
    entropy-coded payload) and the ring setups (the ring is an exchange
    strategy of the SAME wire modes, not a mode of its own)."""
    if mode.endswith("golomb") or mode == "ring_pack2":
        return "votes"
    if mode == "ring_pack8":
        return "pack8"
    return mode


def tiny_model():
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.model import Model
    cfg = ModelConfig(name="analysis-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=64, pattern=(LayerSpec(mixer="attn"),),
                      dtype="float32", attn_chunk=8, q_chunk=8, loss_chunk=8,
                      remat=False)
    return Model(cfg)


def tiny_batch(vocab: int, b: int = 2, s: int = 8, seed: int = 0):
    import numpy as np
    rng = np.random.RandomState(seed)
    return {
        "inputs": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }


def mode_comp(mode: str):
    """The representative CompressionConfig of one wire mode."""
    from repro.core.algorithm import CompressionConfig
    from repro.core.budgets import BudgetConfig

    compressor, server, vote_impl, budget = _setup_of(mode)
    # the golomb setups' budget IS their plan sparsity: a target_sparsity
    # budget both drives the compressor and resolves the wire capacity p
    kind = "target_sparsity" if mode.endswith("golomb") else "fixed"
    return CompressionConfig(compressor=compressor,
                             budget=BudgetConfig(kind=kind, value=budget),
                             server=server)


def participation_spec():
    """The ParticipationSpec the elastic setups build with: uniform weights,
    the quorum as an explicit fraction. The census/count billing depends only
    on the spec's PRESENCE (which exchange family the step traces), not on
    its numbers — any valid spec pins the same equations."""
    from repro.dist import collectives
    return collectives.ParticipationSpec(q_frac=0.5)


def mode_wire(mode: str, m: int, *, elastic: bool = False):
    """A costing-only VoteWire at hypothetical worker count ``m`` — the ring
    setups cost (and the steps build) their wires with the sweep chunk size.
    ``elastic=True`` attaches the participation spec, switching the byte
    ledger to the weighted-exchange billing (psum wires: two f32 all-reduces;
    gather wires: the weight side channel)."""
    from repro.dist import collectives

    part = participation_spec() if elastic else None
    rcr = RING_SWEEP_CHUNK_ROWS if mode in RING_SETUPS else None
    if mode == "pack8" or mode == "ring_pack8":
        return collectives.Pack8Wire(axes=("data",), n_workers=m,
                                     ring_chunk_rows=rcr, participation=part)
    if mode.endswith("golomb"):
        return collectives.GolombWire(axes=("data",), n_workers=m, p=GOLOMB_P,
                                      ring_chunk_rows=rcr, participation=part)
    if mode == "ring_pack2":
        return collectives.PackedVoteWire(axes=("data",), n_workers=m,
                                          ring_chunk_rows=rcr,
                                          participation=part)
    return collectives.VoteWire(axes=("data",), n_workers=m,
                                participation=part)


def build_mode_step(mode: str, *, bucketed: bool = False,
                    elastic: bool = False, participation=None):
    """Build the 1-device `simple` train step whose wire negotiation resolves
    to ``mode``; returns (step, state, batch, model, mesh, comp).
    ``elastic=True`` builds the weighted, participation-normalized variant
    (the same ParticipationSpec as ``mode_wire(elastic=True)``); an explicit
    ``participation`` spec overrides it (the bench's chaos timing rows)."""
    from repro.core import engine
    from repro.launch.mesh import make_host_mesh
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_simple import TrainStepConfig, build_train_step

    _, server, vote_impl, _ = _setup_of(mode)
    comp = mode_comp(mode)
    resolved = engine.wire_mode(comp, vote_impl=vote_impl)
    assert resolved == wire_mode_of(mode), (mode, resolved)
    if mode.endswith("golomb"):
        # the golomb setups are only themselves if the payload negotiation
        # picks the entropy-coded stream (votes mode + the gather impl)
        assert engine.wire_payload_format(
            comp, resolved, vote_impl=vote_impl) == "golomb"
    model = tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(model.cfg.vocab_size)
    scfg = TrainStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                           worker_axes=("data",), vote_impl=vote_impl,
                           donate=False, backend="interpret",
                           bucketed=bucketed,
                           ring_chunk_rows=(RING_SWEEP_CHUNK_ROWS
                                            if mode in RING_SETUPS else None),
                           participation=(participation
                                          if participation is not None
                                          else (participation_spec()
                                                if elastic else None)))
    step = build_train_step(model, scfg, mesh)
    state = init_state(params, server=server, seed=7)
    return step, state, batch, model, mesh, comp


def mode_ledger(mode: str, model, m: int):
    """(payload_bytes, scalar_bytes) the VoteWire ledger bills for one round
    of the tiny model at a hypothetical worker count ``m`` — split the way the
    census splits (array payloads vs protocol scalars). The split re-sums to
    ``collectives.uplink_ledger`` exactly (asserted per leaf)."""
    from repro.core import engine
    from repro.dist import collectives

    comp = mode_comp(mode)
    share = engine.needs_shared_linf(comp)
    wire = mode_wire(mode, m)
    emode = wire_mode_of(mode)
    payload = scalar = 0.0
    for s in jax.tree_util.tree_leaves(model.param_shapes()):
        n = int(math.prod(s.shape))
        p = (collectives.decoded_wire_bytes(n, m) if mode == "decoded"
             else wire.wire_bytes(n))
        # pack8 decode scales ride once per ring chunk (x1 monolithic)
        sc = (wire.scalar_bytes() * wire.ring_chunks(n)
              if emode == "pack8" else 0.0) \
            + (collectives.allreduce_scalar_bytes(m) if share else 0.0)
        assert abs((p + sc) - collectives.uplink_ledger(
            emode, wire, n, share_linf=share)) < 1e-6, (mode, n)
        payload += p
        scalar += sc
    return payload, scalar


def mode_bucket_plan(mode: str, model, m: int, bucket_bytes=None):
    """The BucketPlan the bucketed simple step builds for ``model``."""
    from repro.dist import bucketing

    wire = mode_wire(mode, m)
    fmt = bucketing.wire_bucket_format(wire_mode_of(mode), wire)
    return bucketing.build_bucket_plan(
        jax.tree_util.tree_leaves(model.param_shapes()), fmt,
        bucket_bytes=bucket_bytes,
        rows_fn=(wire.payload_rows if fmt == "golomb" else None))


def mode_bucketed_ledger(mode: str, model, m: int, bucket_bytes=None, *,
                         elastic: bool = False):
    """(payload_bytes, scalar_bytes, plan) the bucketed-wire ledger bills for
    one round of ``model`` at ``m`` hypothetical workers — the bucketed twin
    of ``mode_ledger``, split the same census way. ``elastic=True`` bills the
    participation-carrying wire (``uplink_ledger_bucket`` reads the spec off
    the wire: the pack8 side vector widens by the raw-weight entry, the
    ternary gather wires add the (1,) weight scalar, the psum wires' second
    f32 participation all-reduce lands inside the payload term)."""
    from repro.core import engine
    from repro.dist import bucketing

    share = engine.needs_shared_linf(mode_comp(mode))
    wire = mode_wire(mode, m, elastic=elastic)
    plan = mode_bucket_plan(mode, model, m, bucket_bytes)
    payload, scalar = bucketing.plan_ledger(wire_mode_of(mode), wire, plan,
                                            share_linf=share)
    return payload, scalar, plan


def elastic_mode_ledger(mode: str, model, m: int):
    """(payload_bytes, scalar_bytes) the per-leaf ELASTIC wire bills for one
    round at ``m`` hypothetical workers — the weighted-exchange twin of
    ``mode_ledger``, split the census way: the psum wires' participation
    all-reduce is a second per-coordinate f32 payload (inside
    ``wire_bytes``), pack8's widened [scale*w, w] side slot is a (2,) gather
    — >= 2 elements, payload class — and the ternary gather wires' (1,)
    weight is scalar protocol traffic. Re-sums to ``uplink_ledger``
    exactly (asserted per leaf); the decoded mode bypasses the wire object
    (weights premultiply the decode scale), so nothing widens there."""
    from repro.core import engine
    from repro.dist import collectives

    comp = mode_comp(mode)
    share = engine.needs_shared_linf(comp)
    wire = mode_wire(mode, m, elastic=True)
    emode = wire_mode_of(mode)
    payload = scalar = 0.0
    for s in jax.tree_util.tree_leaves(model.param_shapes()):
        n = int(math.prod(s.shape))
        p = (collectives.decoded_wire_bytes(n, m) if mode == "decoded"
             else wire.wire_bytes(n))
        sc = 0.0
        if emode == "pack8":
            p += wire.scalar_bytes() * wire.ring_chunks(n)
        elif mode != "decoded":
            sc += wire.weight_bytes() * wire.ring_chunks(n)
        if share:
            sc += collectives.allreduce_scalar_bytes(m)
        assert abs((p + sc) - collectives.uplink_ledger(
            emode, wire, n, share_linf=share)) < 1e-6, (mode, n)
        payload += p
        scalar += sc
    return payload, scalar


def traced_step_census(mode: str, *, bucketed: bool = False):
    """Trace the mode's built step and census its collectives. Returns
    (census, model)."""
    from repro.dist import compat

    step, state, batch, model, mesh, _ = build_mode_step(mode, bucketed=bucketed)
    with compat.set_mesh(mesh):
        closed = jax.make_jaxpr(step)(state, batch)
    return collective_census(closed), model


def census_check(mode: str, m: int = HYPOTHETICAL_M, *, bucketed: bool = False):
    """The acceptance pin: traced collective array-payload bytes == VoteWire
    ledger bytes at ``m`` hypothetical workers, scalar traffic covers the
    protocol scalars. ``bucketed=True`` pins the bucketed step against the
    ``bucketing.plan_ledger`` twin instead. Returns
    (findings, census, ledger_payload, ledger_scalar)."""
    census, model = traced_step_census(mode, bucketed=bucketed)
    if bucketed:
        payload, scalar, _ = mode_bucketed_ledger(mode, model, m)
    else:
        payload, scalar = mode_ledger(mode, model, m)
    rule = CollectiveCensus(axis_sizes={"data": m})
    label = f"step[{mode}{'/bucketed' if bucketed else ''}]"
    findings = rule.check(label, census,
                          ledger_payload=payload, ledger_scalar_min=scalar)
    return findings, census, payload, scalar


def run_census_checks(m: int = HYPOTHETICAL_M):
    findings, checks = [], 0
    for mode in list(MODE_SETUPS) + list(RING_SETUPS):
        for bucketed in (False, True):
            f, _, _, _ = census_check(mode, m, bucketed=bucketed)
            findings += f
            checks += 1
    return findings, checks


# ---------------------------------------------------------------------------
# Collective LAUNCH counts — the bucketed wire's raison d'etre
# ---------------------------------------------------------------------------

def mode_count_budget(mode: str, model, *, bucketed: bool,
                      m: int = HYPOTHETICAL_M):
    """(expected_payload_launches, max_scalar_launches) for one simple-mode
    round. Per-leaf: one payload exchange per leaf. Bucketed: one per bucket,
    plus one (n_slots,) scale-vector gather on the pack8 wire and one (L,)
    shared-linf pmax when the compressor shares its scale — both >= 2
    elements, so they count as payload launches (and are billed as payload
    bytes by the same rule in ``plan_ledger``). Ring setups launch one
    payload ppermute per CHUNK (the wire's ``ring_chunks`` framing), and
    the ring pack8 bucket re-ships its scale vector with every chunk."""
    from repro.core import engine

    leaves = jax.tree_util.tree_leaves(model.param_shapes())
    n_leaves = len(leaves)
    share = engine.needs_shared_linf(mode_comp(mode))
    wire = mode_wire(mode, m)
    if not bucketed:
        # scalar budget: per-leaf n_sel (+ per-leaf scale protocol on the
        # shared/pack8 wires, once per ring chunk) + metric reductions
        expected = sum(wire.ring_chunks(int(math.prod(s.shape)))
                       for s in leaves)
        return expected, n_leaves + expected + 8
    plan = mode_bucket_plan(mode, model, m)
    if mode in RING_SETUPS:
        chunks = sum(wire.bucket_ring_chunks(b) for b in plan.buckets)
        extra = (chunks if wire_mode_of(mode) == "pack8" else 0) \
            + (1 if share else 0)
        return chunks + extra, 8
    extra = (1 if mode == "pack8" else 0) + (1 if share else 0)
    return len(plan.buckets) + extra, 8


def count_check(mode: str, *, bucketed: bool):
    """Blocking launch-count pin: traced payload-collective launches ==
    the mode budget exactly; scalar launches under the protocol cap."""
    census, model = traced_step_census(mode, bucketed=bucketed)
    expected, max_scalar = mode_count_budget(mode, model, bucketed=bucketed)
    rule = CollectiveCountBudget()
    label = f"step[{mode}{'/bucketed' if bucketed else ''}]"
    return rule.check(label, census, expected_payload=expected,
                      max_scalar=max_scalar), census, expected


def elastic_count_budget(mode: str, model, *, bucketed: bool,
                         m: int = HYPOTHETICAL_M):
    """(expected_payload_launches, max_scalar_launches) of the ELASTIC step:
    the psum wires launch TWO f32 all-reduces per exchange (weighted vote +
    per-coordinate participation count), pack8 gathers its widened
    >= 2-element side vector next to every payload, the ternary gather wires
    add only a (1,) scalar weight gather, and decoded keeps its single psum
    (weights premultiply the decode scale before the reduce). The scalar cap
    widens over the legacy budget for the per-leaf weight gathers / the
    decoded mode's per-leaf participation psums."""
    from repro.core import engine

    leaves = jax.tree_util.tree_leaves(model.param_shapes())
    n_leaves = len(leaves)
    share = engine.needs_shared_linf(mode_comp(mode))
    _, _, vote_impl, _ = _setup_of(mode)
    if mode == "decoded":
        per = 1                 # one f32 psum; W is a scalar psum
    elif wire_mode_of(mode) == "pack8":
        per = 2                 # payload gather + (n_side >= 2,) side gather
    elif vote_impl == "psum":
        per = 2                 # weighted-vote psum + participation psum
    else:
        per = 1                 # ternary gather; the (1,) weight is scalar
    if not bucketed:
        return per * n_leaves, 3 * n_leaves + 8
    plan = mode_bucket_plan(mode, model, m)
    extra = 1 if share else 0   # the (L,) shared-linf pmax
    return per * len(plan.buckets) + extra, len(plan.buckets) + 8


def run_participation_checks(m: int = HYPOTHETICAL_M):
    """The elastic-participation gate: trace the ELASTIC build of every
    wire-mode setup (per-leaf AND bucketed) once, and run three blocking
    rules on the same jaxpr — the census byte pin against the elastic
    ledger, the launch-count pin against the elastic budget, and the
    masked-payload-zero rule (every untiled integer gather payload must
    trace back to its participation mask). The legacy ring setups get the
    mask rule too: the chunked ppermute hop ships the same masked buffers,
    and the cross-scope backtrack (while-carry -> init operand) is exactly
    what the ring exercises."""
    from repro.dist import compat

    findings, checks = [], 0
    census_rule = CollectiveCensus(axis_sizes={"data": m})
    count_rule = CollectiveCountBudget()
    mask_rule = MaskedPayloadZero()
    for mode in MODE_SETUPS:
        for bucketed in (False, True):
            step, state, batch, model, mesh, _ = build_mode_step(
                mode, bucketed=bucketed, elastic=True)
            with compat.set_mesh(mesh):
                closed = jax.make_jaxpr(step)(state, batch)
            census = collective_census(closed)
            label = f"step[{mode}{'/bucketed' if bucketed else ''}/elastic]"
            if bucketed:
                payload, scalar, _ = mode_bucketed_ledger(mode, model, m,
                                                          elastic=True)
            else:
                payload, scalar = elastic_mode_ledger(mode, model, m)
            findings += census_rule.check(label, census,
                                          ledger_payload=payload,
                                          ledger_scalar_min=scalar)
            expected, max_scalar = elastic_count_budget(mode, model,
                                                        bucketed=bucketed,
                                                        m=m)
            findings += count_rule.check(label, census,
                                         expected_payload=expected,
                                         max_scalar=max_scalar)
            findings += mask_rule.check(label, closed)
            checks += 3
    for mode in RING_SETUPS:
        step, state, batch, model, mesh, _ = build_mode_step(mode,
                                                             bucketed=True)
        with compat.set_mesh(mesh):
            closed = jax.make_jaxpr(step)(state, batch)
        findings += mask_rule.check(f"step[{mode}/bucketed]", closed)
        checks += 1
    return findings, checks


#: stacked-block model configs the launch-ratio floor is asserted on
RATIO_CONFIGS = ("qwen1.5-4b", "qwen2.5-32b", "qwen2-moe-a2.7b")

#: per-leaf / bucketed payload-launch floor on every stacked-block config
MIN_COUNT_RATIO = 5.0


def count_ratio_checks(m: int = HYPOTHETICAL_M):
    """Static acceptance floor: on every stacked-block model config, the
    bucketed wire must launch >= MIN_COUNT_RATIO x fewer payload collectives
    than the per-leaf wire, for every mode. Pure plan arithmetic — no big
    model is traced, only its shape tree."""
    from repro.configs.registry import get_config
    from repro.models.model import Model

    rule = CollectiveCountBudget()
    findings, checks = [], 0
    for name in RATIO_CONFIGS:
        model = Model(get_config(name))
        for mode in MODE_SETUPS:
            per_leaf, _ = mode_count_budget(mode, model, bucketed=False)
            bucketed, _ = mode_count_budget(mode, model, bucketed=True)
            checks += 1
            if per_leaf < MIN_COUNT_RATIO * bucketed:
                findings.append(rule.finding(
                    f"{name}[{mode}]",
                    f"bucketed wire launches {bucketed} payload collectives "
                    f"vs {per_leaf} per-leaf — ratio "
                    f"{per_leaf / max(bucketed, 1):.1f}x is under the "
                    f"{MIN_COUNT_RATIO:.0f}x floor"))
    return findings, checks


def run_count_checks():
    findings, checks = [], 0
    for mode in list(MODE_SETUPS) + list(RING_SETUPS):
        for bucketed in (False, True):
            f, _, _ = count_check(mode, bucketed=bucketed)
            findings += f
            checks += 1
    # count_ratio_checks stays on the monolithic setups: the ring trades
    # launch count for residency BY DESIGN (one ppermute per chunk), so a
    # bucketed-vs-per-leaf launch floor is the wrong question there —
    # gather_hbm_checks asserts the ring's own win instead
    f, c = count_ratio_checks()
    return findings + f, checks + c


#: billed-byte floor of the entropy-coded wire vs the flat 2-bit wire at the
#: paper-regime plan sparsity (the PR's acceptance threshold)
MIN_ENTROPY_RATIO = 2.0


def entropy_wire_ledgers(model, m: int = HYPOTHETICAL_M):
    """((golomb_per_leaf, pack2_per_leaf), (golomb_bucketed, pack2_bucketed))
    payload bytes one round of ``model`` bills on the entropy-coded wire vs
    the flat 2-bit gather wire at ``m`` hypothetical workers. Pure ledger/plan
    arithmetic — no tracing; the same formulas the census pins bytes against,
    so a floor asserted here is a floor on the traced wire."""
    from repro.dist import bucketing, collectives

    gw = mode_wire("golomb", m)
    pw = collectives.PackedVoteWire(axes=("data",), n_workers=m)
    leaves = jax.tree_util.tree_leaves(model.param_shapes())
    g_leaf = sum(gw.wire_bytes(int(math.prod(s.shape))) for s in leaves)
    p_leaf = sum(pw.wire_bytes(int(math.prod(s.shape))) for s in leaves)
    g_plan = bucketing.build_bucket_plan(leaves, "golomb",
                                         rows_fn=gw.payload_rows)
    p_plan = bucketing.build_bucket_plan(leaves, "pack2")
    g_bucket, _ = bucketing.plan_ledger("votes", gw, g_plan)
    p_bucket, _ = bucketing.plan_ledger("votes", pw, p_plan)
    return (g_leaf, p_leaf), (g_bucket, p_bucket)


def entropy_wire_checks(m: int = HYPOTHETICAL_M):
    """Blocking byte-ratio floor: on every stacked-block model config, the
    golomb wire's billed payload bytes — capacity padding tax included — must
    undercut the flat 2-bit wire by >= MIN_ENTROPY_RATIO x at the paper-regime
    plan sparsity (GOLOMB_P), per-leaf AND bucketed. The byte twin of
    ``count_ratio_checks``: pure plan arithmetic over the real model shape
    trees, no tracing."""
    from repro.configs.registry import get_config
    from repro.models.model import Model

    rule = EntropyWireBudget(MIN_ENTROPY_RATIO)
    findings, checks = [], 0
    for name in RATIO_CONFIGS:
        model = Model(get_config(name))
        (g_leaf, p_leaf), (g_bucket, p_bucket) = entropy_wire_ledgers(model, m)
        findings += rule.check(f"{name}[per-leaf]",
                               golomb_bytes=g_leaf, pack2_bytes=p_leaf)
        findings += rule.check(f"{name}[bucketed]",
                               golomb_bytes=g_bucket, pack2_bytes=p_bucket)
        checks += 2
    return findings, checks


def _ring_wire_pair(mode: str, m: int, chunk_rows: int):
    """(monolithic, ring) twins of one ring setup's gather wire — identical
    wire class and parameters, only the exchange strategy differs."""
    from repro.dist import collectives

    if mode == "ring_pack8":
        cls, kw = collectives.Pack8Wire, {}
    elif mode == "ring_golomb":
        cls, kw = collectives.GolombWire, {"p": GOLOMB_P}
    else:
        cls, kw = collectives.PackedVoteWire, {}
    mono = cls(axes=("data",), n_workers=m, **kw)
    ring = cls(axes=("data",), n_workers=m, ring_chunk_rows=chunk_rows, **kw)
    return mono, ring


def gather_hbm_checks(m: int = HYPOTHETICAL_M):
    """Blocking peak-HBM floor: on every stacked-block model config, the ring
    gather's peak gathered-payload HBM (``gather_hbm_bytes``, at the
    documented production chunk size) must undercut the monolithic gather's
    M x payload by >= M/2 x for every ring setup, per-leaf AND bucketed.
    Pure ledger/plan arithmetic over the real model shape trees — the same
    formulas the train metric surfaces, so a floor here is a floor on the
    reported residency. M/2 is exact for the single-chunk golomb leaf stream
    (2 chunks vs M payloads of the same stream); every chunked case clears
    it with room."""
    from repro.configs.registry import get_config
    from repro.dist import bucketing, collectives
    from repro.models.model import Model

    rule = GatherHbmBudget(min_ratio=m / 2.0)
    findings, checks = [], 0
    for name in RATIO_CONFIGS:
        model = Model(get_config(name))
        leaves = jax.tree_util.tree_leaves(model.param_shapes())
        sizes = [int(math.prod(s.shape)) for s in leaves]
        for mode in RING_SETUPS:
            mono, ring = _ring_wire_pair(
                mode, m, collectives.DEFAULT_RING_CHUNK_ROWS)
            emode = wire_mode_of(mode)
            findings += rule.check(
                f"{name}[{mode}/per-leaf]",
                ring_bytes=max(ring.gather_hbm_bytes(n) for n in sizes),
                mono_bytes=max(mono.gather_hbm_bytes(n) for n in sizes))
            fmt = bucketing.wire_bucket_format(emode, mono)
            plan = bucketing.build_bucket_plan(
                leaves, fmt,
                rows_fn=(mono.payload_rows if fmt == "golomb" else None))
            findings += rule.check(
                f"{name}[{mode}/bucketed]",
                ring_bytes=bucketing.plan_gather_hbm_bytes(emode, ring, plan),
                mono_bytes=bucketing.plan_gather_hbm_bytes(emode, mono, plan))
            checks += 2
    return findings, checks


def hlo_check(mode: str = "votes"):
    """Compile one step and pin the post-SPMD HLO collective bytes against the
    jaxpr census and the ledger at the BUILD worker count. Tier-1 builds on
    one device, where every ring term is zero on all three sides — degenerate
    but honest; the nonzero byte math of the HLO model is pinned by the
    synthetic-HLO tests in tests/test_analysis.py."""
    from repro.dist import compat

    step, state, batch, model, mesh, _ = build_mode_step(mode)
    with compat.set_mesh(mesh):
        stats = hlo_collective_stats(step, state, batch, default_group=1)
        closed = jax.make_jaxpr(step)(state, batch)
    census = collective_census(closed)
    m = int(mesh.shape["data"])
    jaxpr_bytes = census.total_bytes({"data": m})
    payload, scalar = mode_ledger(mode, model, m)
    rule = HloJaxprAgreement()
    findings = rule.check(f"hlo[{mode}]", hlo_bytes=stats.wire_bytes,
                          jaxpr_bytes=jaxpr_bytes,
                          ledger_bytes=payload + scalar)
    return findings, 1


def run_spec_checks():
    """Per-registry-row traceable-program rules: every fused wire op against
    its declared ``hbm_limits`` contract (the old hand-written int8/int32 pins,
    now spec-driven), plus the bf16 promotion-drift pin — a declared-bf16
    gradient must reach the wire without a full-size f32 HBM copy."""
    import numpy as np
    from repro.core.compressors import SPECS

    findings, checks = [], 0
    g32 = jnp.asarray(np.random.RandomState(11).randn(4096), jnp.float32)
    g16 = g32.astype(jnp.bfloat16)
    drift = DtypePromotionDrift(banned=("float32",), min_elems=2)
    for spec in SPECS.values():
        if spec.fused_pack_op is None:
            continue
        findings += check_fused_uplink(spec, g32)
        checks += 1
        # param resolved OUTSIDE the traced fn: the scale statistic itself
        # legitimately reads g in f32 — the pin is about the uplink path
        param = spec.local_scale(g16) if spec.local_scale is not None else 1.0
        findings += drift.check(
            f"{spec.name}.fused_pack_op[bf16]",
            lambda x: spec.fused_pack_op(x, param, jnp.uint32(7),
                                         interpret=True), g16)
        checks += 1
    return findings, checks
