"""Jaxpr auditor: traceable-program rules over recursively-walked jaxprs.

The walker (``iter_eqns``) is the generalization of the old
``kernels/common.hbm_elems`` visitor (which now delegates here). It descends
into every sub-jaxpr an equation carries — scan/while/cond/pjit bodies,
``custom_jvp_call``/``custom_vjp_call``/``closed_call`` and their
post-AD ``*_jaxpr`` forms via an explicit primitive->param map, plus a generic
sweep over list/tuple/dict-valued params for anything the map doesn't name —
but never into a ``pallas_call`` kernel body, whose values live in VMEM
registers, not HBM.

Rules:

  NoHbmIntermediate(dtype, limit)  — at most ``limit`` elements of ``dtype``
      materialized between ops. Declared per-``CompressorSpec``
      (``spec.hbm_limits``); ``check_fused_uplink`` runs a spec's declared
      rules against its own fused wire op — the declarative replacement for
      every hand-written int8/int32 pin.
  CollectiveCensus(axis_sizes, tolerance) — tally psum/all_gather/ppermute/...
      payload bytes of a traced step under the ring-collective byte model at
      *hypothetical* worker-axis sizes, and pin them against the VoteWire
      ledger. Tracing happens on a 1-device mesh (tier-1); the eqn structure
      is M-independent, so evaluating the model at M=16 gives a non-vacuous
      byte pin without multi-device hardware. M must stay <= 127 so the
      build-time ``_sum_dtype`` bucket (int8) matches the hypothetical M.
  DtypePromotionDrift(banned, min_elems) — flags ``banned``-dtype tensors of
      >= min_elems elements on a declared-narrow (e.g. bf16) leaf path: a
      full-size f32 HBM intermediate on a bf16 uplink is a silent 2x traffic
      regression.
  MaskedPayloadZero — every untiled >= 2-element integer gather payload
      (all_gather/ppermute) must trace back to a ``select_n`` participation
      mask through shape-preserving primitives and across scope boundaries:
      a non-reporting worker's bytes still ride the SPMD gather, so they
      must be exact zeros or they vote.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Callable, Iterable, Iterator, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.framework import Finding, Rule

try:
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover — very old jax
    from jax import core as jcore


# ---------------------------------------------------------------------------
# The walker
# ---------------------------------------------------------------------------

#: primitive -> param keys that carry its sub-jaxprs. The generic param sweep
#: below finds ClosedJaxpr/Jaxpr values wherever they sit, so most primitives
#: need no entry; the explicit map exists for the call-like primitives whose
#: descent is a *contract* (the old walker's blind spot): custom_jvp/custom_vjp
#: calls, closed_call, and the post-partial-eval ``*_call_jaxpr`` forms.
EXPLICIT_SUB_JAXPRS: dict[str, tuple] = {
    "custom_jvp_call": ("call_jaxpr",),
    "custom_jvp_call_jaxpr": ("fun_jaxpr",),
    "custom_vjp_call": ("call_jaxpr",),
    "custom_vjp_call_jaxpr": ("fun_jaxpr",),
    "closed_call": ("call_jaxpr",),
    "core_call": ("call_jaxpr",),
    "remat2": ("jaxpr",),
    "checkpoint": ("jaxpr",),
    "pjit": ("jaxpr",),
    "scan": ("jaxpr",),
    "while": ("cond_jaxpr", "body_jaxpr"),
    "cond": ("branches",),
}


def _param_jaxprs(value, seen: set) -> Iterator:
    """Yield every (unvisited) Jaxpr reachable from one param value:
    ClosedJaxpr/Jaxpr directly, or nested in lists/tuples/dicts."""
    if isinstance(value, jcore.ClosedJaxpr):
        value = value.jaxpr
    if isinstance(value, jcore.Jaxpr):
        if id(value) not in seen:
            seen.add(id(value))
            yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _param_jaxprs(v, seen)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _param_jaxprs(v, seen)


def sub_jaxprs(eqn) -> Iterator:
    """All sub-jaxprs of one equation: the explicit contract params first,
    then the generic sweep (deduplicated, so nothing is visited twice)."""
    seen: set = set()
    for key in EXPLICIT_SUB_JAXPRS.get(eqn.primitive.name, ()):
        if key in eqn.params:
            yield from _param_jaxprs(eqn.params[key], seen)
    for value in eqn.params.values():
        yield from _param_jaxprs(value, seen)


def iter_eqns(jaxpr, *, enter_pallas: bool = False) -> Iterator:
    """Depth-first over every equation of ``jaxpr`` and its sub-jaxprs.
    ``enter_pallas=False`` (the HBM view) stops at pallas_call boundaries."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not enter_pallas:
            continue
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, enter_pallas=enter_pallas)


def _as_jaxpr(fn_or_jaxpr, args):
    if isinstance(fn_or_jaxpr, jcore.ClosedJaxpr):
        return fn_or_jaxpr.jaxpr
    if isinstance(fn_or_jaxpr, jcore.Jaxpr):
        return fn_or_jaxpr
    return jax.make_jaxpr(fn_or_jaxpr)(*args).jaxpr


def hbm_usage(fn, *args, dtypes: Sequence = (jnp.int8,)) -> dict:
    """Element count per dtype of arrays materialized *between* ops (HBM-level
    traffic) when tracing ``fn(*args)``. Pallas kernel bodies excluded."""
    want = {jnp.dtype(d): 0 for d in dtypes}
    for eqn in iter_eqns(_as_jaxpr(fn, args)):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt in want:
                want[dt] += math.prod(aval.shape)
    return want


def hbm_elems(fn, *args, dtype=jnp.int8) -> int:
    """Single-dtype view of ``hbm_usage`` — the engine of the historical
    ``kernels.common.int8_hbm_elems``/``int32_hbm_elems`` pins."""
    return hbm_usage(fn, *args, dtypes=(dtype,))[jnp.dtype(dtype)]


# ---------------------------------------------------------------------------
# NoHbmIntermediate — the per-spec fused-uplink contract
# ---------------------------------------------------------------------------

class NoHbmIntermediate(Rule):
    """At most ``limit`` elements of ``dtype`` may hit HBM in the traced
    program. ``limit=0`` is the fused-kernel guarantee (gradient -> wire bytes
    in one pass); qsgd8 declares ``("int32", 1)`` — the single scatter-start
    index of the canonical-view pad, never an O(n) level tensor."""

    name = "no-hbm-intermediate"
    description = "fused ops must not materialize banned-dtype HBM tensors"

    def __init__(self, dtype, limit: int = 0):
        self.dtype = jnp.dtype(dtype)
        self.limit = int(limit)

    def check(self, label: str, fn, *args) -> list:
        count = hbm_elems(fn, *args, dtype=self.dtype)
        if count > self.limit:
            return [self.finding(
                label,
                f"{count} {self.dtype.name} elements materialized at the HBM "
                f"level (declared limit {self.limit})")]
        return []


def spec_hbm_rules(spec) -> tuple:
    """The NoHbmIntermediate rules one CompressorSpec row declares."""
    return tuple(NoHbmIntermediate(dtype, limit) for dtype, limit in spec.hbm_limits)


def check_fused_uplink(spec, g, *, seed: int = 7, param=None) -> list:
    """Run a spec's declared HBM rules against its own fused wire op.

    ``param`` defaults to the spec's local scale statistic (scale-carrying
    rows) or 1.0 (scale-free rows) — the counts are structural, not
    param-dependent. The seed is passed as uint32 exactly as the engine
    supplies it, so no stray i32->u32 scalar conversion muddies the count.
    """
    if spec.fused_pack_op is None:
        return []
    if param is None:
        param = spec.local_scale(g) if spec.local_scale is not None else 1.0
    findings: list = []
    for rule in spec_hbm_rules(spec):
        findings += rule.check(
            f"{spec.name}.fused_pack_op",
            lambda x: spec.fused_pack_op(x, param, jnp.uint32(seed),
                                         interpret=True), g)
    return findings


# ---------------------------------------------------------------------------
# CollectiveCensus — collective payload bytes vs the VoteWire ledger
# ---------------------------------------------------------------------------

#: ring-model family per collective primitive (mirrors launch/hlo_stats.py and
#: the VoteWire ledgers — one byte model, three places that must agree)
COLLECTIVE_PRIMS = ("psum", "pmax", "pmin", "all_gather", "all_to_all",
                    "ppermute", "reduce_scatter", "psum_scatter")

#: named-axis primitives that move NO payload over the fabric: device-id
#: introspection and the replication-adjustment markers shard_map's
#: check_rep/check_vma machinery inserts. Everything else that names a mesh
#: axis and carries bytes is either modeled (COLLECTIVE_PRIMS) or an
#: *unknown* collective — recorded on ``Census.unknown`` and turned into a
#: blocking Finding by the census rule, never an uncounted zero.
NONWIRE_PRIMS = ("axis_index", "pvary", "pbroadcast")


def _named_axes(eqn) -> tuple:
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(ax, (tuple, list)):
        ax = (ax,)
    return tuple(a for a in ax if isinstance(a, str))


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective equation: what ships, over which named axes.

    ``trips`` is how many times the equation runs per step — the product of
    the ``scan`` lengths enclosing it (a collective inside the streamed
    backward scan launches once per superblock). ``tiled`` marks the
    all_gather variant the FSDP parameter path uses (``tiled=True``); wire
    exchanges gather with ``tiled=False``, so the flag separates parameter
    movement from uplink payload."""

    primitive: str
    axes: tuple
    in_elems: int      # total operand elements (1 => scalar protocol traffic)
    in_bytes: int      # total operand payload bytes
    out_bytes: int
    trips: int = 1
    tiled: bool = False

    def group_size(self, axis_sizes: Mapping[str, int]) -> int:
        m = 1
        for a in self.axes:
            m *= int(axis_sizes[a])
        return m

    def ring_bytes(self, axis_sizes: Mapping[str, int]) -> float:
        """Per-device wire bytes under the ring model at the given axis sizes
        (the same first principles as hlo_stats and the VoteWire ledgers)."""
        m = self.group_size(axis_sizes)
        if m <= 1:
            return 0.0
        if self.primitive in ("psum", "pmax", "pmin"):      # all-reduce
            return 2.0 * (m - 1) / m * self.in_bytes
        if self.primitive == "all_gather":                  # transmit to M-1 peers
            return float((m - 1) * self.in_bytes)
        if self.primitive in ("reduce_scatter", "psum_scatter"):
            return float((m - 1) * self.out_bytes)
        if self.primitive == "all_to_all":
            return (m - 1) / m * self.in_bytes
        # ppermute: the ring-pipelined gather's hop primitive. ONE traced
        # ppermute is an M-1-hop ring (the hop loop is a while_loop, whose
        # body the walker bills at trips=1), each hop shipping the full
        # chunk — so a chunk's ring costs (M-1) x chunk bytes, and summing
        # over chunks reproduces the gather wire's (M-1) x payload exactly.
        assert self.primitive == "ppermute", self.primitive
        return float((m - 1) * self.in_bytes)


@dataclasses.dataclass(frozen=True)
class Census:
    """Every collective of one traced program, byte-costable at any
    hypothetical axis sizes. ``unknown`` holds payload-carrying named-axis
    equations the byte model does NOT cover — they are excluded from every
    byte/count sum (no model to bill them under) and exist to be surfaced
    loudly by the census rule, not silently zeroed."""

    records: tuple
    unknown: tuple = ()

    def counts(self) -> Counter:
        return Counter({p: sum(r.trips for r in self.records if r.primitive == p)
                        for p in {r.primitive for r in self.records}})

    def _select(self, *, min_elems: int = 0, max_elems: Optional[int] = None,
                include_tiled: bool = True):
        return (r for r in self.records
                if r.in_elems >= min_elems
                and (max_elems is None or r.in_elems <= max_elems)
                and (include_tiled or not r.tiled))

    def total_bytes(self, axis_sizes, *, min_elems: int = 0,
                    max_elems: Optional[int] = None,
                    include_tiled: bool = True) -> float:
        return sum(r.trips * r.ring_bytes(axis_sizes)
                   for r in self._select(min_elems=min_elems,
                                         max_elems=max_elems,
                                         include_tiled=include_tiled))

    def payload_bytes(self, axis_sizes) -> float:
        """Array-payload traffic (>= 2 elements): the wire-ledger term.
        FSDP parameter gathers (``tiled=True``) are parameter movement, not
        uplink — the VoteWire ledger does not bill them, so neither does the
        payload view."""
        return self.total_bytes(axis_sizes, min_elems=2, include_tiled=False)

    def scalar_bytes(self, axis_sizes) -> float:
        """Scalar protocol traffic: decode scales, n_sel/loss/nnz metrics."""
        return self.total_bytes(axis_sizes, max_elems=1)

    def payload_count(self) -> int:
        """Launches per step of array-payload (>= 2 element, untiled)
        collectives — the uplink launch count the bucketed wire collapses."""
        return sum(r.trips for r in self._select(min_elems=2,
                                                 include_tiled=False))

    def scalar_count(self) -> int:
        """Launches per step of scalar (<= 1 element) collectives."""
        return sum(r.trips for r in self._select(max_elems=1))


def collective_census(fn, *args) -> Census:
    """Trace ``fn(*args)`` (or take a ready jaxpr) and record every
    collective equation, descending like the HBM walker. Descent through a
    ``scan`` multiplies ``trips`` by the scan length, so a collective inside
    the streamed backward scan is billed once per superblock; ``while`` trip
    counts are unknowable statically and stay at 1 — which is exactly the
    ring gather's billing contract: its hop loop is a while_loop whose single
    ppermute models the whole M-1-hop ring (``CollectiveRecord.ring_bytes``).

    A payload-carrying equation that NAMES a mesh axis but is neither a
    modeled collective (``COLLECTIVE_PRIMS``) nor a known payload-free prim
    (``NONWIRE_PRIMS``) lands on ``Census.unknown`` — the census rule blocks
    on it, because an unmodeled collective silently billed at zero bytes is
    how a ledger pin rots."""
    records = []
    unknown = []

    def walk(jaxpr, trips: int):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
                out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
                records.append(CollectiveRecord(
                    primitive=name,
                    axes=_named_axes(eqn),
                    in_elems=sum(math.prod(a.shape) for a in in_avals),
                    in_bytes=sum(math.prod(a.shape) * jnp.dtype(a.dtype).itemsize
                                 for a in in_avals),
                    out_bytes=sum(math.prod(a.shape) * jnp.dtype(a.dtype).itemsize
                                  for a in out_avals),
                    trips=trips,
                    tiled=bool(eqn.params.get("tiled", False)),
                ))
            elif name not in NONWIRE_PRIMS and _named_axes(eqn):
                in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
                in_bytes = sum(math.prod(a.shape) * jnp.dtype(a.dtype).itemsize
                               for a in in_avals)
                if in_bytes > 0:
                    unknown.append(CollectiveRecord(
                        primitive=name,
                        axes=_named_axes(eqn),
                        in_elems=sum(math.prod(a.shape) for a in in_avals),
                        in_bytes=in_bytes,
                        out_bytes=0,
                        trips=trips,
                    ))
            if name == "pallas_call":
                continue
            sub_trips = trips
            if name == "scan":
                sub_trips = trips * int(eqn.params.get("length", 1))
            for sub in sub_jaxprs(eqn):
                walk(sub, sub_trips)

    walk(_as_jaxpr(fn, args), 1)
    return Census(records=tuple(records), unknown=tuple(unknown))


class CollectiveCensus(Rule):
    """Pin a traced step's collective bytes against the VoteWire ledger.

    Array payloads (>= 2 elements) must equal the ledger's ``wire_bytes`` sum
    exactly (within ``tolerance`` — 0 by default: the ledger is built from the
    same padded buffer sizes the collectives ship). Scalar traffic must cover
    at least the ledger's ``scalar_bytes`` protocol term; the census may
    legitimately exceed it with metric reductions (n_sel / loss / nnz), which
    the ledger deliberately does not bill to the wire.
    """

    name = "collective-census"
    description = "traced collective bytes must match the VoteWire ledger"

    def __init__(self, axis_sizes: Mapping[str, int], tolerance: float = 0.0):
        self.axis_sizes = dict(axis_sizes)
        self.tolerance = float(tolerance)

    def check(self, label: str, census: Census, *, ledger_payload: float,
              ledger_scalar_min: float = 0.0) -> list:
        findings = []
        if census.unknown:
            names = ", ".join(sorted({
                f"{r.primitive}[{','.join(r.axes)}]({r.in_bytes}B)"
                for r in census.unknown}))
            findings.append(self.finding(
                label,
                f"{len(census.unknown)} payload-carrying collective "
                f"equation(s) the byte model does not cover: {names} — an "
                f"unmodeled collective billed at zero bytes voids the "
                f"ledger pin; teach CollectiveRecord.ring_bytes its model "
                f"(or add a payload-free prim to NONWIRE_PRIMS)"))
        payload = census.payload_bytes(self.axis_sizes)
        tol = self.tolerance * max(abs(ledger_payload), 1.0)
        if abs(payload - ledger_payload) > tol:
            findings.append(self.finding(
                label,
                f"collective array-payload bytes {payload:.1f} != VoteWire "
                f"ledger {ledger_payload:.1f} at axis sizes "
                f"{self.axis_sizes} (census: {dict(census.counts())})"))
        scal = census.scalar_bytes(self.axis_sizes)
        if scal + 1e-9 < ledger_scalar_min:
            findings.append(self.finding(
                label,
                f"scalar collective bytes {scal:.1f} do not cover the "
                f"ledger's protocol scalars {ledger_scalar_min:.1f}"))
        return findings


class CollectiveCountBudget(Rule):
    """Pin a traced step's collective LAUNCH counts, not just its bytes.

    Launch count is the latency story the byte census cannot see: a hundred
    tiny exchanges and one bucket of the same total bytes cost the same under
    the ring byte model, but each launch pays fixed fabric latency. The rule
    pins the array-payload launch count to the mode's exact budget (per-leaf:
    one-ish per leaf; bucketed: one-ish per bucket — the builder's formula),
    and caps the scalar protocol launches. Exceeding either is a regression
    to chatty-wire behavior; a payload count BELOW budget means the ledger
    formula itself drifted from the program — both block.
    """

    name = "collective-count"
    description = "traced collective launch counts must match the mode budget"

    def check(self, label: str, census: Census, *, expected_payload: int,
              max_scalar: Optional[int] = None) -> list:
        findings = []
        got = census.payload_count()
        if got != int(expected_payload):
            findings.append(self.finding(
                label,
                f"{got} array-payload collective launches per step, budget "
                f"says exactly {expected_payload} "
                f"(census: {dict(census.counts())})"))
        if max_scalar is not None:
            scal = census.scalar_count()
            if scal > int(max_scalar):
                findings.append(self.finding(
                    label,
                    f"{scal} scalar collective launches per step exceed the "
                    f"protocol budget {max_scalar}"))
        return findings


class EntropyWireBudget(Rule):
    """Blocking compression-ratio floor for the entropy-coded uplink.

    The golomb wire only earns its place if its HONEST billed bytes — static
    capacity rows including the percentile padding tax, exactly what the
    fixed-shape gather ships and the ledger/census pin — undercut the flat
    2-bit wire by at least ``min_ratio`` at the paper-regime plan sparsity.
    A capacity formula drifting loose (over-padded rows), a row-alignment
    regression, or a bucket plan billing coordinate-count fiction would all
    silently eat the sub-2-bit win; this rule blocks on it, the byte twin of
    ``CollectiveCountBudget``'s launch-ratio floor.
    """

    name = "entropy-wire-budget"
    description = ("golomb wire bytes (capacity padding included) must beat "
                   "the flat 2-bit wire by the configured floor")

    def __init__(self, min_ratio: float = 2.0):
        self.min_ratio = float(min_ratio)

    def check(self, label: str, *, golomb_bytes: float,
              pack2_bytes: float) -> list:
        if golomb_bytes * self.min_ratio > pack2_bytes:
            ratio = pack2_bytes / max(golomb_bytes, 1e-9)
            return [self.finding(
                label,
                f"golomb wire bills {golomb_bytes:.0f} B vs {pack2_bytes:.0f} "
                f"B on the flat 2-bit wire — ratio {ratio:.2f}x is under the "
                f"{self.min_ratio:.1f}x floor")]
        return []


class GatherHbmBudget(Rule):
    """Blocking peak-HBM floor for the ring-pipelined gather.

    The ring wire's whole point is residency: the monolithic gather holds
    M x payload of gathered bytes in HBM before decoding, the chunked
    ppermute ring holds ~2 chunks. This rule pins that win via the honest
    ``gather_hbm_bytes`` ledger — ring peak HBM must undercut the monolithic
    gather's by at least ``min_ratio`` (M/2 at the hypothetical census M:
    2 chunks vs M payloads, with chunk <= payload). A chunk-framing
    regression (chunks growing past the payload, a ledger billing the ring
    at gather residency) blocks here; wire BYTES are intentionally not part
    of this rule — the ring moves the same bytes, only the residency drops.
    """

    name = "gather-hbm-budget"
    description = ("ring gather peak payload HBM must undercut the "
                   "monolithic gather by the configured floor")

    def __init__(self, min_ratio: float):
        self.min_ratio = float(min_ratio)

    def check(self, label: str, *, ring_bytes: float,
              mono_bytes: float) -> list:
        if ring_bytes * self.min_ratio > mono_bytes:
            ratio = mono_bytes / max(ring_bytes, 1e-9)
            return [self.finding(
                label,
                f"ring gather peaks at {ring_bytes:.0f} B of gathered "
                f"payload HBM vs {mono_bytes:.0f} B monolithic — ratio "
                f"{ratio:.2f}x is under the {self.min_ratio:.1f}x floor")]
        return []


# ---------------------------------------------------------------------------
# MaskedPayloadZero — a non-reporting worker's gather payload must be zeros
# ---------------------------------------------------------------------------

#: primitives a payload's ZEROS survive unchanged — the mask backtracker
#: walks through these from a gathered operand toward its mask gate: shape/
#: layout moves, dtype casts, bucket assembly (concatenate/pad), and the
#: ring's own hop primitive. Anything else (an add of fresh data, an iota)
#: breaks zero-provenance and the search stops on that path.
MASK_PASS_THROUGH = frozenset({
    "slice", "dynamic_slice", "reshape", "convert_element_type",
    "broadcast_in_dim", "transpose", "squeeze", "expand_dims", "rev",
    "concatenate", "pad", "copy", "ppermute",
})

#: collective primitives whose operand IS a worker's shipped uplink payload
#: (the monolithic gather and the chunked ring's hop)
GATHER_PRIMS = ("all_gather", "ppermute")


def _is_int_payload(aval) -> bool:
    """Is this aval a >= 2-element integer buffer — the shape of a packed
    wire payload? The f32 scale/weight side channels are value-carrying by
    design (a non-reporter's weight slot ships its 0.0) and exempt."""
    dt = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    return (dt is not None and shape is not None
            and jnp.issubdtype(dt, jnp.integer)
            and math.prod(shape) >= 2)


def _producers(jaxpr, cache: dict) -> dict:
    """id(outvar) -> producing eqn table for one jaxpr (memoized)."""
    tbl = cache.get(id(jaxpr))
    if tbl is None:
        tbl = {}
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                tbl[id(v)] = eqn
        cache[id(jaxpr)] = tbl
    return tbl


def _map_invar_out(eqn, sub, idx):
    """The outer operand feeding sub-jaxpr invar ``idx`` of call-like
    ``eqn`` (None if unmappable). ``while`` splits its invars into
    cond-consts + body-consts + carry; ``cond`` prefixes the predicate;
    everything else (pjit/scan/shard_map/remat/custom_* calls) aligns its
    sub invars to the TAIL of the equation invars (1:1 when lengths match)."""
    name = eqn.primitive.name
    if name == "while":
        cn = int(eqn.params.get("cond_nconsts", 0))
        bn = int(eqn.params.get("body_nconsts", 0))
        body = eqn.params["body_jaxpr"]
        body = body.jaxpr if isinstance(body, jcore.ClosedJaxpr) else body
        if sub is body:
            return eqn.invars[cn + idx]
        return eqn.invars[idx] if idx < cn else eqn.invars[bn + idx]
    if name == "cond":
        return eqn.invars[idx + 1]
    n_in, n_sub = len(eqn.invars), len(sub.invars)
    if n_sub <= n_in:
        return eqn.invars[n_in - n_sub + idx]
    return None


def _call_outvar_sources(prod, pos, jaxpr, frames):
    """Where a call-like producer's ``pos``-th output comes from: the
    matching sub-jaxpr outvar (descending a frame), plus — for ``while`` —
    the initial carry operand (the loop may pass the value through
    untouched)."""
    name = prod.primitive.name
    inner = frames + ((jaxpr, prod),)
    if name == "while":
        body = prod.params["body_jaxpr"]
        body = body.jaxpr if isinstance(body, jcore.ClosedJaxpr) else body
        cn = int(prod.params.get("cond_nconsts", 0))
        bn = int(prod.params.get("body_nconsts", 0))
        if pos < len(body.outvars):
            yield body.outvars[pos], body, inner
        if cn + bn + pos < len(prod.invars):
            yield prod.invars[cn + bn + pos], jaxpr, frames
        return
    if name == "cond":
        for br in prod.params.get("branches", ()):
            br = br.jaxpr if isinstance(br, jcore.ClosedJaxpr) else br
            if pos < len(br.outvars):
                yield br.outvars[pos], br, inner
        return
    for sub in sub_jaxprs(prod):
        if pos < len(sub.outvars):
            yield sub.outvars[pos], sub, inner


def traces_to_mask(var, jaxpr, frames, cache=None, seen=None) -> bool:
    """Does ``var``'s producer chain contain a ``select_n`` mask gate?

    Walks backward through ``MASK_PASS_THROUGH`` primitives and through
    ``pallas_call`` pack kernels (an all-zero vote block packs to all-zero
    wire bytes). A jaxpr invar maps UP to the calling equation's operand
    (``frames`` is the ((jaxpr, eqn), ...) call stack built by the site
    walker); a call-like producer maps DOWN into its sub-jaxpr's matching
    outvar. Cycles (the while carry) are cut by the visited set.
    """
    cache = {} if cache is None else cache
    seen = set() if seen is None else seen
    if isinstance(var, jcore.Literal):
        return False
    key = (id(jaxpr), id(var))
    if key in seen:
        return False
    seen.add(key)
    prod = _producers(jaxpr, cache).get(id(var))
    if prod is None:
        # a jaxpr invar: continue in the caller's scope. constvars (closed-
        # over constants) are never mask outputs — dead end.
        try:
            idx = jaxpr.invars.index(var)
        except ValueError:
            return False
        if not frames:
            return False
        caller_jaxpr, caller_eqn = frames[-1]
        outer = _map_invar_out(caller_eqn, jaxpr, idx)
        if outer is None:
            return False
        return traces_to_mask(outer, caller_jaxpr, frames[:-1], cache, seen)
    name = prod.primitive.name
    if name == "select_n":
        return True
    if name == "pallas_call" or name in MASK_PASS_THROUGH:
        return any(traces_to_mask(v, jaxpr, frames, cache, seen)
                   for v in prod.invars if not isinstance(v, jcore.Literal))
    try:
        pos = prod.outvars.index(var)
    except ValueError:
        return False
    for src_var, src_jaxpr, src_frames in _call_outvar_sources(
            prod, pos, jaxpr, frames):
        if traces_to_mask(src_var, src_jaxpr, src_frames, cache, seen):
            return True
    return False


def _gather_payload_sites(jaxpr, frames, out):
    """Collect (eqn, operand var, owning jaxpr, frames) for every untiled
    gather of a >= 2-element integer payload, descending like the census
    walker (pallas bodies excluded) with the call stack threaded through."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if (name in GATHER_PRIMS and not eqn.params.get("tiled", False)
                and _named_axes(eqn)):
            for v in eqn.invars:
                if (not isinstance(v, jcore.Literal)
                        and _is_int_payload(getattr(v, "aval", None))):
                    out.append((eqn, v, jaxpr, frames))
        if name == "pallas_call":
            continue
        for sub in sub_jaxprs(eqn):
            _gather_payload_sites(sub, frames + ((jaxpr, eqn),), out)


class MaskedPayloadZero(Rule):
    """Every gather-wire payload must carry its participation mask gate.

    SPMD ships fixed shapes, so a masked-out (non-reporting) worker's bytes
    still ride every gather wire — correctness of the vote demands those
    bytes be EXACT zeros (an all-zero packed message decodes to zero votes;
    stale nonzero bytes would vote). The structural witness is a
    ``select_n`` — ``VoteWire.mask_message``'s ``jnp.where`` — somewhere in
    the gathered operand's producer chain. The rule backtracks every
    untiled >= 2-element integer-dtype ``all_gather``/``ppermute`` operand
    (packed payloads are integer buffers; the f32 scale/weight side
    channels legitimately ship values and are exempt) through
    shape-preserving primitives, across while/scan/pjit scope boundaries,
    and through pallas pack kernels — and blocks when no mask gate is
    found. FSDP parameter movement (``tiled=True``) is exempt: parameters
    are replicated state, not per-worker reports.
    """

    name = "masked-payload-zero"
    description = ("untiled gather payloads must trace back to a "
                   "participation mask (select_n)")

    def check(self, label: str, fn, *args) -> list:
        sites: list = []
        _gather_payload_sites(_as_jaxpr(fn, args), (), sites)
        findings, cache = [], {}
        for eqn, var, owner, frames in sites:
            if traces_to_mask(var, owner, frames, cache):
                continue
            aval = var.aval
            findings.append(self.finding(
                label,
                f"untiled {eqn.primitive.name}[{','.join(_named_axes(eqn))}] "
                f"ships a {jnp.dtype(aval.dtype).name}{tuple(aval.shape)} "
                f"payload with no participation mask (select_n) in its "
                f"producer chain — a non-reporting worker's stale bytes "
                f"would ride the wire and vote"))
        return findings


# ---------------------------------------------------------------------------
# DtypePromotionDrift — f32 leaks on declared-narrow leaf paths
# ---------------------------------------------------------------------------

class DtypePromotionDrift(Rule):
    """No >= min_elems tensor of a banned (wide) dtype may hit HBM on a path
    declared narrow — e.g. a bf16 gradient leaf reaching the packed wire must
    not round-trip through a full-size f32 copy (in-register f32 math inside
    kernel bodies is fine and expected)."""

    name = "dtype-promotion-drift"
    description = "no full-size wide-dtype HBM tensors on narrow leaf paths"

    def __init__(self, banned: Sequence = ("float32",), min_elems: int = 2):
        self.banned = tuple(jnp.dtype(d) for d in banned)
        self.min_elems = int(min_elems)

    def check(self, label: str, fn, *args) -> list:
        leaks: Counter = Counter()
        for eqn in iter_eqns(_as_jaxpr(fn, args)):
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt in self.banned and math.prod(aval.shape) >= self.min_elems:
                    leaks[(eqn.primitive.name, dt.name)] += math.prod(aval.shape)
        if not leaks:
            return []
        worst = ", ".join(f"{prim}->{dt}({n})" for (prim, dt), n
                          in leaks.most_common(3))
        return [self.finding(
            label,
            f"{sum(leaks.values())} wide-dtype elements (>= {self.min_elems} "
            f"per tensor) materialized on a declared-narrow path: {worst}")]
