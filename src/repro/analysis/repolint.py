"""AST repo-lint: the architecture invariants the registry refactors bought,
enforced at parse time with a **zero-entry allowlist**.

Rules (scope: every ``.py`` under ``src/repro``):

  no-compressor-name-branching — comparing an identifier that mentions
      ``compressor``/``algorithm`` against a ``SPECS`` name (or a
      ``startswith`` prefix of one) is dispatch-by-name: the drift PR 4/5
      eradicated. All capability questions go through
      ``core.compressors.SPECS`` lookups. (The registry module itself — where
      the names are *defined* — is exempt.)
  no-raw-collectives — ``lax.psum``/``all_gather``/... outside
      ``dist/collectives.py`` bypasses the VoteWire ledger: bytes move that no
      ledger bills. Use ``collectives.scalar_psum`` (metrics),
      ``collectives.fsdp_all_gather`` (param gathers) or a VoteWire.
      ``lax.axis_index`` is fine — it moves no payload.
  no-jnp-alloc-in-kernel — inside a Pallas kernel body (any function with a
      ``*_ref`` parameter in ``kernels/*/kernel.py``), literal-shape jnp
      allocators (``jnp.zeros``/``arange``/``asarray``/...) don't lower on
      TPU (1-D iota, host-shape allocation — scratch memory belongs in
      ``scratch_shapes``). Elementwise jnp math and ``*_like`` constructors
      (shape taken from a Ref operand) are kernel-legal and allowed.
  specs-complete — runtime registry lint: every ``CompressorSpec`` row is
      fully populated (fused ops must declare their ``hbm_limits`` contract,
      ``uplink_bits`` must name a bit model) and the legacy ``COMPRESSORS``
      view is exactly the derived table.

The allowlist is the escape hatch for a *temporarily* grandfathered site; it
ships empty and tests pin it empty.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Optional

from repro.analysis.framework import Finding, Rule

#: (rule_name, repo-relative posix path) pairs exempted from that rule.
#: SHIPS EMPTY — tests/test_analysis.py pins ``len(ALLOWLIST) == 0``.
ALLOWLIST: frozenset = frozenset()

#: the package root this lint walks (src/repro)
PKG_ROOT = Path(__file__).resolve().parents[1]

_BANNED_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "pshuffle", "psum_scatter", "reduce_scatter",
})

#: literal-shape allocators + iota family; *_like variants deliberately absent
_JNP_ALLOC_FNS = frozenset({
    "zeros", "ones", "full", "empty", "arange", "linspace", "logspace",
    "eye", "tri", "identity", "indices", "asarray", "array", "frombuffer",
    "fromfunction", "meshgrid",
})

_NAME_TOKENS = ("compressor", "algorithm")


def _dotted(node) -> Optional[str]:
    """'jax.lax.psum' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_compressor(node) -> bool:
    """Does this expression involve an identifier naming a compressor/algorithm?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and any(t in n.id.lower() for t in _NAME_TOKENS):
            return True
        if isinstance(n, ast.Attribute) and any(t in n.attr.lower() for t in _NAME_TOKENS):
            return True
    return False


def _spec_names() -> frozenset:
    from repro.core.compressors import SPECS
    return frozenset(SPECS)


def _str_consts(node) -> list:
    """String literals of a comparator: a Constant, or the elements of a
    literal tuple/list/set."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


class NoCompressorNameBranching(Rule):
    name = "no-compressor-name-branching"
    description = "dispatch on compressor names only via core.compressors.SPECS"

    EXEMPT = ("repro/core/compressors.py",)

    def check(self, tree: ast.AST, relpath: str) -> list:
        if relpath in self.EXEMPT:
            return []
        names = _spec_names()
        findings = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Compare) and any(
                    isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn))
                    for op in node.ops):
                sides = [node.left, *node.comparators]
                lits = [s for side in sides for s in _str_consts(side)]
                hit = sorted(set(lits) & names)
                if hit and any(_mentions_compressor(s) for s in sides
                               if not _str_consts(s)):
                    findings.append(self.finding(
                        f"{relpath}:{node.lineno}",
                        f"branches on compressor name(s) {hit} — use a "
                        f"CompressorSpec lookup (get_spec(...).<field>)"))
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr == "startswith"
                  and node.args
                  and _mentions_compressor(node.func.value)):
                for prefix in _str_consts(node.args[0]):
                    if prefix and any(n.startswith(prefix) for n in names):
                        findings.append(self.finding(
                            f"{relpath}:{node.lineno}",
                            f"prefix-matches compressor names via "
                            f"startswith({prefix!r}) — use a CompressorSpec "
                            f"lookup"))
                        break
        return findings


class NoRawCollectives(Rule):
    name = "no-raw-collectives"
    description = "lax collectives live in dist/collectives.py only"

    EXEMPT = ("repro/dist/collectives.py",)

    def check(self, tree: ast.AST, relpath: str) -> list:
        if relpath in self.EXEMPT:
            return []
        findings = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and node.attr in _BANNED_COLLECTIVES:
                chain = _dotted(node.value)
                if chain is not None and chain.split(".")[-1] == "lax":
                    findings.append(self.finding(
                        f"{relpath}:{node.lineno}",
                        f"raw lax.{node.attr} outside dist/collectives.py — "
                        f"bytes the VoteWire ledger never sees; use the "
                        f"sanctioned wrapper (collectives.scalar_psum / "
                        f"fsdp_all_gather / a VoteWire exchange)"))
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.module.split(".")[-1] == "lax":
                bad = sorted({a.name for a in node.names} & _BANNED_COLLECTIVES)
                if bad:
                    findings.append(self.finding(
                        f"{relpath}:{node.lineno}",
                        f"imports raw collectives {bad} from jax.lax outside "
                        f"dist/collectives.py"))
        return findings


class NoJnpAllocInKernel(Rule):
    name = "no-jnp-alloc-in-kernel"
    description = "no literal-shape jnp allocation inside Pallas kernel bodies"

    @staticmethod
    def _is_kernel_file(relpath: str) -> bool:
        parts = Path(relpath).parts
        return "kernels" in parts and parts[-1] == "kernel.py"

    def check(self, tree: ast.AST, relpath: str) -> list:
        if not self._is_kernel_file(relpath):
            return []
        findings = []
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = node.args
            all_args = [*args.posonlyargs, *args.args, *args.kwonlyargs]
            if not any(a.arg.endswith("_ref") for a in all_args):
                continue  # not a kernel body (wrapper/launcher code is fine)
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in _JNP_ALLOC_FNS):
                    chain = _dotted(sub.func.value)
                    if chain in ("jnp", "jax.numpy", "numpy", "np"):
                        findings.append(self.finding(
                            f"{relpath}:{sub.lineno}",
                            f"{chain}.{sub.func.attr} inside kernel body "
                            f"{node.name!r}: literal-shape allocation/iota "
                            f"does not lower on TPU — use "
                            f"lax.broadcasted_iota, *_like, or a "
                            f"scratch_shapes entry"))
        return findings


class SpecsComplete(Rule):
    name = "specs-complete"
    description = "every CompressorSpec row fully declares its contracts"

    def check(self) -> list:
        import jax.numpy as jnp

        from repro.core import compressors as C

        findings = []
        where = "repro/core/compressors.py"
        for name, spec in C.SPECS.items():
            if spec.name != name:
                findings.append(self.finding(
                    where, f"SPECS key {name!r} != spec.name {spec.name!r}"))
            if not callable(spec.api) or not callable(spec.values):
                findings.append(self.finding(
                    where, f"{name}: api/values must be callable"))
            if spec.uplink_bits not in C.UPLINK_BIT_MODELS:
                findings.append(self.finding(
                    where, f"{name}: uplink_bits {spec.uplink_bits!r} not in "
                           f"{C.UPLINK_BIT_MODELS}"))
            if spec.fused_pack_op is not None and not spec.hbm_limits:
                findings.append(self.finding(
                    where, f"{name}: a fused wire op must declare its "
                           f"hbm_limits contract (which dtypes never hit HBM)"))
            for entry in spec.hbm_limits:
                dtype, limit = entry
                try:
                    jnp.dtype(dtype)
                except TypeError:
                    findings.append(self.finding(
                        where, f"{name}: hbm_limits dtype {dtype!r} unknown"))
                if not isinstance(limit, int) or limit < 0:
                    findings.append(self.finding(
                        where, f"{name}: hbm_limits limit {limit!r} must be "
                               f"an int >= 0"))
        if C.COMPRESSORS != {n: s.api for n, s in C.SPECS.items()}:
            findings.append(self.finding(
                where, "COMPRESSORS is not the derived {name: spec.api} view"))
        return findings


AST_RULES = (NoCompressorNameBranching(), NoRawCollectives(), NoJnpAllocInKernel())


def _allowed(f: Finding) -> bool:
    relpath = f.where.rsplit(":", 1)[0]
    return (f.rule, relpath) in ALLOWLIST


def lint_source(src: str, relpath: str) -> list:
    """Run the AST rules over one source string (unit-test entry point)."""
    tree = ast.parse(src, filename=relpath)
    findings = []
    for rule in AST_RULES:
        findings += rule.check(tree, relpath)
    return [f for f in findings if not _allowed(f)]


def iter_py_files(root: Path) -> Iterable[Path]:
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


def run_repolint(root: Optional[Path] = None) -> tuple:
    """AST rules over every file under src/repro + the registry lint.
    Returns (findings, checks)."""
    root = Path(root) if root is not None else PKG_ROOT
    findings = []
    checks = 0
    for path in iter_py_files(root):
        relpath = "repro/" + path.relative_to(root).as_posix() \
            if root.name == "repro" else path.relative_to(root).as_posix()
        findings += lint_source(path.read_text(), relpath)
        checks += len(AST_RULES)
    specs_rule = SpecsComplete()
    findings += [f for f in specs_rule.check() if not _allowed(f)]
    checks += 1
    return findings, checks
