"""``python -m repro.analysis`` — run every static-analysis pass and exit
nonzero on any error finding. This is the blocking CI gate.

Order: AST repo-lint first (cheap, no tracing), then per-spec traceable-program
rules, then the wire-mode collective censuses (per-leaf AND bucketed), then the
collective launch-count budgets (with the bucketed >= 5x launch-ratio floor on
the stacked-block configs), then the elastic-participation gate (census +
count pins on the weighted-exchange builds, plus the masked-payload-zero rule
on every gather wire), then the entropy-wire byte-ratio floor (golomb must
beat the flat 2-bit wire >= 2x on the same configs), then the ring gather's
peak-HBM floor (ring residency must undercut the monolithic gather >= M/2 x
on the same configs), then the HLO agreement check (compiles one step).
"""

from __future__ import annotations

import sys

from repro.analysis import drivers, report
from repro.analysis.framework import merge
from repro.analysis.repolint import run_repolint


def main(argv=None) -> int:
    reports = []

    findings, checks = run_repolint()
    reports.append(report(findings, checks))
    print(f"repolint: {checks} checks, {len(findings)} findings", flush=True)

    findings, checks = drivers.run_spec_checks()
    reports.append(report(findings, checks))
    print(f"spec rules: {checks} checks, {len(findings)} findings", flush=True)

    findings, checks = drivers.run_census_checks()
    reports.append(report(findings, checks))
    print(f"collective census: {checks} checks, {len(findings)} findings",
          flush=True)

    findings, checks = drivers.run_count_checks()
    reports.append(report(findings, checks))
    print(f"collective counts: {checks} checks, {len(findings)} findings",
          flush=True)

    findings, checks = drivers.run_participation_checks()
    reports.append(report(findings, checks))
    print(f"participation wire: {checks} checks, {len(findings)} findings",
          flush=True)

    findings, checks = drivers.entropy_wire_checks()
    reports.append(report(findings, checks))
    print(f"entropy wire budget: {checks} checks, {len(findings)} findings",
          flush=True)

    findings, checks = drivers.gather_hbm_checks()
    reports.append(report(findings, checks))
    print(f"gather hbm budget: {checks} checks, {len(findings)} findings",
          flush=True)

    findings, checks = drivers.hlo_check()
    reports.append(report(findings, checks))
    print(f"hlo agreement: {checks} checks, {len(findings)} findings",
          flush=True)

    rep = merge(reports)
    print(rep.render())
    return rep.exit_code()


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
