"""`repro.analysis` — the rule-based static-analysis subsystem.

Three passes share one ``Rule``/``Finding``/``report`` framework
(``analysis.framework``):

  jaxpr_audit — traceable-program rules: ``NoHbmIntermediate`` (the
                per-CompressorSpec generalization of the old hand-written
                ``int8_hbm_elems`` pins), ``CollectiveCensus`` (collective
                payload bytes vs the VoteWire ledger) and
                ``DtypePromotionDrift`` (f32 leaks on bf16 leaf paths).
  hlo_audit   — the post-SPMD collective census (``launch/hlo_stats``) pinned
                against the jaxpr census and the ledger within a documented
                padding tolerance.
  repolint    — AST architecture lint: no compressor name-branching outside
                ``core/compressors.SPECS``, no raw ``lax`` collectives outside
                ``dist/collectives.py``, no jnp array allocation inside Pallas
                kernel bodies, SPECS completeness. Zero-entry allowlist.

``python -m repro.analysis`` runs everything and exits nonzero on any error
finding — the blocking CI gate.
"""

from repro.analysis.framework import Finding, Report, Rule, report

__all__ = ["Finding", "Report", "Rule", "report"]
