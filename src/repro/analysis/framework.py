"""The shared Rule/Finding/report skeleton of every analysis pass.

A ``Rule`` is a named, declarative check. Each pass hands its rules whatever
artifact it analyzes (a traced jaxpr, compiled HLO text, a python AST) and the
rule answers with ``Finding``s — never by raising. A ``Report`` aggregates
findings across rules and renders them; the CLI exit code is
``report.exit_code()``. Severity ``error`` blocks; ``info`` is advisory
context (e.g. census byte tables) printed but never failing.

Adding a rule = subclass ``Rule``, set ``name``/``description``, implement a
``check(...)`` returning ``list[Finding]`` (use ``self.finding(...)``), and
register it with the pass that owns its artifact type (see README "Static
analysis").
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

SEVERITIES = ("error", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or advisory note) at one location.

    ``where`` is whatever locates the artifact: ``path:line`` for AST rules, a
    program label (e.g. ``step[pack8]``) for jaxpr/HLO rules.
    """

    rule: str
    where: str
    message: str
    severity: str = "error"

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def render(self) -> str:
        return f"[{self.severity}] {self.rule}: {self.where}: {self.message}"


class Rule:
    """Base class: a named check producing findings.

    Subclasses define ``check(...)`` with whatever signature their pass calls
    them with; the contract is only that it returns ``list[Finding]``.
    """

    name: str = "rule"
    description: str = ""

    def finding(self, where: str, message: str, *, severity: str = "error") -> Finding:
        return Finding(rule=self.name, where=where, message=message,
                       severity=severity)

    def check(self, *args, **kwargs) -> "list[Finding]":
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Report:
    """Aggregated findings of one analysis run."""

    findings: tuple
    checks: int = 0   # how many rule evaluations ran (a 0-finding report with
                      # 0 checks is a configuration bug, not a clean bill)

    @property
    def errors(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == "error")

    @property
    def ok(self) -> bool:
        return not self.errors

    def exit_code(self) -> int:
        return 0 if self.ok else 1

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        verdict = "OK" if self.ok else "FAIL"
        lines.append(f"{verdict}: {self.checks} checks, "
                     f"{len(self.errors)} errors, "
                     f"{len(self.findings) - len(self.errors)} notes")
        return "\n".join(lines)


def report(findings: Iterable[Finding], checks: int) -> Report:
    return Report(findings=tuple(findings), checks=checks)


def merge(reports: Sequence[Report]) -> Report:
    out: list[Finding] = []
    checks = 0
    for r in reports:
        out.extend(r.findings)
        checks += r.checks
    return Report(findings=tuple(out), checks=checks)
