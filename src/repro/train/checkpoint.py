"""Checkpointing: atomic, mesh-independent, elastic-restore.

Format: one directory per step containing a ``manifest.json`` (tree structure,
shapes, dtypes, step, seed, and a structure *fingerprint*) and flat ``.npy``
payloads keyed by canonical leaf index. Writes go to ``<dir>.tmp`` then
``os.rename`` (atomic on POSIX) so a crash mid-save never corrupts the latest
checkpoint; ``keep`` rotation prunes old steps. Arrays are saved *logically*
(fully-gathered numpy) — restore re-shards onto ANY mesh via device_put with
the target shardings, which is the elastic-scaling path: majority-vote state
is M-invariant so a checkpoint trained on 256 chips resumes on 8
(tests/mdev/check_fault_tolerance.py).

The fingerprint hashes every leaf's (path, shape, dtype): restoring into a
state whose tree doesn't match raises ``CheckpointMismatchError`` instead of
silently loading another run's weights — the classic stale-/tmp-dir footgun
(``train.loop`` catches it and starts fresh with a loud warning).

For multi-TB models a production deployment would write per-shard payloads;
the manifest format has a ``sharded`` flag reserved for that extension.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


class CheckpointMismatchError(ValueError):
    """The checkpoint's tree/config fingerprint doesn't match the restore
    target — it belongs to a different model or run configuration."""


def _leaf_descs(tree) -> list[list]:
    """[(keypath, shape, dtype)] per leaf — the structural identity of a state
    pytree (values excluded). ShapeDtypeStructs and arrays both work."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for p, leaf in flat:
        shape = list(getattr(leaf, "shape", np.shape(leaf)))
        dtype = str(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        out.append([jax.tree_util.keystr(p), shape, dtype])
    return out


def tree_fingerprint(tree) -> str:
    """Stable hex digest of the tree structure + per-leaf shapes/dtypes."""
    payload = json.dumps(_leaf_descs(tree), separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _tree_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def save(ckpt_dir: str, step: int, state, *, keep: int = 3, extra: Optional[dict] = None):
    """Atomically save a TrainState-like pytree."""
    target = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = target + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    manifest = {
        "step": int(step),
        "n_leaves": len(flat),
        "paths": [jax.tree_util.keystr(p) for p, _ in flat],
        "leaves": _leaf_descs(state),
        "fingerprint": tree_fingerprint(state),
        "extra": extra or {},
        "sharded": False,
    }
    for i, (_, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:  # numpy can't round-trip bf16: widen losslessly
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(target):
        shutil.rmtree(target)
    os.rename(tmp, target)  # atomic publish
    _rotate(ckpt_dir, keep)
    return target


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
                out.append(int(name[5:]))
    return sorted(out)


def restore(ckpt_dir: str, like, *, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of NamedSharding
    for resharding onto the current mesh (elastic restore)."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, MANIFEST)) as f:
        manifest = json.load(f)

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    want_fp = tree_fingerprint(like)
    got_fp = manifest.get("fingerprint")
    if got_fp is not None and got_fp != want_fp:
        want_desc = {tuple(d[0:1]) + (tuple(d[1]), d[2]) for d in _leaf_descs(like)}
        got_desc = {tuple(d[0:1]) + (tuple(d[1]), d[2]) for d in manifest.get("leaves", [])}
        diff = sorted(x[0] for x in want_desc.symmetric_difference(got_desc))[:8]
        raise CheckpointMismatchError(
            f"checkpoint {src} was written by a different model/config: "
            f"fingerprint {got_fp} != expected {want_fp} "
            f"(first differing leaves: {diff}). Point ckpt_dir at a fresh "
            f"directory, or delete the stale checkpoint.")
    # legacy manifests (no fingerprint) still get the structural checks
    if len(flat_like) != manifest["n_leaves"]:
        raise CheckpointMismatchError(
            f"leaf count mismatch: ckpt {manifest['n_leaves']} vs target {len(flat_like)}")
    want_paths = [jax.tree_util.keystr(p) for p, _ in flat_like]
    if want_paths != manifest["paths"]:
        raise CheckpointMismatchError("tree structure mismatch on restore")

    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_like))
    leaves = []
    for i, ((_, leaf_like), sh) in enumerate(zip(flat_like, sh_flat)):
        arr = np.load(os.path.join(src, f"leaf_{i:05d}.npy"))
        dtype = leaf_like.dtype
        val = jnp.asarray(arr, dtype=dtype)
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves), manifest
