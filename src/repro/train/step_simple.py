"""`simple`-mode distributed train step (DESIGN.md §3 mode 1).

Top level: ``jax.shard_map`` manual over the worker axes ('pod','data') — the
paper's M workers — and auto (GSPMD) over 'model' (TP/EP/SP). Parameters are
replicated across workers and sharded over 'model' by their placement +
``hint()`` constraints inside the model code.

Per round (Algorithm 1 / Algorithm 2 with tau=1..):
  1. every worker computes the local gradient of its microbatch
     (optionally tau compressed local steps, Alg. 2),
  2. compresses each gradient leaf with its worker-specific counter stream,
     in the vote wire's native format (int8 ternary for the psum wires, fused
     2-bit packed for `allgather_packed`),
  3. one wire exchange over the worker axes = upload + server sum
     (`repro.dist.collectives.VoteWire`: psum | hier | allgather_packed),
  4. C(.) (majority vote sign, scaled-sign with server-side EF, or the scaled
     mean for shared-scale ternary baselines) computed redundantly everywhere
     = free downlink,
  5. SGD update; params stay bitwise identical across workers.

Which wire a compressor rides is negotiated from the CompressorSpec table
(``engine.wire_mode``): ternary compressors with a worker-invariant scale
(scale-free, or TernGrad's psum-max'd shared_max) exchange ternary votes on
the integer/packed wire even under a mean server; qsgd8's int8 sign*level
payload rides the 1 B/coord pack8 gather (+ per-worker f32 scales) when
``vote_impl='allgather_packed'``; per-worker-scale ternary baselines
(qsgd_1bit/scaled_sign under mean) and the float formats psum decoded
float32 — honestly costing fp32 collective bytes, which is exactly the
communication gap the paper's tables report.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engine, prng
from repro.core.algorithm import CompressionConfig
from repro.dist import bucketing, collectives, compat
from repro.dist.sharding import ACT_RULES_TRAIN
from repro.models.common import axis_rules
from repro.train import sampling
from repro.train.state import LrSchedule, TrainState


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    compression: CompressionConfig
    lr: LrSchedule
    local_lr: float = 1.0          # eta_L (Alg. 2)
    worker_axes: Sequence[str] = ("data",)
    vote_impl: str = "psum"        # psum | hier | allgather_packed
    quorum: Any = 1                # server deadband: |votes| < quorum -> no step;
                                   # int (broadcast) or a pytree prefix of the
                                   # param tree with per-leaf ints
    donate: bool = True
    backend: Optional[str] = None  # kernel backend; None -> $REPRO_KERNEL_BACKEND
    bucketed: bool = False         # bucketized uplink: one collective per wire
                                   # bucket instead of one per gradient leaf
    bucket_bytes: Optional[int] = None  # payload cap per bucket (None: one
                                        # bucket for the whole tree)
    golomb_p: Optional[float] = None    # plan-time nnz fraction sizing the
                                        # golomb wire's static capacity (None:
                                        # a target_sparsity budget's target)
    ring_chunk_rows: Optional[int] = None  # ring-pipelined gather: payload
                                           # rows per ppermute chunk (gather
                                           # wires only; None: monolithic
                                           # all_gather)
    participation: Optional[collectives.ParticipationSpec] = None
                                           # elastic participation: per-worker
                                           # vote weights + quorum-fraction
                                           # deadband + report dropout; None =
                                           # the legacy fixed-quorum path


def _leaf_seeds(worker_seed, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    seeds = [prng.fold_seed(worker_seed, i) for i in range(len(leaves))]
    return jax.tree_util.tree_unflatten(treedef, seeds)


def _local_grads(model, params, batch, comp_cfg: CompressionConfig, wseed, local_lr,
                 backend=None):
    """Returns (loss, message_source_tree).

    tau == 1: message source = the raw local gradient (Alg. 1).
    tau > 1 : message source = sum of the tau compressed local steps (Alg. 2);
              batch leaves carry a leading tau axis.
    """
    loss_fn = lambda p, b: model.loss(p, b)[0]
    tau = comp_cfg.local_steps
    if tau == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    local_cfg = engine.local_step_config(comp_cfg)

    def body(carry, c):
        w, acc = carry
        micro = jax.tree_util.tree_map(lambda x: x[c], batch)
        loss, grads = jax.value_and_grad(loss_fn)(w, micro)
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        qs = []
        for i, g in enumerate(leaves):
            seed = prng.fold_seed(wseed, 7000 + i)
            q = engine.compress_leaf(g, local_cfg, seed, counter_base=c * g.size,
                                     backend=backend).values
            qs.append(q)
        q_tree = jax.tree_util.tree_unflatten(treedef, qs)
        w = jax.tree_util.tree_map(lambda p, q: p - local_lr * q.astype(p.dtype), w, q_tree)
        acc = jax.tree_util.tree_map(lambda a, q: a + q.astype(jnp.int32), acc, q_tree)
        return (w, acc), loss

    acc0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.int32), params)
    (_, acc), losses = jax.lax.scan(body, (params, acc0), jnp.arange(tau))
    msg_source = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), acc)
    return jnp.mean(losses), msg_source


def build_train_step(model, step_cfg: TrainStepConfig, mesh) -> Callable:
    """Returns jit'd train_step(state, batch) -> (state, metrics)."""
    comp = step_cfg.compression
    axes = tuple(step_cfg.worker_axes)
    backend = engine.resolve_backend(step_cfg.backend)
    # wire negotiation + per-leaf quorum: CompressorSpec/table lookups resolved
    # (and validated) before tracing
    mode = engine.wire_mode(comp, vote_impl=step_cfg.vote_impl)
    # built (and validated — hier demands two worker axes, sizes >= 1) at
    # step-build time, in the compressor's declared payload format; golomb
    # specs additionally resolve the plan-time nnz fraction that sizes the
    # entropy-coded wire's static capacity
    wire_fmt = engine.wire_payload_format(comp, mode,
                                          vote_impl=step_cfg.vote_impl)
    part = step_cfg.participation
    if part is not None:
        # elastic participation: loud build-time gates — the EF server cannot
        # be participation-normalized, and the weights must cover the mesh
        engine.check_participation_server(comp.server, comp.compressor)
    wire = collectives.make_vote_wire(
        step_cfg.vote_impl, axes, mesh, backend=backend,
        wire_format=wire_fmt,
        golomb_p=(engine.resolve_golomb_p(comp, step_cfg.golomb_p)
                  if wire_fmt == "golomb" else None),
        ring_chunk_rows=engine.resolve_ring_chunk_rows(
            step_cfg.ring_chunk_rows, step_cfg.vote_impl),
        participation=part)
    share_linf = engine.needs_shared_linf(comp)
    if mode != "votes" and engine.needs_server_ef(comp.server):
        raise ValueError(
            f"server {comp.server!r} keeps an error-feedback residual that "
            f"only updates on the integer vote wire, but compressor "
            f"{comp.compressor!r} rides the {mode!r} wire — the run would "
            f"silently aggregate by mean while carrying a dead full-model EF "
            f"residual; use a ternary vote-wire compressor or a plain 'mean' "
            f"server")
    quorum_leaves = jax.tree_util.tree_leaves(
        engine.broadcast_quorum(step_cfg.quorum, model.param_shapes()))
    # per-leaf quorum as a FRACTION of realized participation (build-time:
    # bad quorums and q_frac out of (0,1] fail before tracing)
    q_fracs = ([part.resolve_q_frac(q, wire.n_workers) for q in quorum_leaves]
               if part is not None else None)
    if mode != "votes" and any(q != 1 for q in quorum_leaves):
        raise ValueError(
            f"quorum={step_cfg.quorum!r} is a vote-server deadband, but "
            f"compressor {comp.compressor!r} with server {comp.server!r} "
            f"rides the {mode!r} wire where it would be silently ignored; "
            f"use a vote server ({engine.VOTE_SERVERS}) or quorum=1")

    # static bucket layout (bucketed uplink): the whole tree's leaves packed
    # into few wire buckets, offsets row-aligned per the wire's payload format
    plan = None
    if step_cfg.bucketed:
        bucket_fmt = bucketing.wire_bucket_format(mode, wire)
        plan = bucketing.build_bucket_plan(
            jax.tree_util.tree_leaves(model.param_shapes()),
            bucket_fmt,
            bucket_bytes=step_cfg.bucket_bytes,
            # golomb slots are CAPACITY rows — a pure (n, p) function owned
            # by the wire, not a coordinate-count row formula
            rows_fn=(wire.payload_rows if bucket_fmt == "golomb" else None))

    # activation hints may only target auto (non-worker) mesh axes; in pure-DP
    # mode every axis is a worker and no constraints are needed (all compute local)
    act_rules = {k: v for k, v in ACT_RULES_TRAIN.items()
                 if not (isinstance(v, str) and v in axes)}

    def body(state: TrainState, batch):
        with axis_rules(act_rules, mesh):
            return _body_inner(state, batch)

    def _finish(state, treedef, new_leaves, ef_leaves, loss, lr, nnz_acc,
                total, mask, wire_bytes, gather_hbm):
        n_workers = collectives.worker_count(axes)
        new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
        new_ef_tree = (jax.tree_util.tree_unflatten(treedef, ef_leaves)
                       if state.ef_residual is not None else None)
        loss_mean = collectives.scalar_psum(loss, axes) / n_workers
        nnz_mean = collectives.scalar_psum(nnz_acc, axes) / n_workers / jnp.float32(total)
        metrics = {"loss": loss_mean, "lr": lr, "nnz_frac": nnz_mean,
                   "participated": collectives.scalar_psum(mask.astype(jnp.float32), axes),
                   "wire_bytes_per_device": jnp.float32(wire_bytes),
                   "gather_hbm_bytes": jnp.float32(gather_hbm)}
        new_state = TrainState(params=new_params, ef_residual=new_ef_tree,
                               step=state.step + 1, seed=state.seed)
        return new_state, metrics

    def _body_inner(state: TrainState, batch):
        params = state.params
        widx = collectives.worker_index(axes)
        n_workers = collectives.worker_count(axes)
        rseed = sampling.round_seed(state.seed, state.step)
        wseed = prng.fold_seed(rseed, 0x5EED) + widx.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        mask = sampling.participation_mask(rseed, state.step, widx, comp.worker_sample_fraction)
        if part is not None:
            # elastic: the round's effective reporting set is the sampled set
            # minus chaos dropouts; w_eff = static weight x report bit is the
            # weight that rides the wire (exact 0.0 for a silent worker)
            mask = mask & sampling.report_mask(rseed, state.step, widx,
                                               part.dropout)
            w_eff = (part.weight_of(widx, n_workers)
                     * mask.astype(jnp.float32))

        loss, msg_src = _local_grads(model, params, batch, comp, wseed,
                                     step_cfg.local_lr, backend=backend)

        leaves, treedef = jax.tree_util.tree_flatten(msg_src)
        new_leaves, ef_leaves = [], []
        ef_flat = (jax.tree_util.tree_leaves(state.ef_residual)
                   if state.ef_residual is not None else [None] * len(leaves))
        p_leaves = jax.tree_util.tree_flatten(params)[0]
        lr = step_cfg.lr(state.step)
        nnz_acc = jnp.float32(0.0)
        total = 0
        wire_bytes = 0.0   # per-device uplink ledger (static sizes under jit)
        gather_hbm = 0.0   # peak gather-payload residency (max over exchanges)

        if plan is not None:
            # ---- bucketized uplink: few big collectives -------------------
            # per-leaf compress (seeds/counter_base/budget unchanged — slot
            # payloads are bitwise the per-leaf wire messages), then ONE
            # exchange per bucket; protocol scalars are deduplicated (one
            # n_sel psum, one shared-linf vector pmax for the whole tree)
            n_sel = collectives.scalar_psum(mask.astype(jnp.float32), axes)
            shared_vec = (collectives.worker_shared_linf_many(leaves, axes, mask=mask)
                          if share_linf else None)
            payloads = [None] * len(leaves)
            scales = [None] * len(leaves)
            for b in plan.buckets:
                for s in b.slots:
                    i, g = s.index, leaves[s.index]
                    seed_i = prng.fold_seed(wseed, i)
                    shared = shared_vec[i] if share_linf else None
                    if mode == "decoded":
                        msg = engine.compress_leaf(g, comp, seed_i,
                                                   backend=backend,
                                                   shared_linf=shared)
                        # elastic: the weight premultiplies the decode scale
                        # (w_eff == 1.0 is a bitwise identity; a dropped
                        # worker's slot decodes to exact zeros)
                        sc = msg.scale * w_eff if part is not None else msg.scale
                        dec, nnz = collectives.decoded_message(
                            msg.values, sc, mask,
                            is_ternary=comp.is_ternary)
                        payloads[i] = bucketing.as_rows(dec, plan.fmt, s.rows)
                        nnz_acc += nnz
                    else:
                        msg = engine.compress_leaf_rows(
                            g, comp, seed_i, rows=s.rows, backend=backend,
                            wire=wire, shared_linf=shared)
                        payloads[i] = wire.mask_message(msg.values, mask)
                        nnz_acc += wire.message_nnz(payloads[i])
                        scales[i] = msg.scale
                    total += g.size
            new_leaves = [None] * len(leaves)
            ef_leaves = [None] * len(leaves)
            for b in plan.buckets:
                buf = bucketing.assemble_bucket(
                    [payloads[s.index] for s in b.slots], b, plan.fmt)
                wtots = None
                if mode == "decoded":
                    parts = bucketing.split_bucket(
                        collectives.decoded_exchange_bucket(buf, axes), b)
                    if part is not None:
                        # weights already premultiplied into the psum'd
                        # stream; W (the mean divisor) is one protocol scalar
                        wtots = collectives.scalar_psum(w_eff, axes)
                elif part is not None:
                    # elastic: one weighted exchange per bucket returns
                    # (sum_m w_m payload_m, W) — W is per-slot on the psum
                    # wires (per-coordinate arrays) and one scalar on the
                    # gather wires
                    if mode == "pack8":
                        parts, wtots = wire.exchange_bucket_weighted(
                            buf, b, weight=w_eff,
                            scale=jnp.stack([scales[s.index]
                                             for s in b.slots]))
                    else:
                        parts, wtots = wire.exchange_bucket_weighted(
                            buf, b, weight=w_eff)
                elif mode == "pack8":
                    parts = wire.exchange_bucket(
                        buf, b, scale=jnp.stack([scales[s.index]
                                                 for s in b.slots]))
                else:
                    parts = wire.exchange_bucket(buf, b)
                for j, (s, agg) in enumerate(zip(b.slots, parts)):
                    i = s.index
                    if part is not None:
                        wt = (wtots[j] if isinstance(wtots, (list, tuple))
                              else wtots)
                        if mode == "votes":
                            new_p, new_ef = engine.server_apply(
                                p_leaves[i], agg, comp, lr=lr, ef=ef_flat[i],
                                part_total=wt, q_frac=q_fracs[i],
                                backend=backend)
                        else:
                            new_p, new_ef = engine.server_apply(
                                p_leaves[i], agg, comp, lr=lr, ef=ef_flat[i],
                                n_sel=wt, server="mean",
                                scale=(scales[i] if mode == "scaled_votes"
                                       else None),
                                backend=backend)
                    elif mode == "votes":
                        new_p, new_ef = engine.server_apply(
                            p_leaves[i], agg, comp, lr=lr, ef=ef_flat[i],
                            n_sel=n_sel, quorum=quorum_leaves[i],
                            backend=backend)
                    else:
                        # mean servers: scaled_votes decodes with the ONE
                        # shared scale; pack8/decoded sums arrive dequantized
                        new_p, new_ef = engine.server_apply(
                            p_leaves[i], agg, comp, lr=lr, ef=ef_flat[i],
                            n_sel=n_sel, server="mean",
                            scale=(scales[i] if mode == "scaled_votes" else None),
                            backend=backend)
                    new_leaves[i], ef_leaves[i] = new_p, new_ef
            pay, scal = bucketing.plan_ledger(mode, wire, plan,
                                              share_linf=share_linf)
            wire_bytes = pay + scal
            gather_hbm = bucketing.plan_gather_hbm_bytes(mode, wire, plan)
            return _finish(state, treedef, new_leaves, ef_leaves, loss, lr,
                           nnz_acc, total, mask, wire_bytes, gather_hbm)

        for i, (g, p, ef) in enumerate(zip(leaves, p_leaves, ef_flat)):
            seed_i = prng.fold_seed(wseed, i)
            # ONE ledger definition for both train modes — pinned against the
            # traced collective census by repro.analysis
            wire_bytes += collectives.uplink_ledger(mode, wire, g.size,
                                                    share_linf=share_linf)
            if mode != "decoded":
                gather_hbm = max(gather_hbm, wire.gather_hbm_bytes(g.size))
            shared = None
            if share_linf:
                # TernGrad's magnitude-sharing protocol / linf_share budgets:
                # one f32 pmax over the sampled workers before compressing
                shared = collectives.worker_shared_linf(g, axes, mask=mask)
            if mode != "decoded":
                # wire-native messages (packed uint8 / int8 votes, or int8
                # pack8 levels): one exchange = upload + server sum, then
                # C(.) + SGD fused in the engine. scaled_votes additionally
                # carries ONE shared decode scale (msg.scale) next to the
                # payload; pack8 gathers every worker's scale and dequantizes
                # during the exchange.
                msg = engine.compress_leaf(g, comp, seed_i, backend=backend,
                                           wire=wire, shared_linf=shared)
                votes = wire.mask_message(msg.values, mask)
                nnz_acc += wire.message_nnz(votes)
                n_sel = collectives.scalar_psum(mask.astype(jnp.float32), axes)
                if part is not None:
                    # elastic: weighted exchange returns (sum w_m votes_m, W);
                    # vote servers normalize the deadband to W, mean servers
                    # divide by it
                    if mode == "pack8":
                        wv, wtot = wire.exchange_weighted(
                            votes, g.size, g.shape, weight=w_eff,
                            scale=msg.scale)
                        new_p, new_ef = engine.server_apply(
                            p, wv, comp, lr=lr, ef=ef, n_sel=wtot,
                            server="mean", backend=backend)
                    elif mode == "votes":
                        wv, wtot = wire.exchange_weighted(
                            votes, g.size, g.shape, weight=w_eff)
                        new_p, new_ef = engine.server_apply(
                            p, wv, comp, lr=lr, ef=ef,
                            part_total=wtot, q_frac=q_fracs[i],
                            backend=backend)
                    else:
                        wv, wtot = wire.exchange_weighted(
                            votes, g.size, g.shape, weight=w_eff)
                        new_p, new_ef = engine.server_apply(
                            p, wv, comp, lr=lr, ef=ef, n_sel=wtot,
                            server="mean", scale=msg.scale, backend=backend)
                elif mode == "pack8":
                    dec_sum = wire.exchange(votes, g.size, g.shape,
                                            scale=msg.scale)
                    new_p, new_ef = engine.server_apply(
                        p, dec_sum, comp, lr=lr, ef=ef, n_sel=n_sel,
                        server="mean", backend=backend)
                elif mode == "votes":
                    vote_sum = wire.exchange(votes, g.size, g.shape)
                    new_p, new_ef = engine.server_apply(
                        p, vote_sum, comp, lr=lr, ef=ef, n_sel=n_sel,
                        quorum=quorum_leaves[i], backend=backend)
                else:
                    vote_sum = wire.exchange(votes, g.size, g.shape)
                    new_p, new_ef = engine.server_apply(
                        p, vote_sum, comp, lr=lr, ef=ef, n_sel=n_sel,
                        server="mean", scale=msg.scale, backend=backend)
            else:
                msg = engine.compress_leaf(g, comp, seed_i, backend=backend,
                                           shared_linf=shared)
                # decoded-float wire: per-worker-scale ternary baselines
                # (qsgd_1bit/scaled_sign under a mean server) and the float
                # formats ship decode(compress(g)) — fp32 collective bytes,
                # honestly the cost this family pays (identity's message IS
                # g, so D-SGD is bit-identical to raw psum)
                if part is not None:
                    # elastic decoded wire: the weight premultiplies the
                    # decode scale (w_eff == 1.0 is a bitwise identity, a
                    # dropped worker decodes to exact zeros) and the mean
                    # divisor becomes the realized participation W
                    vote_sum, nnz = collectives.decoded_exchange(
                        msg.values, msg.scale * w_eff, mask, axes,
                        is_ternary=comp.is_ternary)
                    n_or_w = collectives.scalar_psum(w_eff, axes)
                else:
                    vote_sum, nnz = collectives.decoded_exchange(
                        msg.values, msg.scale, mask, axes,
                        is_ternary=comp.is_ternary)
                    n_or_w = collectives.scalar_psum(
                        mask.astype(jnp.float32), axes)
                nnz_acc += nnz
                new_p, new_ef = engine.server_apply(
                    p, vote_sum, comp, lr=lr, ef=ef, n_sel=n_or_w,
                    server="mean", backend=backend)
            total += g.size
            new_leaves.append(new_p)
            ef_leaves.append(new_ef)

        return _finish(state, treedef, new_leaves, ef_leaves, loss, lr,
                       nnz_acc, total, mask, wire_bytes, gather_hbm)

    state_spec = P()   # replicated w.r.t. the manual worker axes
    batch_axis = 1 if comp.local_steps > 1 else 0
    def batch_spec(x=None):
        spec = [None] * 4
        spec[batch_axis] = axes if len(axes) > 1 else axes[0]
        return P(*spec[:batch_axis + 1])

    wrapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(state_spec, batch_spec()),
        out_specs=(state_spec, state_spec),
        axis_names=set(axes),
        check_vma=False,
    )
    if step_cfg.donate:
        return jax.jit(wrapped, donate_argnums=(0,))
    return jax.jit(wrapped)
