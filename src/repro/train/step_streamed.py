"""`streamed`-mode distributed train step (DESIGN.md §3 mode 2) — for models
whose local gradient cannot exist in HBM all at once (qwen2-vl-72b, jamba-398b,
llama4-scout).

ALL parameters (block stacks AND embed/head) are FSDP-sharded along 'data'
(and over 'model' via GSPMD). One round:

  forward:  lax.scan over superblocks; each iteration all-gathers ONLY that
            block's param shards (bf16) and emits the block input — O(1 block)
            of gathered params live at any time.
  head:     gather embed/head, loss + vjp for the outer params.
  backward: reverse lax.scan; per superblock: re-gather params, recompute under
            jax.vjp (remat), compress the *local, unreduced* block gradient,
            exchange the wire-native message over the worker axes (any
            `vote_impl`: psum | hier | allgather_packed, and any wire mode:
            votes | scaled_votes | pack8 | decoded), then do ALL server math
            (sign / scaled-sign EF / scaled mean, SGD) on this rank's shard
            only — the full fp32 update tensor never exists. Gradients die
            block-by-block.

Counter streams are laid out identically to simple mode (leaf salt = canonical
tree position, counter = offset within the stacked leaf) — the cross-mode
equivalence test relies on this.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engine, prng
from repro.core.algorithm import CompressionConfig
from repro.dist import bucketing, collectives, compat
from repro.dist.sharding import ACT_RULES_TRAIN
from repro.models.common import axis_rules, rms_norm
from repro.train import sampling
from repro.train.state import LrSchedule, TrainState

REPLICATED = -1  # sentinel: leaf not FSDP-sharded (None is not a pytree leaf)


@dataclasses.dataclass(frozen=True)
class StreamedStepConfig:
    compression: CompressionConfig
    lr: LrSchedule
    worker_axes: Sequence[str] = ("data",)
    fsdp_axis: str = "data"
    vote_impl: str = "psum"        # psum | hier | allgather_packed
    quorum: Any = 1                # server deadband: |votes| < quorum -> no step;
                                   # int (broadcast) or a pytree prefix of the
                                   # param tree with per-leaf ints
    donate: bool = True
    backend: Optional[str] = None  # kernel backend; None -> $REPRO_KERNEL_BACKEND
    bucketed: bool = False         # bucketized uplink + double-buffered
                                   # backward scan (exchange of superblock i
                                   # overlaps vjp/compress of superblock i-1)
    bucket_bytes: Optional[int] = None  # payload cap per bucket (None: one
                                        # bucket per superblock / outer group)
    golomb_p: Optional[float] = None    # plan-time nnz fraction sizing the
                                        # golomb wire's static capacity (None:
                                        # a target_sparsity budget's target)
    ring_chunk_rows: Optional[int] = None  # ring-pipelined gather: payload
                                           # rows per ppermute chunk (gather
                                           # wires only; None: monolithic
                                           # all_gather)
    participation: Optional[collectives.ParticipationSpec] = None
                                           # elastic participation: per-worker
                                           # vote weights + quorum-fraction
                                           # deadband + report dropout; None =
                                           # the legacy fixed-quorum path


# ---------------------------------------------------------------------------
# FSDP sharding layout
# ---------------------------------------------------------------------------

def fsdp_shard_axis(shape, n_shards: int, min_axis: int = 0, avoid=()) -> int:
    """Largest axis (>= min_axis, not in avoid) divisible by n_shards;
    REPLICATED if none. ``avoid`` holds axes already claimed by TP ('model')."""
    best, best_size = REPLICATED, 0
    for ax in range(min_axis, len(shape)):
        if ax in avoid:
            continue
        if shape[ax] % n_shards == 0 and shape[ax] >= n_shards and shape[ax] > best_size:
            best, best_size = ax, shape[ax]
    return best


def _spec_of(ax: int, axis_name: str) -> P:
    if ax == REPLICATED:
        return P()
    parts = [None] * (ax + 1)
    parts[ax] = axis_name
    return P(*parts)


def _is_logical(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def build_fsdp_layout(shapes_tree, n_shards: int, axis_name: str, min_axis: int = 1,
                      logical_tree=None):
    """(PartitionSpec tree, shard-axis int tree). min_axis=1 skips the stacked R
    axis for block leaves; outer leaves use min_axis=0. When ``logical_tree`` is
    given, axes that TP would claim (DESIGN: vocab/heads/ff/expert -> model) are
    excluded so the data and model shardings never collide on one dim."""
    from repro.dist.sharding import TP_RULES

    leaves, treedef = jax.tree_util.tree_flatten(shapes_tree)
    if logical_tree is None:
        lg_leaves = [()] * len(leaves)
    else:
        lg_leaves = treedef.flatten_up_to(logical_tree)
    ax_leaves = []
    for s, lg in zip(leaves, lg_leaves):
        avoid = tuple(i for i, name in enumerate(lg)
                      if name is not None and TP_RULES.get(name) is not None)
        ax_leaves.append(fsdp_shard_axis(s.shape, n_shards, min_axis, avoid))
    axes_tree = jax.tree_util.tree_unflatten(treedef, ax_leaves)
    specs_tree = jax.tree_util.tree_map(lambda a: _spec_of(a, axis_name), axes_tree)
    return specs_tree, axes_tree


def streamed_shardings(model, mesh, fsdp_axis: str = "data"):
    """Single source of truth for streamed-mode parameter placement:
    returns (NamedSharding tree [FSDP+TP merged], shard-axis tree, shard-map
    PartitionSpec tree [manual/FSDP part only])."""
    from jax.sharding import NamedSharding
    from repro.dist.sharding import logical_to_spec, sanitize_spec

    shapes = model.param_shapes()
    logical = model.param_logical_axes()
    n = mesh.shape[fsdp_axis]
    named, manual_specs, axes = {}, {}, {}
    for k in shapes:
        min_axis = 1 if k == "blocks" else 0
        specs_k, axes_k = build_fsdp_layout(shapes[k], n, fsdp_axis,
                                            min_axis=min_axis, logical_tree=logical[k])

        lg_leaves, treedef = jax.tree_util.tree_flatten(logical[k], is_leaf=_is_logical)
        ax_leaves = treedef.flatten_up_to(axes_k)
        sh_leaves = treedef.flatten_up_to(shapes[k])
        merged = []
        for lg, ax, sds in zip(lg_leaves, ax_leaves, sh_leaves):
            # TP part first, sanitized to the actual dims (placement must divide)
            spec = list(sanitize_spec(logical_to_spec(lg), sds.shape, mesh))
            while len(spec) <= max(ax, 0):
                spec.append(None)
            if ax != REPLICATED:
                assert spec[ax] is None, (k, lg, ax)
                spec[ax] = fsdp_axis
            merged.append(NamedSharding(mesh, P(*spec)))
        named[k] = jax.tree_util.tree_unflatten(treedef, merged)
        manual_specs[k] = specs_k
        axes[k] = axes_k
    return named, axes, manual_specs


# ---------------------------------------------------------------------------
# Step builder
# ---------------------------------------------------------------------------

def build_streamed_train_step(model, step_cfg: StreamedStepConfig, mesh) -> Callable:
    cfg = model.cfg
    assert not cfg.tail_pattern, "streamed mode does not support tail blocks"
    assert not cfg.tie_embeddings, "streamed mode expects untied embeddings"
    comp = step_cfg.compression
    assert comp.local_steps == 1, "streamed mode implements Alg. 1 exchange (tau=1)"
    backend = engine.resolve_backend(step_cfg.backend)
    axes = tuple(step_cfg.worker_axes)
    # wire-mode negotiation (CompressorSpec lookup) resolved before tracing;
    # every mode — votes, scaled_votes, pack8, decoded — runs streamed
    mode = engine.wire_mode(comp, vote_impl=step_cfg.vote_impl)
    # built (and validated — hier demands two worker axes, sizes >= 1) at
    # step-build time, in the compressor's declared payload format; golomb
    # specs additionally resolve the plan-time nnz fraction that sizes the
    # entropy-coded wire's static capacity
    wire_fmt = engine.wire_payload_format(comp, mode,
                                          vote_impl=step_cfg.vote_impl)
    part = step_cfg.participation
    if part is not None:
        # elastic participation: loud build-time gates — the EF server cannot
        # be participation-normalized, and the weights must cover the mesh
        engine.check_participation_server(comp.server, comp.compressor)
    wire = collectives.make_vote_wire(
        step_cfg.vote_impl, axes, mesh, backend=backend,
        wire_format=wire_fmt,
        golomb_p=(engine.resolve_golomb_p(comp, step_cfg.golomb_p)
                  if wire_fmt == "golomb" else None),
        ring_chunk_rows=engine.resolve_ring_chunk_rows(
            step_cfg.ring_chunk_rows, step_cfg.vote_impl),
        participation=part)
    share_linf = engine.needs_shared_linf(comp)
    if mode != "votes" and engine.needs_server_ef(comp.server):
        raise ValueError(
            f"server {comp.server!r} keeps an error-feedback residual that "
            f"only updates on the integer vote wire, but compressor "
            f"{comp.compressor!r} rides the {mode!r} wire — the run would "
            f"silently aggregate by mean while carrying a dead full-model EF "
            f"residual; use a ternary vote-wire compressor or a plain 'mean' "
            f"server")
    fsdp_ax = step_cfg.fsdp_axis
    n_shards = mesh.shape[fsdp_ax]

    shapes = model.param_shapes()
    # per-leaf quorum, validated at build time; indexed by canonical leaf
    # position (same flat order as idx_tree below)
    quorum_flat = jax.tree_util.tree_leaves(
        engine.broadcast_quorum(step_cfg.quorum, shapes))
    # per-leaf quorum as a FRACTION of realized participation (build-time:
    # bad quorums and q_frac out of (0,1] fail before tracing)
    q_frac_flat = ([part.resolve_q_frac(q, wire.n_workers) for q in quorum_flat]
                   if part is not None else None)
    if mode != "votes" and any(q != 1 for q in quorum_flat):
        raise ValueError(
            f"quorum={step_cfg.quorum!r} is a vote-server deadband, but "
            f"compressor {comp.compressor!r} with server {comp.server!r} "
            f"rides the {mode!r} wire where it would be silently ignored; "
            f"use a vote server ({engine.VOTE_SERVERS}) or quorum=1")
    _, axes_all, manual_specs = streamed_shardings(model, mesh, fsdp_ax)
    block_specs, block_axes = manual_specs["blocks"], axes_all["blocks"]
    outer_keys = [k for k in shapes if k != "blocks"]
    outer_specs = {k: manual_specs[k] for k in outer_keys}
    outer_axes = {k: axes_all[k] for k in outer_keys}

    ax_flat = jax.tree_util.tree_leaves(block_axes)
    flat_shapes, shapes_treedef = jax.tree_util.tree_flatten(shapes)
    idx_tree = jax.tree_util.tree_unflatten(shapes_treedef, list(range(len(flat_shapes))))
    blocks_idx_flat = jax.tree_util.tree_leaves(idx_tree["blocks"])
    total_coords = sum(int(jnp.prod(jnp.array(s.shape))) for s in flat_shapes)
    # per-round per-device uplink ledger: block leaves exchange once per layer
    # at their per-layer size (padding is per-exchange, so it multiplies out),
    # outer leaves once at full size
    def exchange_bytes(n: int) -> float:
        # ONE ledger definition for both train modes (collectives.uplink_ledger)
        # — pinned against the traced collective census by repro.analysis
        return collectives.uplink_ledger(mode, wire, n, share_linf=share_linf)

    wire_ledger = sum(
        cfg.n_repeats * exchange_bytes(math.prod(s.shape[1:]))
        for s in jax.tree_util.tree_leaves(shapes["blocks"]))
    wire_ledger += sum(exchange_bytes(math.prod(s.shape))
                       for k in outer_keys
                       for s in jax.tree_util.tree_leaves(shapes[k]))
    # peak gather-payload residency (max over exchanges; 0.0 for psum wires
    # and the decoded-float path, which never materialize a gathered tensor)
    gather_hbm = 0.0
    if mode != "decoded":
        gather_hbm = max(
            [wire.gather_hbm_bytes(math.prod(s.shape[1:]))
             for s in jax.tree_util.tree_leaves(shapes["blocks"])]
            + [wire.gather_hbm_bytes(math.prod(s.shape))
               for k in outer_keys
               for s in jax.tree_util.tree_leaves(shapes[k])],
            default=0.0)

    # static bucket layouts (bucketed uplink): one plan for a superblock
    # layer's leaves (applied every scan iteration), one for the outer leaves
    block_plan = outer_plan = None
    blocks_treedef = jax.tree_util.tree_structure(shapes["blocks"])
    if step_cfg.bucketed:
        fmt = bucketing.wire_bucket_format(mode, wire)
        # golomb slots are CAPACITY rows — a pure (n, p) function owned by
        # the wire, not a coordinate-count row formula
        rows_fn = wire.payload_rows if fmt == "golomb" else None
        block_plan = bucketing.build_bucket_plan(
            [jax.ShapeDtypeStruct(s.shape[1:], s.dtype)
             for s in jax.tree_util.tree_leaves(shapes["blocks"])],
            fmt, bucket_bytes=step_cfg.bucket_bytes, rows_fn=rows_fn)
        outer_plan = bucketing.build_bucket_plan(
            [shapes[k] for k in outer_keys], fmt,
            bucket_bytes=step_cfg.bucket_bytes, rows_fn=rows_fn)
        # the double-buffered scan primes with one zero bucket and drains the
        # last pending bucket after the scan -> n_repeats + 1 block-bucket
        # exchanges per step; the shared-linf vector pmax runs at compress
        # time, once per REAL layer (n_repeats)
        pay, scal = bucketing.streamed_plan_ledger(
            mode, wire, block_plan, outer_plan, cfg.n_repeats,
            share_linf=share_linf)
        wire_ledger = pay + scal
        gather_hbm = max(
            bucketing.plan_gather_hbm_bytes(mode, wire, block_plan),
            bucketing.plan_gather_hbm_bytes(mode, wire, outer_plan))

    def _gather(leaf, ax):
        return leaf if ax == REPLICATED else collectives.fsdp_all_gather(
            leaf, fsdp_ax, ax, tiled=True)

    def _slice(full, ax, shard_size):
        if ax == REPLICATED:
            return full
        start = jax.lax.axis_index(fsdp_ax) * shard_size
        return jax.lax.dynamic_slice_in_dim(full, start, shard_size, axis=ax)

    def leaf_update(p_shard, g_full, *, seed, counter_base, ef_shard, mask, lr,
                    shard_ax: int, leaf_size: int, quorum: int,
                    w_eff=None, q_frac=None):
        """compress(full) -> wire exchange(full) -> server math + SGD on the SHARD.

        The fp32 update/EF tensors only ever exist at shard size; the
        full-size artifacts are the bf16/f32 gradient (transient, from vjp)
        and the exchanged message (1 B/coord int8 votes for the psum wires,
        0.25 B/coord packed ternary or 1 B/coord pack8 levels for the gather
        wires, 4 B/coord fp32 for the decoded psum). Under elastic
        participation (``w_eff`` set) the exchange is the weighted one and
        the realized-participation total W replaces the fixed quorum /
        selected-count divisor; a per-coordinate W (psum wires) is sliced to
        the shard alongside the weighted vote."""
        shared = (collectives.worker_shared_linf(g_full, axes, mask=mask)
                  if share_linf else None)
        n_sel = collectives.scalar_psum(mask.astype(jnp.float32), axes)
        wtot = None
        if mode == "decoded":
            # per-worker decode scales / float payloads: decode locally, psum
            # fp32 — the wire object is bypassed, exactly like simple mode
            # (decoded_exchange is the one shared definition)
            msg = engine.compress_leaf(g_full, comp, seed, counter_base,
                                       backend=backend, shared_linf=shared)
            if part is not None:
                # the weight premultiplies the decode scale (w_eff == 1.0 is
                # a bitwise identity; a dropped worker decodes to exact
                # zeros) and the mean divisor becomes W
                agg, nnz = collectives.decoded_exchange(
                    msg.values, msg.scale * w_eff, mask, axes,
                    is_ternary=comp.is_ternary)
                wtot = collectives.scalar_psum(w_eff, axes)
            else:
                agg, nnz = collectives.decoded_exchange(
                    msg.values, msg.scale, mask, axes,
                    is_ternary=comp.is_ternary)
        else:
            msg = engine.compress_leaf(g_full, comp, seed, counter_base,
                                       backend=backend, wire=wire,
                                       shared_linf=shared)
            votes = wire.mask_message(msg.values, mask)
            nnz = wire.message_nnz(votes)
            if part is not None:
                agg, wtot = wire.exchange_weighted(
                    votes, g_full.size, g_full.shape, weight=w_eff,
                    scale=(msg.scale if mode == "pack8" else None))
            else:
                agg = wire.exchange(votes, g_full.size, g_full.shape,
                                    scale=(msg.scale if mode == "pack8" else None))
        shard_size = p_shard.shape[shard_ax] if shard_ax != REPLICATED else None
        vs = _slice(agg, shard_ax, shard_size)
        if part is not None:
            # W rides per-coordinate on the psum wires — slice it like the
            # weighted vote; gather wires return one scalar
            wt = wtot if jnp.ndim(wtot) == 0 else _slice(wtot, shard_ax,
                                                         shard_size)
            if mode == "votes":
                new_shard, new_ef = engine.server_apply(
                    p_shard, vs, comp, lr=lr, ef=ef_shard,
                    part_total=wt, q_frac=q_frac, backend=backend)
            else:
                new_shard, new_ef = engine.server_apply(
                    p_shard, vs, comp, lr=lr, ef=ef_shard, n_sel=wt,
                    server="mean",
                    scale=(msg.scale if mode == "scaled_votes" else None),
                    backend=backend)
        elif mode == "votes":
            # shards partition the leaf, so the scaled-sign L1 reduces across them
            l1_reduce = ((lambda part: collectives.scalar_psum(part, fsdp_ax))
                         if shard_ax != REPLICATED else None)
            new_shard, new_ef = engine.server_apply(
                p_shard, vs, comp, lr=lr, ef=ef_shard, n_sel=n_sel,
                leaf_size=leaf_size, l1_reduce=l1_reduce, quorum=quorum,
                backend=backend)
        else:
            # mean-server wires: scaled_votes carries the ONE shared decode
            # scale outside the sum; pack8/decoded sums arrive pre-dequantized
            new_shard, new_ef = engine.server_apply(
                p_shard, vs, comp, lr=lr, ef=ef_shard, n_sel=n_sel,
                server="mean",
                scale=(msg.scale if mode == "scaled_votes" else None),
                backend=backend)
        return new_shard, new_ef, nnz

    # ------------------------------------------------------------------
    # bucketed uplink: group-level compress / exchange+apply
    # ------------------------------------------------------------------
    # static per-leaf metadata in group order (blocks: per-layer flat leaves,
    # outer: outer_keys order) — quorum/shard-axis lookups resolved at build
    block_shard_axes = [a - 1 if a != REPLICATED else REPLICATED for a in ax_flat]
    block_quorums = [quorum_flat[i] for i in blocks_idx_flat]
    outer_shard_axes = [axes_all[k] for k in outer_keys]
    outer_quorums = [quorum_flat[idx_tree[k]] for k in outer_keys]
    block_q_fracs = ([q_frac_flat[i] for i in blocks_idx_flat]
                     if part is not None else None)
    outer_q_fracs = ([q_frac_flat[idx_tree[k]] for k in outer_keys]
                     if part is not None else None)

    def _group_compress(plan_, g_leaves, seeds, bases, mask, w_eff=None):
        """Per-leaf compress into bucket slices (seeds/counter_base unchanged
        vs the per-leaf path — slot payloads are bitwise the per-leaf wire
        messages), assembled into the plan's wire buffers. Returns
        (bufs, svecs, nnz): one payload buffer and one (n_slots,) f32
        decode-scale vector per bucket (1.0 where the mode carries none).
        Under elastic participation the decoded mode's decode scale is
        premultiplied by ``w_eff`` (w_eff == 1.0 is a bitwise identity)."""
        slots = {s.index: s for b in plan_.buckets for s in b.slots}
        shared_vec = (collectives.worker_shared_linf_many(g_leaves, axes, mask=mask)
                      if share_linf else None)
        payloads = [None] * len(g_leaves)
        scales = [jnp.float32(1.0)] * len(g_leaves)
        nnz = jnp.float32(0.0)
        for j, g in enumerate(g_leaves):
            shared = shared_vec[j] if share_linf else None
            if mode == "decoded":
                msg = engine.compress_leaf(g, comp, seeds[j], bases[j],
                                           backend=backend, shared_linf=shared)
                sc = msg.scale * w_eff if part is not None else msg.scale
                dec, z = collectives.decoded_message(
                    msg.values, sc, mask, is_ternary=comp.is_ternary)
                payloads[j] = bucketing.as_rows(dec, plan_.fmt, slots[j].rows)
                nnz += z
            else:
                msg = engine.compress_leaf_rows(
                    g, comp, seeds[j], bases[j], rows=slots[j].rows,
                    backend=backend, wire=wire, shared_linf=shared)
                payloads[j] = wire.mask_message(msg.values, mask)
                nnz += wire.message_nnz(payloads[j])
                scales[j] = msg.scale
        bufs = tuple(bucketing.assemble_bucket(
            [payloads[s.index] for s in b.slots], b, plan_.fmt)
            for b in plan_.buckets)
        svecs = tuple(jnp.stack([scales[s.index] for s in b.slots])
                      for b in plan_.buckets)
        return bufs, svecs, nnz

    def _group_apply(plan_, bufs, svecs, ps_leaves, ef_leaves, shard_axes,
                     quorums, *, n_sel, lr, w_eff=None, w_psum=None,
                     q_fracs=None):
        """ONE exchange per bucket, then the per-leaf server math + SGD on
        this rank's shards — identical server semantics (per-leaf quorum, EF
        residuals, shared-scale decode, l1_reduce) at bucket granularity.
        Under elastic participation (``w_eff`` set) the exchange is the
        weighted one: W is per-slot per-coordinate on the psum wires (sliced
        to the shard like the vote) and one scalar on the gather wires; the
        decoded mode's W is the caller's precomputed ``w_psum``."""
        new_ps = [None] * len(ps_leaves)
        new_efs = [None] * len(ps_leaves)
        for b, buf, sv in zip(plan_.buckets, bufs, svecs):
            wtots = None
            if mode == "decoded":
                parts = bucketing.split_bucket(
                    collectives.decoded_exchange_bucket(buf, axes), b)
                wtots = w_psum
            elif part is not None:
                if mode == "pack8":
                    parts, wtots = wire.exchange_bucket_weighted(
                        buf, b, weight=w_eff, scale=sv)
                else:
                    parts, wtots = wire.exchange_bucket_weighted(
                        buf, b, weight=w_eff)
            elif mode == "pack8":
                parts = wire.exchange_bucket(buf, b, scale=sv)
            else:
                parts = wire.exchange_bucket(buf, b)
            for pos, (s, agg) in enumerate(zip(b.slots, parts)):
                j = s.index
                sh_ax = shard_axes[j]
                shard_size = (ps_leaves[j].shape[sh_ax]
                              if sh_ax != REPLICATED else None)
                vs = _slice(agg, sh_ax, shard_size)
                if part is not None:
                    wt = (wtots[pos] if isinstance(wtots, (list, tuple))
                          else wtots)
                    wt = wt if jnp.ndim(wt) == 0 else _slice(wt, sh_ax,
                                                             shard_size)
                    if mode == "votes":
                        new_ps[j], new_efs[j] = engine.server_apply(
                            ps_leaves[j], vs, comp, lr=lr, ef=ef_leaves[j],
                            part_total=wt, q_frac=q_fracs[j],
                            backend=backend)
                    else:
                        new_ps[j], new_efs[j] = engine.server_apply(
                            ps_leaves[j], vs, comp, lr=lr, ef=ef_leaves[j],
                            n_sel=wt, server="mean",
                            scale=(sv[pos] if mode == "scaled_votes" else None),
                            backend=backend)
                elif mode == "votes":
                    l1_reduce = ((lambda part: collectives.scalar_psum(part, fsdp_ax))
                                 if sh_ax != REPLICATED else None)
                    new_ps[j], new_efs[j] = engine.server_apply(
                        ps_leaves[j], vs, comp, lr=lr, ef=ef_leaves[j],
                        n_sel=n_sel, leaf_size=s.size, l1_reduce=l1_reduce,
                        quorum=quorums[j], backend=backend)
                else:
                    new_ps[j], new_efs[j] = engine.server_apply(
                        ps_leaves[j], vs, comp, lr=lr, ef=ef_leaves[j],
                        n_sel=n_sel, server="mean",
                        scale=(sv[pos] if mode == "scaled_votes" else None),
                        backend=backend)
        return new_ps, new_efs

    def body(state: TrainState, batch):
        with axis_rules(ACT_RULES_TRAIN, mesh):
            return _body_inner(state, batch)

    def _body_inner(state: TrainState, batch):
        params = state.params
        widx = collectives.worker_index(axes)
        n_workers = collectives.worker_count(axes)
        rseed = sampling.round_seed(state.seed, state.step)
        wseed = prng.fold_seed(rseed, 0x5EED) + widx.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        mask = sampling.participation_mask(rseed, state.step, widx, comp.worker_sample_fraction)
        w_eff = w_psum = None
        if part is not None:
            # elastic: the round's effective reporting set is the sampled set
            # minus chaos dropouts; w_eff = static weight x report bit is the
            # weight that rides the wire (exact 0.0 for a silent worker)
            mask = mask & sampling.report_mask(rseed, state.step, widx,
                                               part.dropout)
            w_eff = (part.weight_of(widx, n_workers)
                     * mask.astype(jnp.float32))
            w_psum = collectives.scalar_psum(w_eff, axes)
        lr = step_cfg.lr(state.step)
        positions = batch["positions"]
        positions3 = batch.get("positions3")
        has_ef = state.ef_residual is not None

        def gather_block(block_slice):
            leaves, treedef = jax.tree_util.tree_flatten(block_slice)
            out = [_gather(l, (a - 1 if a != REPLICATED else a))
                   for l, a in zip(leaves, ax_flat)]
            return jax.tree_util.tree_unflatten(treedef, out)

        # ---------------- forward ----------------
        outer_full = {k: _gather(params[k], outer_axes[k]) for k in outer_keys}
        h0 = model.embed_stage(outer_full if cfg.input_kind == "tokens" else params, batch)

        def fwd_body(h, block_shard):
            full = gather_block(block_shard)
            return model.superblock_apply(full, h, positions, positions3), h

        h_final, h_inputs = jax.lax.scan(fwd_body, h0, params["blocks"])

        # ---------------- head / loss ----------------
        def head_fn(outer_p, h):
            hn = rms_norm(h, outer_p["final_norm"], cfg.norm_eps)
            return model.head_loss(outer_p, hn, batch["labels"])

        loss, head_vjp = jax.vjp(head_fn, outer_full, h_final)
        g_outer, g_h = head_vjp(jnp.float32(1.0))

        # ---------------- backward over superblocks ----------------
        if block_plan is not None:
            # bucketed + double-buffered: iteration for superblock l first
            # applies the PENDING buckets (superblock l+1's compressed
            # gradient, carried from the previous iteration), then runs this
            # block's vjp + compress. The pending exchange has no data
            # dependency on the vjp, so the collective flies while the
            # recompute/compress math runs. A zero bucket primes the pipe
            # (first iteration, results dropped) and the last pending bucket
            # drains after the scan -> n_repeats + 1 exchanges per bucket.
            n_sel_b = collectives.scalar_psum(mask.astype(jnp.float32), axes)
            seeds_b = [prng.fold_seed(wseed, i) for i in blocks_idx_flat]
            block_leaves = jax.tree_util.tree_leaves(params["blocks"])
            ps0 = tuple(jnp.zeros(l.shape[1:], l.dtype) for l in block_leaves)
            if has_ef:
                ef0 = tuple(jnp.zeros(l.shape[1:], l.dtype)
                            for l in jax.tree_util.tree_leaves(state.ef_residual["blocks"]))
            else:
                ef0 = tuple(jnp.float32(0.0) for _ in block_leaves)
            bufs0 = tuple(jnp.zeros((b.rows, bucketing.ROW_WIDTH[block_plan.fmt]),
                                    bucketing.ROW_DTYPE[block_plan.fmt])
                          for b in block_plan.buckets)
            svecs0 = tuple(jnp.ones((len(b.slots),), jnp.float32)
                           for b in block_plan.buckets)

            def bwd_body_b(carry, xs):
                g_h, nnz_acc, pbufs, psvecs, pps, pefs = carry
                if has_ef:
                    block_shard, h_in, layer, ef_slice = xs
                else:
                    block_shard, h_in, layer = xs
                # drain the pending (upper) superblock FIRST — its exchange
                # overlaps this block's recompute below
                new_shards, new_efs = _group_apply(
                    block_plan, pbufs, psvecs, list(pps), list(pefs),
                    block_shard_axes, block_quorums, n_sel=n_sel_b, lr=lr,
                    w_eff=w_eff, w_psum=w_psum, q_fracs=block_q_fracs)
                full = gather_block(block_shard)

                def fwd(bp, h):
                    return model.superblock_apply(bp, h, positions, positions3)

                _, vjp = jax.vjp(fwd, full, h_in)
                g_block, g_h_prev = vjp(g_h)
                g_leaves, g_def = jax.tree_util.tree_flatten(g_block)
                ps_leaves = g_def.flatten_up_to(block_shard)
                ef_leaves = (g_def.flatten_up_to(ef_slice) if has_ef
                             else [jnp.float32(0.0)] * len(g_leaves))
                bases = [layer.astype(jnp.uint32) * jnp.uint32(g.size)
                         for g in g_leaves]
                bufs, svecs, nnz = _group_compress(
                    block_plan, g_leaves, seeds_b, bases, mask, w_eff=w_eff)
                outs = (jax.tree_util.tree_unflatten(g_def, new_shards),)
                if has_ef:
                    outs = outs + (jax.tree_util.tree_unflatten(g_def, new_efs),)
                carry = (g_h_prev, nnz_acc + nnz, bufs, svecs,
                         tuple(ps_leaves), tuple(ef_leaves))
                return carry, outs

            xs = (params["blocks"], h_inputs, jnp.arange(cfg.n_repeats))
            if has_ef:
                xs = xs + (state.ef_residual["blocks"],)
            carry0 = (g_h, jnp.float32(0.0), bufs0, svecs0, ps0, ef0)
            (g_h0, nnz_acc, pbufs, psvecs, pps, pefs), ys = jax.lax.scan(
                bwd_body_b, carry0, xs, reverse=True)
            # drain: the final pending buckets hold superblock 0's update.
            # ys[l] holds superblock l+1's (iteration l applied the PENDING
            # layer); ys[n_repeats-1] is the priming dummy — dropped.
            fin_shards, fin_efs = _group_apply(
                block_plan, pbufs, psvecs, list(pps), list(pefs),
                block_shard_axes, block_quorums, n_sel=n_sel_b, lr=lr,
                w_eff=w_eff, w_psum=w_psum, q_fracs=block_q_fracs)

            def _shift(stacked, first):
                return jnp.concatenate([first[None], stacked[:-1]], axis=0)

            new_blocks = jax.tree_util.tree_map(
                _shift, ys[0],
                jax.tree_util.tree_unflatten(blocks_treedef, fin_shards))
            new_ef_blocks = (jax.tree_util.tree_map(
                _shift, ys[1],
                jax.tree_util.tree_unflatten(blocks_treedef, fin_efs))
                if has_ef else None)

            # ---- embed backward + bucketed outer group ----
            g_embed = None
            if cfg.input_kind == "tokens":
                def embed_fn(emb):
                    return model.embed_stage({"embed": emb}, batch)
                _, embed_vjp = jax.vjp(embed_fn, outer_full["embed"])
                (g_embed,) = embed_vjp(g_h0)

            g_outer_leaves = []
            for k in outer_keys:
                g_k = g_outer[k]
                if k == "embed" and g_embed is not None:
                    g_k = g_k + g_embed
                g_outer_leaves.append(g_k)
            seeds_o = [prng.fold_seed(wseed, idx_tree[k]) for k in outer_keys]
            bases_o = [jnp.uint32(0)] * len(outer_keys)
            o_bufs, o_svecs, o_nnz = _group_compress(
                outer_plan, g_outer_leaves, seeds_o, bases_o, mask,
                w_eff=w_eff)
            nnz_acc = nnz_acc + o_nnz
            o_efs = ([state.ef_residual[k] for k in outer_keys] if has_ef
                     else [jnp.float32(0.0)] * len(outer_keys))
            o_new, o_new_efs = _group_apply(
                outer_plan, o_bufs, o_svecs, [params[k] for k in outer_keys],
                o_efs, outer_shard_axes, outer_quorums, n_sel=n_sel_b, lr=lr,
                w_eff=w_eff, w_psum=w_psum, q_fracs=outer_q_fracs)

            new_params = {"blocks": new_blocks}
            new_ef = {"blocks": new_ef_blocks} if has_ef else None
            for k, np_, ne in zip(outer_keys, o_new, o_new_efs):
                new_params[k] = np_
                if has_ef:
                    new_ef[k] = ne

            loss_mean = collectives.scalar_psum(loss, axes) / n_workers
            nnz_mean = (collectives.scalar_psum(nnz_acc, axes) / n_workers
                        / jnp.float32(total_coords))
            metrics = {"loss": loss_mean, "lr": lr, "nnz_frac": nnz_mean,
                       "participated": n_sel_b,
                       "wire_bytes_per_device": jnp.float32(wire_ledger),
                       "gather_hbm_bytes": jnp.float32(gather_hbm)}
            new_state = TrainState(params=new_params, ef_residual=new_ef,
                                   step=state.step + 1, seed=state.seed)
            return new_state, metrics

        def bwd_body(carry, xs):
            g_h, nnz_acc = carry
            if has_ef:
                block_shard, h_in, layer, ef_slice = xs
            else:
                block_shard, h_in, layer = xs
            full = gather_block(block_shard)

            def fwd(bp, h):
                return model.superblock_apply(bp, h, positions, positions3)

            _, vjp = jax.vjp(fwd, full, h_in)
            g_block, g_h_prev = vjp(g_h)

            g_leaves, g_def = jax.tree_util.tree_flatten(g_block)
            ps_leaves = g_def.flatten_up_to(block_shard)
            ef_leaves = (g_def.flatten_up_to(ef_slice) if has_ef
                         else [jnp.float32(0.0)] * len(g_leaves))

            new_shards, new_efs = [], []
            for g, p_shard, ef, ax, leaf_idx in zip(
                    g_leaves, ps_leaves, ef_leaves, ax_flat, blocks_idx_flat):
                seed_i = prng.fold_seed(wseed, leaf_idx)
                base = layer.astype(jnp.uint32) * jnp.uint32(g.size)
                sh_ax = ax - 1 if ax != REPLICATED else REPLICATED
                new_shard, new_ef, nnz = leaf_update(
                    p_shard, g, seed=seed_i, counter_base=base, ef_shard=ef,
                    mask=mask, lr=lr, shard_ax=sh_ax, leaf_size=g.size,
                    quorum=quorum_flat[leaf_idx], w_eff=w_eff,
                    q_frac=(q_frac_flat[leaf_idx] if part is not None
                            else None))
                nnz_acc = nnz_acc + nnz
                new_shards.append(new_shard)
                new_efs.append(new_ef)
            outs = (jax.tree_util.tree_unflatten(g_def, new_shards),)
            if has_ef:
                outs = outs + (jax.tree_util.tree_unflatten(g_def, new_efs),)
            return (g_h_prev, nnz_acc), outs

        xs = (params["blocks"], h_inputs, jnp.arange(cfg.n_repeats))
        if has_ef:
            xs = xs + (state.ef_residual["blocks"],)
        (g_h0, nnz_acc), ys = jax.lax.scan(bwd_body, (g_h, jnp.float32(0.0)), xs, reverse=True)
        new_blocks = ys[0]
        new_ef_blocks = ys[1] if has_ef else None

        # ---------------- embed backward + outer updates ----------------
        g_embed = None
        if cfg.input_kind == "tokens":
            def embed_fn(emb):
                return model.embed_stage({"embed": emb}, batch)
            _, embed_vjp = jax.vjp(embed_fn, outer_full["embed"])
            (g_embed,) = embed_vjp(g_h0)

        new_params = {"blocks": new_blocks}
        new_ef = {"blocks": new_ef_blocks} if has_ef else None
        for k in outer_keys:
            g_k = g_outer[k]
            if k == "embed" and g_embed is not None:
                g_k = g_k + g_embed
            seed_i = prng.fold_seed(wseed, idx_tree[k])
            ef_k = state.ef_residual[k] if has_ef else jnp.float32(0.0)
            new_shard, new_ef_k, nnz = leaf_update(
                params[k], g_k, seed=seed_i, counter_base=jnp.uint32(0),
                ef_shard=ef_k, mask=mask, lr=lr,
                shard_ax=outer_axes[k], leaf_size=g_k.size,
                quorum=quorum_flat[idx_tree[k]], w_eff=w_eff,
                q_frac=(q_frac_flat[idx_tree[k]] if part is not None
                        else None))
            nnz_acc = nnz_acc + nnz
            new_params[k] = new_shard
            if has_ef:
                new_ef[k] = new_ef_k

        loss_mean = collectives.scalar_psum(loss, axes) / n_workers
        nnz_mean = collectives.scalar_psum(nnz_acc, axes) / n_workers / jnp.float32(total_coords)
        metrics = {"loss": loss_mean, "lr": lr, "nnz_frac": nnz_mean,
                   "participated": collectives.scalar_psum(mask.astype(jnp.float32), axes),
                   "wire_bytes_per_device": jnp.float32(wire_ledger),
                   "gather_hbm_bytes": jnp.float32(gather_hbm)}
        new_state = TrainState(params=new_params, ef_residual=new_ef,
                               step=state.step + 1, seed=state.seed)
        return new_state, metrics

    # ------------------------------------------------------------------
    # shard_map wiring
    # ------------------------------------------------------------------
    p_specs = {"blocks": block_specs}
    for k in outer_keys:
        p_specs[k] = outer_specs[k]
    state_specs = TrainState(
        params=p_specs,
        ef_residual=(p_specs if engine.needs_server_ef(comp.server) else None),
        step=P(), seed=P())
    batch_spec = P(axes if len(axes) > 1 else axes[0])

    wrapped = compat.shard_map(
        body, mesh=mesh,
        in_specs=(state_specs, batch_spec),
        out_specs=(state_specs, P()),
        axis_names=set(axes) | {fsdp_ax},
        check_vma=False,
    )
    if step_cfg.donate:
        return jax.jit(wrapped, donate_argnums=(0,))
    return jax.jit(wrapped)


def fsdp_param_shardings(model, mesh, fsdp_axis: str = "data"):
    """NamedShardings (FSDP over data + TP over model) to place params for the
    streamed trainer."""
    named, _, _ = streamed_shardings(model, mesh, fsdp_axis)
    return named
