"""Worker sampling = the paper's partial participation = straggler/failure
tolerance on the mesh.

Each round, worker m participates iff hash(round_seed, m) < p_s. On a TPU mesh
every device still executes the program (SPMD), but a masked worker contributes
zeros to the vote and is excluded from the divisor — algorithmically identical
to not being sampled (Cor. 1), which is also exactly what we do when a host is
known-slow or down: the scheduler marks it unsampled instead of stalling the
round. Deterministic given (seed, round), so restarts reproduce the same
participation sequence.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import prng


def participation_mask(seed, round_idx, worker_idx, p_sample: float) -> jnp.ndarray:
    """bool scalar (per worker) — participates this round?"""
    if p_sample >= 1.0:
        return jnp.bool_(True)
    u = prng.uniform01(prng.fold_seed(seed, 0xFA17, 1),
                       jnp.asarray(round_idx, jnp.uint32) * jnp.uint32(1_000_003)
                       + jnp.asarray(worker_idx, jnp.uint32))
    return u < p_sample


def report_mask(seed, round_idx, worker_idx, dropout: float) -> jnp.ndarray:
    """bool scalar (per worker) — does a *sampled* worker's report arrive this
    round? Models elastic-participation chaos (crashes, stragglers past the
    round deadline) independently of the sampling policy: a distinct salt from
    ``participation_mask`` so the two masks are uncorrelated streams. The
    effective reporting set is ``participation_mask & report_mask``;
    ``dropout=0.0`` short-circuits to True (the fully-reporting fleet)."""
    if dropout <= 0.0:
        return jnp.bool_(True)
    u = prng.uniform01(prng.fold_seed(seed, 0xD0A7, 1),
                       jnp.asarray(round_idx, jnp.uint32) * jnp.uint32(1_000_003)
                       + jnp.asarray(worker_idx, jnp.uint32))
    return u >= dropout


def round_seed(base_seed, round_idx) -> jnp.ndarray:
    return prng.fold_seed(base_seed, 0x52D) + jnp.asarray(round_idx, jnp.uint32) * jnp.uint32(0x9E3779B9)
