"""Training driver: step loop + checkpoint/restart + failure handling.

Fault model (DESIGN.md §4):
  * straggler / transient worker failure  -> worker sampling already excludes it
    from the round (algorithm-level, Cor. 1); nothing to do here.
  * process / pod loss                    -> resume from the last atomic
    checkpoint; the data stream is a pure function of (seed, step) so the
    restarted run replays the exact same rounds (bitwise, tested).
  * elastic rescale                       -> restore() re-shards the logical
    checkpoint onto the new mesh; majority-vote state has no per-worker terms,
    so M can change freely between rounds.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    fail_at_step: Optional[int] = None   # failure injection (tests)


def run(
    train_step: Callable,
    state: TrainState,
    batch_fn: Callable[[int], dict],
    cfg: LoopConfig,
    *,
    log: Callable[[str], None] = print,
) -> tuple[TrainState, list[dict]]:
    """Runs the loop; resumes from cfg.ckpt_dir if a checkpoint exists.

    ``batch_fn`` must be a pure function of the step index — that is what makes
    restart/elastic replay exact (the resumed run re-requests step k's batch).
    """
    start = int(state.step)
    if cfg.ckpt_dir:
        # resume from the newest COMPATIBLE checkpoint: a stale dir from
        # another model/config (fingerprint mismatch) must neither crash the
        # run nor shadow this run's own valid checkpoints at lower steps
        steps = ckpt_lib.latest_steps(cfg.ckpt_dir)
        for s in reversed(steps):
            try:
                state, manifest = ckpt_lib.restore(cfg.ckpt_dir, state, step=s)
                start = int(manifest["step"])
                log(f"[loop] resumed from step {start}")
                break
            except ckpt_lib.CheckpointMismatchError as e:
                log(f"[loop] WARNING: skipping checkpoint step_{s:08d} in "
                    f"{cfg.ckpt_dir} — written by a different model/config. {e}")
        else:
            if steps:
                log(f"[loop] WARNING: no compatible checkpoint in "
                    f"{cfg.ckpt_dir}; starting fresh (delete the stale "
                    f"checkpoints to reclaim their rotation slots)")

    history = []
    t0 = time.time()
    for step_idx in range(start, cfg.total_steps):
        if cfg.fail_at_step is not None and step_idx == cfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {step_idx}")
        batch = batch_fn(step_idx)
        state, metrics = train_step(state, batch)
        if step_idx % cfg.log_every == 0 or step_idx == cfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step_idx
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            log(f"[loop] step {step_idx}: " +
                " ".join(f"{k}={v:.5g}" for k, v in m.items() if k != "step"))
        if cfg.ckpt_dir and cfg.ckpt_every and (step_idx + 1) % cfg.ckpt_every == 0:
            ckpt_lib.save(cfg.ckpt_dir, step_idx + 1, state, keep=cfg.keep)
    if cfg.ckpt_dir:
        ckpt_lib.save(cfg.ckpt_dir, cfg.total_steps, state, keep=cfg.keep)
    return state, history


def batches_from_fn(batch_fn: Callable[[int], dict], start_step: int = 0) -> Iterator:
    """Adapter: pure (step -> batch) function to an iterator that replays
    deterministically after restarts (the iterator tracks its own cursor)."""
    step = start_step
    while True:
        yield batch_fn(step)
        step += 1
