"""Training state + schedules."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import needs_server_ef


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    ef_residual: Any          # pytree of f32 residuals (or None) — server EF
    step: jnp.ndarray         # int32 round counter
    seed: jnp.ndarray         # uint32 base seed


def init_state(params, *, server: str, seed: int) -> TrainState:
    ef = None
    if needs_server_ef(server):
        ef = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(
        params=params,
        ef_residual=ef,
        step=jnp.int32(0),
        seed=jnp.uint32(seed),
    )


@dataclasses.dataclass(frozen=True)
class LrSchedule:
    base: float = 1e-3
    warmup: int = 0
    decay_steps: Optional[int] = None   # cosine horizon; None = constant
    min_ratio: float = 0.1

    def __call__(self, step):
        lr = jnp.float32(self.base)
        if self.warmup > 0:
            lr = lr * jnp.minimum(1.0, (step + 1) / self.warmup)
        if self.decay_steps:
            t = jnp.clip((step - self.warmup) / max(self.decay_steps - self.warmup, 1), 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
            lr = lr * (self.min_ratio + (1.0 - self.min_ratio) * cos)
        return lr
