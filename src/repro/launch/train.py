"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it drives reduced (smoke) configs end-to-end — the same
code path a TPU deployment uses with the full configs and the production mesh
(the mesh geometry and trainer mode come from the registry; nothing else
changes). Checkpoints/resume/failure-injection are live here.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, trainer_mode
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.synthetic import LMStreamConfig, lm_batch
from repro.dist import collectives, compat
from repro.launch.mesh import make_host_mesh, make_production_mesh, worker_axes_of
from repro.models.model import Model
from repro.train import loop as loop_lib
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step
from repro.train.step_streamed import (StreamedStepConfig, build_streamed_train_step,
                                       fsdp_param_shardings)


def build_everything(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh(args.host_data, args.host_model)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    wa = worker_axes_of(mesh)
    comp = CompressionConfig(
        compressor=args.compressor,
        budget=BudgetConfig(kind=args.budget_kind, value=args.budget),
        server=args.server,
        local_steps=args.tau,
        local_budget=args.local_budget,
        worker_sample_fraction=args.participation,
    )
    lr = LrSchedule(base=args.lr, warmup=args.warmup)
    # --ring engages the ring-pipelined gather on the packed uplink wires;
    # None keeps the monolithic all_gather
    ring_rows = ((args.ring_chunk_rows or collectives.DEFAULT_RING_CHUNK_ROWS)
                 if args.ring else None)
    # elastic participation: any of --worker-weights/--quorum-frac/--dropout
    # builds a ParticipationSpec (validated loudly before the step builds) and
    # switches the vote to the weighted, participation-normalized form
    part = None
    if (args.worker_weights is not None or args.quorum_frac is not None
            or args.dropout > 0.0):
        weights = (tuple(float(x) for x in args.worker_weights.split(","))
                   if args.worker_weights else None)
        part = collectives.ParticipationSpec(
            weights=weights, q_frac=args.quorum_frac, dropout=args.dropout)
    mode = args.mode or trainer_mode(args.arch)
    if mode == "simple":
        step = build_train_step(model, TrainStepConfig(
            compression=comp, lr=lr, local_lr=args.local_lr, worker_axes=wa,
            vote_impl=args.vote_impl, quorum=args.quorum,
            bucketed=args.bucketed,
            ring_chunk_rows=ring_rows, participation=part), mesh)
        params = model.init(jax.random.PRNGKey(args.seed))
    else:
        step = build_streamed_train_step(model, StreamedStepConfig(
            compression=comp, lr=lr, worker_axes=wa,
            vote_impl=args.vote_impl, quorum=args.quorum,
            bucketed=args.bucketed,
            ring_chunk_rows=ring_rows, participation=part), mesh)
        params = model.init(jax.random.PRNGKey(args.seed))
        params = jax.tree_util.tree_map(jax.device_put, params,
                                        fsdp_param_shardings(model, mesh))
    state = init_state(params, server=comp.server, seed=args.seed)
    return cfg, model, mesh, step, state, comp


def batch_fn_for(cfg, args):
    stream = LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            global_batch=args.batch, seed=args.seed)

    def fn(step_idx: int) -> dict:
        b = lm_batch(stream, step_idx)
        if cfg.input_kind != "tokens":
            rng = np.random.RandomState(step_idx)
            b["inputs"] = rng.randn(args.batch, args.seq_len, cfg.d_model).astype(np.float32) * 0.3
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.mrope:
            out["positions3"] = jnp.broadcast_to(
                out["positions"][..., None], out["positions"].shape + (3,))
        if args.tau > 1:
            out = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (args.tau,) + x.shape), out)
        return out

    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full config (TPU deployment)")
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--host-data", type=int, default=1)
    ap.add_argument("--host-model", type=int, default=1)
    ap.add_argument("--mode", default=None, choices=[None, "simple", "streamed"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--local-lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--compressor", default="sparsign")
    ap.add_argument("--server", default="scaled_sign_ef")
    ap.add_argument("--vote-impl", default="psum",
                    choices=["psum", "hier", "allgather_packed"],
                    help="vote wire; allgather_packed engages the packed "
                         "uplinks (2-bit ternary, or pack8 for qsgd8)")
    ap.add_argument("--budget", type=float, default=1.0)
    ap.add_argument("--budget-kind", default="fixed",
                    choices=["fixed", "linf_share", "l2_norm",
                             "target_sparsity"],
                    help="budget semantics; target_sparsity doubles as the "
                         "golomb wire's plan-time nonzero fraction")
    ap.add_argument("--local-budget", type=float, default=10.0)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--quorum", type=int, default=1,
                    help="vote-server deadband: |votes| < quorum -> no step "
                         "(majority_vote only); under elastic participation "
                         "it is re-derived as the fraction quorum/M of "
                         "realized participation")
    ap.add_argument("--quorum-frac", type=float, default=None,
                    help="elastic quorum as an explicit fraction of realized "
                         "participation W (overrides the quorum/M "
                         "derivation); engages elastic participation")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round report-dropout rate (chaos: crashed/"
                         "straggling reporters); engages elastic "
                         "participation")
    ap.add_argument("--worker-weights", default=None,
                    help="comma-separated per-worker vote weights (one per "
                         "worker, flat worker-index order); engages elastic "
                         "participation")
    ap.add_argument("--bucketed", action="store_true",
                    help="bucketized uplink (one collective per bucket; "
                         "streamed mode double-buffers exchange vs compute)")
    ap.add_argument("--ring", action="store_true",
                    help="ring-pipelined payload gather (allgather_packed "
                         "only): ppermute fixed-shape chunks around the "
                         "worker ring with streaming decode-sum — O(1) peak "
                         "HBM instead of O(M)")
    ap.add_argument("--ring-chunk-rows", type=int, default=None,
                    help="payload rows per ring chunk (multiple of 32; "
                         f"default {collectives.DEFAULT_RING_CHUNK_ROWS})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg, model, mesh, step, state, comp = build_everything(args)
    lcfg = loop_lib.LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every, fail_at_step=args.fail_at)
    with compat.set_mesh(mesh):
        state, history = loop_lib.run(step, state, batch_fn_for(cfg, args), lcfg)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)
    print(f"done: {len(history)} log points, final loss "
          f"{history[-1]['loss'] if history else float('nan'):.4f}")


if __name__ == "__main__":
    main()
