"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never touches
jax device state. Single pod: (16, 16) = 256 chips ('data', 'model'); multi-pod
adds the leading 'pod' axis: (2, 16, 16) = 512 chips. The ('pod', 'data') axes
are the paper's workers; 'model' carries TP/EP/SP.

Meshes come from repro.dist.compat so the Auto axis types are attached on jax
versions that carry them and silently dropped on the pinned 0.4.x.
"""

from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def worker_axes_of(mesh) -> tuple:
    """The paper's 'worker' axes for a production mesh."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh for host-device tests (8 forced CPU devices)."""
    return compat.make_mesh((data, model), ("data", "model"))
