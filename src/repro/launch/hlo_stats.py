"""Post-SPMD HLO parsing: collective census + wire-byte estimates.

Parses ``compiled.as_text()`` (per-device shapes after SPMD partitioning) and
tallies every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute. Per-device wire bytes use ring-algorithm estimates:

  all-reduce        2 * (n-1)/n * result_bytes
  all-gather        (n-1)/n * result_bytes
  reduce-scatter    (n-1) * result_bytes        (operand = n * result)
  all-to-all        (n-1)/n * result_bytes
  collective-permute  result_bytes

IMPORTANT caveat (documented in EXPERIMENTS.md): ops inside while-loop bodies
appear ONCE in the text; the dry-run handles this by compiling depth-1 and
depth-2 variants of each model and extrapolating linearly in the repeat count
(exact for scan-structured programs). The parser itself reports the static
census — also exactly what the §Perf loop diffs between variants.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(?:\{(?P<explicit>.*?)\}\}|\[(?P<iota>[0-9,]+)\]<=\[(?P<total>[0-9x,]+)\])")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    if m.group("iota"):
        dims = [int(x) for x in m.group("iota").split(",")]
        # [G, n] <= [N]: groups of size = product(dims)/G ... last dim(s) form group
        # v2 iota format: first dim = num groups, rest = group size product
        if len(dims) == 1:
            return dims[0]
        g = dims[0]
        size = 1
        for d in dims[1:]:
            size *= d
        return size
    expl = m.group("explicit")
    first = expl.split("}")[0].lstrip("{")
    return max(1, len([x for x in first.split(",") if x.strip() != ""]))


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    wire_bytes: float

    def as_dict(self):
        return {"counts": dict(self.counts), "bytes_by_op": dict(self.bytes_by_op),
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    counts = defaultdict(int)
    bytes_by_op = defaultdict(float)
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("shape"))
        n = _group_size(line, default_group)
        if op == "all-reduce":
            w = 2.0 * (n - 1) / max(n, 1) * rb
        elif op == "all-gather":
            w = (n - 1) / max(n, 1) * rb
        elif op == "reduce-scatter":
            w = (n - 1) * rb
        elif op == "all-to-all":
            w = (n - 1) / max(n, 1) * rb
        else:  # collective-permute
            w = float(rb)
        counts[op] += 1
        bytes_by_op[op] += w
        wire += w
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op, wire_bytes=wire)


def op_census(hlo_text: str, ops=("fusion", "while", "dot", "convolution",
                                  "custom-call", "dynamic-slice", "dynamic-update-slice")) -> dict:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"= [a-z0-9\[\],()/{{}}]* ?{op}\(", hlo_text))
    return out
