"""Serving launcher: batched prefill + decode with the KV/SSM cache machinery.

``python -m repro.launch.serve --arch mamba2-370m --tokens 32`` runs a greedy
batched generation loop on the smoke config (CPU); with --full and a TPU mesh
the same driver serves the production configs (decode cells of the dry-run
prove they lower/compile at 32k/500k cache depths).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.serve.decode import (build_decode_step, build_prefill,
                                build_update_ingest, encode_weight_update)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--online-updates", type=int, default=0, metavar="K",
                    help="apply a (synthetic) training-round weight update over "
                         "the 2-bit packed downlink wire every K generated "
                         "tokens — the live-update serving demo")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    b, s = args.batch, args.prompt_len
    if cfg.input_kind == "tokens":
        prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        prompt = jnp.asarray(rng.randn(b, s, cfg.d_model) * 0.3, cfg.activation_dtype)
    batch = {"inputs": prompt,
             "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
             "labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.mrope:
        batch["positions3"] = jnp.broadcast_to(batch["positions"][..., None], (b, s, 3))

    prefill = build_prefill(model, mesh, worker_axes=("data",))
    decode = build_decode_step(model, mesh, worker_axes=("data",))

    n_updates = 0
    if args.online_updates:
        # live-update ingestion: each round ships the quorum-gated ternary
        # server decision on the 0.25 B/coord packed wire and applies it via
        # the fused vote_update path (see serve.decode.build_update_ingest)
        ingest = build_update_ingest(model, mesh, lr=1e-4)

        def synth_round(r):
            leaves, treedef = jax.tree_util.tree_flatten(params)
            rr = np.random.RandomState(1000 + r)
            msgs = [encode_weight_update(
                jnp.asarray(rr.randint(-2, 3, l.shape), jnp.int32))
                for l in leaves]
            return jax.tree_util.tree_unflatten(treedef, msgs)

    # NOTE: prefill emits ring/SSD caches sized to the prompt; decode continues
    # into a max_len cache. For the smoke loop we re-init a full-depth cache and
    # replay the prompt through decode (exact, and exercises the decode path).
    max_len = s + args.tokens
    caches = model.init_cache(b, max_len)
    t0 = time.time()
    tok = None
    for pos in range(s + args.tokens - 1):
        if pos < s:
            inp = prompt[:, pos:pos + 1]
        else:
            inp = tok
        dec_batch = {"inputs": inp, "positions": jnp.full((b, 1), pos, jnp.int32)}
        if cfg.mrope:
            dec_batch["positions3"] = jnp.full((b, 1, 3), pos, jnp.int32)
        if args.online_updates and pos >= s and (pos - s) % args.online_updates == 0:
            params = ingest(params, synth_round(n_updates))
            n_updates += 1
        logits, caches = decode(params, caches, dec_batch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if cfg.input_kind == "tokens":
            tok = nxt
        else:  # embedding-input stubs: feed the argmax id through a fixed table
            tok = jnp.take(params.get("embed", jnp.zeros((cfg.vocab_size, cfg.d_model),
                           cfg.activation_dtype)), nxt[:, 0], axis=0)[:, None] \
                  if "embed" in params else jnp.zeros((b, 1, cfg.d_model), cfg.activation_dtype)
    dt = time.time() - t0
    n_generated = args.tokens * b
    print(f"generated {n_generated} tokens in {dt:.2f}s "
          f"({n_generated / dt:.1f} tok/s on CPU smoke config)")
    if n_updates:
        print(f"applied {n_updates} online weight-update rounds mid-serving "
              f"(2-bit packed downlink wire, fused vote_update apply)")
    if cfg.input_kind == "tokens":
        print("sample token ids:", np.asarray(nxt[:, 0])[:8].tolist())


if __name__ == "__main__":
    main()
