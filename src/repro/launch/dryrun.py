import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked at 512) ---
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config, trainer_mode
from repro.configs.shapes import SHAPES, applicable
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.dist import compat
from repro.dist.sharding import tp_param_shardings
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh, worker_axes_of
from repro.models.model import Model
from repro.serve.decode import build_decode_step, build_prefill, serve_input_specs
from repro.train.state import LrSchedule, TrainState
from repro.train.step_simple import TrainStepConfig, build_train_step
from repro.train.step_streamed import (StreamedStepConfig, build_fsdp_layout,
                                       build_streamed_train_step)

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, compiles,
and fits — and extract the roofline inputs from the compiled artifact.

Per cell we compile up to three variants:
  depth=full  -> memory_analysis (fits?), HLO collective census, compile proof
  depth=1,2   -> cost_analysis + wire-byte parse, linearly extrapolated in the
                 superblock repeat count R (exact for scan-structured programs;
                 XLA's cost analysis counts while bodies once — measured 8x
                 undercount on an 8-iteration scan, see EXPERIMENTS.md).
"""


def _compression(args) -> CompressionConfig:
    return CompressionConfig(
        compressor=args.compressor,
        budget=BudgetConfig(kind="fixed", value=args.budget),
        server=args.server,
        local_steps=args.tau,
        local_budget=args.local_budget,
        vote_dtype="int8",
    )


def _reduced(cfg: ModelConfig, depth: int) -> ModelConfig:
    n = len(cfg.pattern) * depth + len(cfg.tail_pattern)
    return dataclasses.replace(cfg, n_layers=n)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input (no allocation)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape, mesh, worker_axes, tau: int = 1):
    wa = tuple(worker_axes) if len(worker_axes) > 1 else worker_axes[0]
    b, s = shape.global_batch, shape.seq_len
    lead = () if tau == 1 else (tau,)
    bspec = P(wa) if tau == 1 else P(None, wa)
    sh = NamedSharding(mesh, bspec)
    if cfg.input_kind == "tokens":
        inputs = jax.ShapeDtypeStruct(lead + (b, s), jnp.int32, sharding=sh)
    else:
        inputs = jax.ShapeDtypeStruct(lead + (b, s, cfg.d_model), cfg.activation_dtype, sharding=sh)
    batch = {
        "inputs": inputs,
        "labels": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32, sharding=sh),
        "positions": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32, sharding=sh),
    }
    if cfg.mrope:
        batch["positions3"] = jax.ShapeDtypeStruct(lead + (b, s, 3), jnp.int32, sharding=sh)
    return batch


def train_state_specs(cfg: ModelConfig, mesh, mode: str, server: str, fsdp_axis="data"):
    model = Model(cfg)
    shapes = model.param_shapes()
    if mode == "simple":
        param_sh = tp_param_shardings(model, mesh)
    else:
        # streamed: FSDP over data + TP over model, merged per leaf
        from repro.train.step_streamed import streamed_shardings
        param_sh, _, _ = streamed_shardings(model, mesh, fsdp_axis)

    params_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), shapes, param_sh)
    ef_sds = None
    if server == "scaled_sign_ef":
        ef_sds = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding), params_sds)
    repl = NamedSharding(mesh, P())
    return TrainState(
        params=params_sds,
        ef_residual=ef_sds,
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
        seed=jax.ShapeDtypeStruct((), jnp.uint32, sharding=repl),
    )


def input_specs(arch: str, shape_name: str, mesh, *, mode=None, comp=None, tau=1):
    """ShapeDtypeStruct stand-ins for every input of the cell's step function."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mode = mode or trainer_mode(arch)
    wa = worker_axes_of(mesh)
    if shape.kind == "train":
        state = train_state_specs(cfg, mesh, mode, comp.server if comp else "scaled_sign_ef")
        batch = train_batch_specs(cfg, shape, mesh, wa, tau=tau)
        return (state, batch)
    if shape.kind == "prefill":
        model = Model(cfg)
        psh = tp_param_shardings(model, mesh)
        params = jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            model.param_shapes(), psh)
        batch = train_batch_specs(cfg, shape, mesh, wa)
        return (params, batch)
    # decode
    shard_seq = shape.global_batch < len(mesh.devices.flatten()) // mesh.shape["model"]
    return serve_input_specs(cfg, shape, mesh=mesh, worker_axes=wa, shard_seq=shard_seq)


def build_step(arch: str, shape_name: str, mesh, *, mode=None, comp=None,
               vote_impl="psum", cfg_override=None, pure_dp=False):
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mode = mode or trainer_mode(arch)
    model = Model(cfg)
    wa = tuple(mesh.axis_names) if pure_dp else worker_axes_of(mesh)
    if shape.kind == "train":
        if mode == "simple":
            return build_train_step(model, TrainStepConfig(
                compression=comp, lr=LrSchedule(base=1e-2), worker_axes=wa,
                vote_impl=vote_impl, donate=True), mesh)
        return build_streamed_train_step(model, StreamedStepConfig(
            compression=comp, lr=LrSchedule(base=1e-2), worker_axes=wa,
            fsdp_axis="data", donate=True), mesh)
    if shape.kind == "prefill":
        return build_prefill(model, mesh, worker_axes=wa)
    shard_seq = shape.global_batch < len(mesh.devices.flatten()) // mesh.shape["model"]
    return build_decode_step(model, mesh, worker_axes=wa, shard_seq=shard_seq)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool, args) -> dict:
    cfg_full = get_config(arch)
    shape = SHAPES[shape_name]
    runs, reason = applicable(cfg_full, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": trainer_mode(arch) if shape.kind == "train" else shape.kind,
        "compressor": args.compressor if shape.kind == "train" else None,
        "server": args.server if shape.kind == "train" else None,
        "vote_impl": args.vote_impl if shape.kind == "train" else None,
        "tau": args.tau if shape.kind == "train" else None,
        "status": "skip" if not runs else None,
        "skip_reason": reason or None,
    }
    if not runs:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    comp = _compression(args)
    mode = trainer_mode(arch)
    if getattr(args, "remat_policy", "full") != "full":
        cfg_full = dataclasses.replace(cfg_full, remat_policy=args.remat_policy)
        rec["remat_policy"] = args.remat_policy
    if mode == "streamed" and shape.kind == "train" and comp.server == "scaled_sign_ef":
        # fp32 server-EF residual for >=72B models cannot fit HBM next to the
        # params; streamed cells run Alg. 1 (SPARSIGNSGD, majority vote), which
        # is the paper's base method. Documented in EXPERIMENTS.md §Dry-run.
        comp = dataclasses.replace(comp, server="majority_vote")
        rec["server"] = "majority_vote (auto: EF residual infeasible at this scale)"
    depths = [None] if args.no_extrapolate else [None, 1, 2]
    per_depth = {}
    try:
        pure_dp = getattr(args, "pure_dp", False)
        for depth in depths:
            cfg = cfg_full if depth is None else _reduced(cfg_full, depth)
            t0 = time.time()
            step = build_step(arch, shape_name, mesh, mode=mode, comp=comp,
                              vote_impl=args.vote_impl, cfg_override=cfg,
                              pure_dp=pure_dp)
            with compat.set_mesh(mesh):
                specs = input_specs_with_cfg(cfg, shape_name, mesh, mode=mode, comp=comp,
                                             tau=args.tau, pure_dp=pure_dp)
                lowered = step.lower(*specs)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
            entry = {"lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # jax 0.4.x: list of per-device dicts
                ca = ca[0] if ca else {}
            entry["flops"] = float(ca.get("flops", 0.0))
            entry["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
            text = compiled.as_text()
            coll = hlo_stats.parse_collectives(text)
            entry["collectives"] = coll.as_dict()
            entry["op_census"] = hlo_stats.op_census(text)
            if depth is None:
                ma = compiled.memory_analysis()
                entry["memory"] = {
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                    "generated_code_bytes": int(ma.generated_code_size_in_bytes),
                }
            per_depth["full" if depth is None else str(depth)] = entry
            del step, lowered, compiled, text
        rec["status"] = "ok"
        rec["n_repeats"] = cfg_full.n_repeats
        rec["depths"] = per_depth
        if not args.no_extrapolate:
            rec["extrapolated"] = extrapolate(per_depth, cfg_full.n_repeats)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug; record it
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def input_specs_with_cfg(cfg, shape_name, mesh, *, mode, comp, tau=1, pure_dp=False):
    """input_specs but honoring a depth-reduced config."""
    shape = SHAPES[shape_name]
    wa = tuple(mesh.axis_names) if pure_dp else worker_axes_of(mesh)
    if shape.kind == "train":
        if pure_dp:
            # every axis is a worker: params fully replicated
            from jax.sharding import NamedSharding
            model = Model(cfg)
            repl = lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                  sharding=NamedSharding(mesh, P()))
            params_sds = jax.tree_util.tree_map(repl, model.param_shapes())
            ef_sds = None
            if comp.server == "scaled_sign_ef":
                ef_sds = jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding),
                    params_sds)
            rs = NamedSharding(mesh, P())
            state = TrainState(params=params_sds, ef_residual=ef_sds,
                               step=jax.ShapeDtypeStruct((), jnp.int32, sharding=rs),
                               seed=jax.ShapeDtypeStruct((), jnp.uint32, sharding=rs))
        else:
            state = train_state_specs(cfg, mesh, mode, comp.server)
        batch = train_batch_specs(cfg, shape, mesh, wa, tau=tau)
        return (state, batch)
    if shape.kind == "prefill":
        model = Model(cfg)
        psh = tp_param_shardings(model, mesh)
        params = jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            model.param_shapes(), psh)
        batch = train_batch_specs(cfg, shape, mesh, wa)
        return (params, batch)
    shard_seq = shape.global_batch < len(mesh.devices.flatten()) // mesh.shape["model"]
    return serve_input_specs(cfg, shape, mesh=mesh, worker_axes=wa, shard_seq=shard_seq)


def extrapolate(per_depth: dict, r_full: int) -> dict:
    """X(R) = X(1) + (X(2) - X(1)) * (R - 1), per metric."""
    d1, d2 = per_depth.get("1"), per_depth.get("2")
    if not d1 or not d2:
        return {}
    out = {}
    for key in ("flops", "bytes_accessed"):
        out[key] = d1[key] + (d2[key] - d1[key]) * (r_full - 1)
    w1 = d1["collectives"]["wire_bytes"]
    w2 = d2["collectives"]["wire_bytes"]
    out["collective_wire_bytes"] = w1 + (w2 - w1) * (r_full - 1)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", choices=["all"] + ARCH_IDS)
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compressor", default="sparsign")
    ap.add_argument("--server", default="scaled_sign_ef",
                    choices=["majority_vote", "scaled_sign_ef", "mean"])
    ap.add_argument("--budget", type=float, default=1.0)
    ap.add_argument("--local-budget", type=float, default=10.0)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--vote-impl", default="psum", choices=["psum", "hier", "allgather_packed"])
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--pure-dp", action="store_true",
                    help="treat EVERY mesh axis as a worker axis (sub-1B models: "
                         "kills TP/SP collectives; the vote is M-invariant)")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                print(f"=== {arch} x {shape_name} x {'2x16x16' if mp else '16x16'} ===",
                      flush=True)
                rec = run_cell(arch, shape_name, multi_pod=mp, args=args)
                records.append(rec)
                status = rec["status"]
                extra = rec.get("skip_reason") or rec.get("error") or ""
                if status == "ok":
                    full = rec["depths"]["full"]
                    mem = full.get("memory", {})
                    print(f"  ok: compile={full['compile_s']}s "
                          f"args={mem.get('argument_bytes', 0)/2**30:.1f}GiB "
                          f"temp={mem.get('temp_bytes', 0)/2**30:.1f}GiB "
                          f"colls={full['collectives']['counts']}", flush=True)
                else:
                    print(f"  {status}: {extra[:300]}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)
    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skip" for r in records)
    fail = sum(r["status"] == "fail" for r in records)
    print(f"\n==== dry-run summary: {ok} ok / {skip} skip / {fail} fail ====")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
