"""Mixture-of-Experts FFN: top-k routing with shared experts.

Two implementations with identical semantics (tested equal when capacity drops
nothing):

  dense  — every expert runs on every token, gated combine. O(T*E*F) compute;
           only for smoke-scale configs and as the correctness oracle.
  gather — production path: per-expert top-C token selection (priority = gate
           probability), gather -> per-expert SwiGLU einsum -> scatter-add
           combine. Experts shard over the 'expert' logical axis (EP over the
           mesh 'model' axis); capacity C = ceil(cf * T * k / E). Tokens beyond
           capacity are dropped (GShard semantics), which the paper's vote
           aggregation is insensitive to.

Expert count is padded to a multiple of the EP shard count by the config layer
(e.g. qwen2-moe 60 -> 64 with 4 null experts the router never selects... the
router logits for padded experts are masked to -inf here).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import hint, swiglu


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int            # real (un-padded) routed experts
    n_experts_padded: int     # >= n_experts, multiple of EP shards
    top_k: int
    d_model: int
    d_ff: int                 # per-expert hidden
    capacity_factor: float = 1.25
    router_act: str = "softmax"   # softmax | sigmoid (llama4-style top-1)
    renorm_topk: bool = False


def capacity(dims: MoEDims, n_tokens: int) -> int:
    c = max(1, int(dims.capacity_factor * n_tokens * dims.top_k / dims.n_experts))
    return min(-(-c // 8) * 8, n_tokens)  # round up to 8, cap at T


def router_probs(x: jnp.ndarray, w_router: jnp.ndarray, dims: MoEDims) -> jnp.ndarray:
    """[T, E_padded] routing probabilities; padded experts masked out."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    if dims.n_experts_padded > dims.n_experts:
        pad_mask = jnp.arange(dims.n_experts_padded) >= dims.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    if dims.router_act == "softmax":
        return jax.nn.softmax(logits, axis=-1)
    return jax.nn.sigmoid(logits)


def _topk_gates(probs: jnp.ndarray, dims: MoEDims):
    gate_vals, expert_idx = jax.lax.top_k(probs, dims.top_k)  # [T, k]
    if dims.renorm_topk:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return gate_vals, expert_idx


def _expert_ffn(xin: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """xin: [E, C, Dm]; weights [E, Dm, F] / [E, F, Dm]."""
    h = swiglu(jnp.einsum("ecd,edf->ecf", xin, w_gate),
               jnp.einsum("ecd,edf->ecf", xin, w_up))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn_gather(params: dict, x: jnp.ndarray, dims: MoEDims) -> jnp.ndarray:
    """x: [T, Dm] -> [T, Dm]."""
    t = x.shape[0]
    probs = router_probs(x, params["router"], dims)
    gate_vals, expert_idx = _topk_gates(probs, dims)

    # token->expert gate matrix [T, E] (0 where not routed)
    assign = jnp.zeros((t, dims.n_experts_padded), jnp.float32)
    assign = assign.at[jnp.arange(t)[:, None], expert_idx].set(gate_vals)

    c = capacity(dims, t)
    # per-expert top-C tokens by gate (priority). [E, C]
    sel_gate, sel_tok = jax.lax.top_k(assign.T, c)
    valid = sel_gate > 0.0

    xin = x[sel_tok.reshape(-1)].reshape(dims.n_experts_padded, c, dims.d_model)
    xin = hint(xin, "expert", None, None)
    out = _expert_ffn(xin.astype(x.dtype), params["w_gate"], params["w_up"], params["w_down"])
    out = out * (sel_gate * valid)[..., None].astype(out.dtype)
    out = hint(out, "expert", None, None)

    y = jnp.zeros((t, dims.d_model), jnp.float32)
    y = y.at[sel_tok.reshape(-1)].add(out.reshape(-1, dims.d_model).astype(jnp.float32))
    return y.astype(x.dtype)


def moe_ffn_dense(params: dict, x: jnp.ndarray, dims: MoEDims) -> jnp.ndarray:
    """Oracle path: all experts on all tokens (top-k gates, no capacity drops)."""
    probs = router_probs(x, params["router"], dims)
    gate_vals, expert_idx = _topk_gates(probs, dims)
    t = x.shape[0]
    gates = jnp.zeros((t, dims.n_experts_padded), jnp.float32)
    gates = gates.at[jnp.arange(t)[:, None], expert_idx].set(gate_vals)

    def one_expert(w_gate, w_up, w_down):
        h = swiglu(x @ w_gate, x @ w_up)
        return h @ w_down  # [T, Dm]

    outs = jax.vmap(one_expert)(params["w_gate"], params["w_up"], params["w_down"])  # [E,T,Dm]
    return jnp.einsum("te,etd->td", gates, outs.astype(jnp.float32)).astype(x.dtype)


def moe_ffn(params: dict, x: jnp.ndarray, dims: MoEDims, impl: str = "gather") -> jnp.ndarray:
    """Routed experts + optional always-on shared expert (params['shared_*'])."""
    fn = moe_ffn_gather if impl == "gather" else moe_ffn_dense
    y = fn(params, x, dims)
    if "shared_w_gate" in params:
        y = y + swiglu(x @ params["shared_w_gate"], x @ params["shared_w_up"]) @ params["shared_w_down"]
    return y


def moe_param_shapes(dims: MoEDims, n_shared: int, dtype) -> dict:
    e, dm, f = dims.n_experts_padded, dims.d_model, dims.d_ff
    shapes = {
        "router": ((dm, e), jnp.float32),
        "w_gate": ((e, dm, f), dtype),
        "w_up": ((e, dm, f), dtype),
        "w_down": ((e, f, dm), dtype),
    }
    if n_shared > 0:
        fs = n_shared * f
        shapes.update({
            "shared_w_gate": ((dm, fs), dtype),
            "shared_w_up": ((dm, fs), dtype),
            "shared_w_down": ((fs, dm), dtype),
        })
    return shapes
