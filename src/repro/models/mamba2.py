"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer block.

Implements the minimal SSD algorithm: chunked scan with intra-chunk einsums
(MXU-friendly matmuls) and an inter-chunk state recurrence carried by
``lax.scan`` — the TPU-native adaptation of the paper's GPU kernel: instead of
a fused triton scan, chunk-local work becomes batched matmuls the MXU executes
at full tilt and the only sequential piece is the O(S/Q) chunk recurrence.

TPU adaptation notes (see DESIGN.md):
  * The reference packs [z, x, B, C, dt] into one in_proj; we split it into
    separate projections (w_z, w_x, w_bc, w_dt) so the head-structured pieces
    shard over the tensor-parallel axis while B/C stay replicated — the packed
    layout cannot shard without resharding collectives on every slice.
  * single B/C group (ngroups=1; the assigned mamba2-370m uses 1)
  * gated RMSNorm simplified to RMSNorm of the gated output; D-term per head.

Decode is the exact O(1) recurrence; equivalence with the chunked path is a
unit test (tests/test_mamba.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import hint, rms_norm


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_inner: int        # expand * d_model
    n_heads: int        # d_inner // head_dim
    head_dim: int
    d_state: int        # N
    d_conv: int = 4
    chunk: int = 128


def mamba_param_defs(dims: MambaDims, dtype) -> dict:
    """name -> (shape, dtype, logical_axes)."""
    di, n, h = dims.d_inner, dims.d_state, dims.n_heads
    return {
        "w_z": ((dims.d_model, di), dtype, (None, "ff")),
        "w_x": ((dims.d_model, di), dtype, (None, "ff")),
        "w_bc": ((dims.d_model, 2 * n), dtype, (None, None)),
        "w_dt": ((dims.d_model, h), dtype, (None, None)),
        "conv_x": ((dims.d_conv, di), dtype, (None, "ff")),
        "conv_bc": ((dims.d_conv, 2 * n), dtype, (None, None)),
        "conv_b_x": ((di,), dtype, ("ff",)),
        "conv_b_bc": ((2 * n,), dtype, (None,)),
        "A_log": ((h,), jnp.float32, (None,)),
        "dt_bias": ((h,), jnp.float32, (None,)),
        "D": ((h,), jnp.float32, (None,)),
        "norm": ((di,), dtype, ("ff",)),
        "w_out": ((di, dims.d_model), dtype, ("ff", None)),
    }


def _causal_conv(x: jnp.ndarray, conv_w: jnp.ndarray, conv_b: jnp.ndarray,
                 init: jnp.ndarray | None = None):
    """Depthwise causal conv along seq. x: [B,S,C]; conv_w: [K,C].

    Returns (out [B,S,C], tail [B,K-1,C]) — the tail primes the decode ring.
    """
    k = conv_w.shape[0]
    b, s, c = x.shape
    front = init if init is not None else jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([front, x], axis=1)
    out = jnp.zeros((b, s, c), jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + s].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    out = jax.nn.silu(out + conv_b.astype(jnp.float32))
    return out.astype(x.dtype), xp[:, s:]


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Causal segment sums: out[..., i, j] = sum_{j < k <= i} x[..., k] (=-inf j>i)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, d_skip, dims: MambaDims,
                init_state=None):
    """SSD over a full sequence.

    x:     [B,S,H,P]   (values)
    dt:    [B,S,H]     (pre-softplus)
    b_mat: [B,S,N], c_mat: [B,S,N]  (single group)
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s_orig, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(dims.chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        # dt -> -inf makes softplus(dt)=0: padded steps leave the state untouched
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q

    dt = jax.nn.softplus(dt.astype(jnp.float32))            # [B,S,H]
    a = -jnp.exp(a_log.astype(jnp.float32))                  # [H]
    da = hint(dt * a[None, None, :], "batch", None, "heads")  # [B,S,H] log decay
    xdt = hint(x.astype(jnp.float32) * dt[..., None], "batch", None, "heads", None)

    # chunk views
    da_c = da.reshape(bsz, nc, q, h)
    x_c = xdt.reshape(bsz, nc, q, h, p)
    b_c = b_mat.astype(jnp.float32).reshape(bsz, nc, q, n)
    c_c = c_mat.astype(jnp.float32).reshape(bsz, nc, q, n)

    # intra-chunk (diagonal blocks): y[i] = sum_j (C_i.B_j) L[h,i,j] x[j]
    l_mat = jnp.exp(_segsum(da_c.transpose(0, 1, 3, 2)))     # [B,nc,H,q,q]
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)             # [B,nc,q,q]
    y_intra = jnp.einsum("bcij,bchij,bcjhp->bcihp", cb, l_mat, x_c)

    # chunk-final states: sum_j exp(sum_{k>j} da) B_j x_j
    da_cum = jnp.cumsum(da_c, axis=2)                        # [B,nc,q,H]
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)    # [B,nc,q,H]
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", b_c, decay_to_end, x_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])               # [B,nc,H]

    def scan_body(state, inp):
        cs_, cd = inp                                        # [B,H,P,N], [B,H]
        out_state = state                                    # state entering this chunk
        state = state * cd[..., None, None] + cs_
        return state, out_state

    init = init_state if init_state is not None else jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, states_in = jax.lax.scan(
        scan_body, init,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)           # [B,nc,H,P,N]

    # off-diagonal contribution: y_off = C_i . (decay_in * state_in)
    decay_in = jnp.exp(da_cum)                               # [B,nc,q,H]
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", c_c, decay_in, states_in)

    y = (y_intra + y_off).reshape(bsz, s, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    if pad:
        y = y[:, :s_orig]
    return y, final_state


def mamba_forward(params: dict, hidden: jnp.ndarray, dims: MambaDims,
                  conv_init=None, ssd_init=None, return_cache: bool = False):
    """Full mixer: projections -> conv -> SSD -> gated norm -> out_proj.

    hidden: [B,S,Dm]. conv_init: [B,K-1,di+2n]. Returns out [B,S,Dm]
    (+ (conv_tail, final_state) if return_cache).
    """
    bsz, s, _ = hidden.shape
    di, n = dims.d_inner, dims.d_state
    z = hidden @ params["w_z"]                               # [B,S,di]
    x_raw = hint(hidden @ params["w_x"], "batch", "seq", "ff")
    bc_raw = hidden @ params["w_bc"]
    dt = (hidden @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"]

    conv_in_x = conv_init[..., :di] if conv_init is not None else None
    conv_in_bc = conv_init[..., di:] if conv_init is not None else None
    x_conv, tail_x = _causal_conv(x_raw, params["conv_x"], params["conv_b_x"], conv_in_x)
    bc_conv, tail_bc = _causal_conv(bc_raw, params["conv_bc"], params["conv_b_bc"], conv_in_bc)

    x = x_conv.reshape(bsz, s, dims.n_heads, dims.head_dim)
    b_mat, c_mat = bc_conv[..., :n], bc_conv[..., n:]
    y, final_state = ssd_chunked(x, dt, params["A_log"], b_mat, c_mat, params["D"], dims, ssd_init)
    y = y.reshape(bsz, s, di).astype(hidden.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = y @ params["w_out"]
    if return_cache:
        return out, (jnp.concatenate([tail_x, tail_bc], axis=-1), final_state)
    return out


def mamba_decode_step(params: dict, hidden: jnp.ndarray, cache, dims: MambaDims):
    """One-token recurrence. hidden: [B,1,Dm]; cache = (conv_ring [B,K-1,di+2n],
    state [B,H,P,N])."""
    conv_ring, state = cache
    bsz = hidden.shape[0]
    di, n = dims.d_inner, dims.d_state
    h0 = hidden[:, 0]
    z = h0 @ params["w_z"]
    x_raw = h0 @ params["w_x"]
    bc_raw = h0 @ params["w_bc"]
    dt = (h0 @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"]

    window = jnp.concatenate([conv_ring, jnp.concatenate([x_raw, bc_raw], -1)[:, None, :]], axis=1)
    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)
    conv_b = jnp.concatenate([params["conv_b_x"], params["conv_b_bc"]], axis=-1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), conv_w.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + conv_b.astype(jnp.float32)).astype(hidden.dtype)
    new_ring = window[:, 1:]

    x = conv_out[..., :di].reshape(bsz, dims.n_heads, dims.head_dim)
    b_vec = conv_out[..., di:di + n].astype(jnp.float32)
    c_vec = conv_out[..., di + n:].astype(jnp.float32)

    dtf = jax.nn.softplus(dt)                                # [B,H]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtf * a[None, :])                        # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32) * dtf[..., None], b_vec)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_vec)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, di).astype(hidden.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = (y @ params["w_out"])[:, None, :]
    return out, (new_ring, state)
