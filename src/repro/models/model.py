"""Model assembly: embedding -> scan(pattern superblocks) -> norm -> LM head.

Exposes the three stages separately (embed_stage / superblock_apply /
head_loss) so the streamed trainer can run its manual per-superblock backward;
``loss`` composes them with lax.scan (+remat) for the simple path, and
``prefill`` / ``decode_step`` provide serving.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blocks_lib
from repro.models.common import dense_init, hint, rms_norm


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------

    def param_defs(self):
        """pytree of (shape, dtype, logical_axes) matching the params pytree."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        r = cfg.n_repeats
        defs = {}
        if cfg.input_kind == "tokens":
            defs["embed"] = ((cfg.vocab_size, cfg.d_model), dt, ("vocab", None))
        block_defs = []
        for spec in cfg.pattern:
            bd = blocks_lib.block_param_defs(cfg, spec)
            block_defs.append({
                k: ((r,) + shape, dtype, (None,) + tuple(logical))
                for k, (shape, dtype, logical) in bd.items()
            })
        defs["blocks"] = tuple(block_defs)
        if cfg.tail_pattern:
            defs["tail"] = tuple(blocks_lib.block_param_defs(cfg, spec) for spec in cfg.tail_pattern)
        defs["final_norm"] = ((cfg.d_model,), dt, (None,))
        if not cfg.tie_embeddings:
            defs["lm_head"] = ((cfg.d_model, cfg.vocab_size), dt, (None, "vocab"))
        return defs

    def param_shapes(self):
        return jax.tree_util.tree_map(
            lambda d: jax.ShapeDtypeStruct(d[0], d[1]),
            self.param_defs(),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple),
        )

    def param_logical_axes(self):
        return jax.tree_util.tree_map(
            lambda d: d[2],
            self.param_defs(),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple),
        )

    def init(self, key) -> dict:
        flat_defs, treedef = jax.tree_util.tree_flatten(
            self.param_defs(),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple),
        )
        keys = jax.random.split(key, len(flat_defs))
        leaves = []
        for (shape, dtype, _), k in zip(flat_defs, keys):
            if len(shape) == 1 or shape[-1] == 1:
                leaves.append(jnp.zeros(shape, dtype))  # norms / biases
            else:
                leaves.append(dense_init(k, shape, dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ------------------------------------------------------------------
    # Stages
    # ------------------------------------------------------------------

    def embed_stage(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        x = batch["inputs"]
        if cfg.input_kind == "tokens":
            h = jnp.take(params["embed"], x, axis=0)
        else:
            h = x.astype(cfg.activation_dtype)
        return hint(h, "batch", "seq", None)

    def _remat_policy(self):
        """§Perf H4: 'dots' saves matmul outputs (recompute elementwise only),
        cutting the training matmul factor from ~4 passes to ~3.2."""
        if self.cfg.remat_policy == "dots":
            return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return None  # 'full': save nothing

    def _superblock(self, h, block_slices, positions, positions3):
        for spec, p in zip(self.cfg.pattern, block_slices):
            fwd = functools.partial(blocks_lib.block_forward, self.cfg, spec)
            if self.cfg.remat and len(self.cfg.pattern) > 1:
                # nested remat: peak memory = ONE block's internals, not the
                # whole superblock's (critical for jamba/hybrid superblocks)
                fwd = jax.checkpoint(fwd, policy=self._remat_policy())
            h = fwd(p, h, positions, positions3)
        return h

    def superblock_apply(self, block_slices, h, positions, positions3=None):
        """Public single-superblock forward (streamed trainer entry point)."""
        return self._superblock(h, block_slices, positions, positions3)

    def forward_hidden(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        h = self.embed_stage(params, batch)
        positions = batch["positions"]
        positions3 = batch.get("positions3")

        def body(carry, xs):
            return self._superblock(carry, xs, positions, positions3), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=self._remat_policy())
        h, _ = jax.lax.scan(body, h, params["blocks"])
        for spec, p in zip(cfg.tail_pattern, params.get("tail", ())):
            h = blocks_lib.block_forward(cfg, spec, p, h, positions, positions3)
        return rms_norm(h, params["final_norm"], cfg.norm_eps)

    def head_loss(self, params, h, labels):
        """Chunked softmax-xent: never materializes [B,S,V] logits."""
        cfg = self.cfg
        w = self.head_weight(params)
        b, s, d = h.shape
        c = min(cfg.loss_chunk, s)
        pad = (-s) % c
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
            s += pad
        hc = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
        yc = labels.reshape(b, s // c, c).transpose(1, 0, 2)

        def chunk(carry, xs):
            h_i, y_i = xs
            logits = (h_i @ w).astype(jnp.float32)
            logits = hint(logits, "batch", None, "vocab")
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, jnp.maximum(y_i, 0)[..., None], axis=-1)[..., 0]
            mask = (y_i >= 0).astype(jnp.float32)
            nll, cnt = carry
            return (nll + jnp.sum((logz - tgt) * mask), cnt + jnp.sum(mask)), None

        body = jax.checkpoint(chunk) if cfg.remat else chunk
        (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, yc))
        return nll / jnp.maximum(cnt, 1.0)

    def loss(self, params, batch):
        h = self.forward_hidden(params, batch)
        loss = self.head_loss(params, h, batch["labels"])
        return loss, {"loss": loss}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def cache_shapes(self, batch_size: int, max_len: int):
        cfg = self.cfg
        r = cfg.n_repeats
        body = []
        for spec in cfg.pattern:
            defs = blocks_lib.block_cache_defs(cfg, spec, batch_size, max_len)
            body.append({k: jax.ShapeDtypeStruct((r,) + shape, dtype) for k, (shape, dtype) in defs.items()})
        out = {"body": tuple(body)}
        if cfg.tail_pattern:
            out["tail"] = tuple(
                {k: jax.ShapeDtypeStruct(shape, dtype)
                 for k, (shape, dtype) in blocks_lib.block_cache_defs(cfg, spec, batch_size, max_len).items()}
                for spec in cfg.tail_pattern)
        return out

    def init_cache(self, batch_size: int, max_len: int):
        def mk(sds):
            if sds.dtype == jnp.int32:  # position slots start empty
                return jnp.full(sds.shape, -1, sds.dtype)
            return jnp.zeros(sds.shape, sds.dtype)
        return jax.tree_util.tree_map(mk, self.cache_shapes(batch_size, max_len))

    def prefill(self, params, batch):
        """Forward that also emits decode caches; returns (hidden_last, caches)."""
        cfg = self.cfg
        h = self.embed_stage(params, batch)
        positions = batch["positions"]
        positions3 = batch.get("positions3")

        def body(carry, xs):
            hh = carry
            caches = []
            for spec, p in zip(cfg.pattern, xs):
                hh, cache = blocks_lib.block_forward(cfg, spec, p, hh, positions, positions3,
                                                     return_cache=True)
                caches.append(cache)
            return hh, tuple(caches)

        if cfg.remat:
            body = jax.checkpoint(body)
        h, body_caches = jax.lax.scan(body, h, params["blocks"])
        caches = {"body": body_caches}
        if cfg.tail_pattern:
            tail_caches = []
            for spec, p in zip(cfg.tail_pattern, params["tail"]):
                h, cache = blocks_lib.block_forward(cfg, spec, p, h, positions, positions3,
                                                    return_cache=True)
                tail_caches.append(cache)
            caches["tail"] = tuple(tail_caches)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, caches

    def decode_step(self, params, caches, batch):
        """One token for every sequence. batch: {"inputs": [B,1] (or [B,1,D]),
        "positions": [B,1], optional "positions3": [B,1,3]}.
        Returns (logits [B,V], new_caches)."""
        cfg = self.cfg
        h = self.embed_stage(params, batch)
        positions = batch["positions"]
        positions3 = batch.get("positions3")

        def body(carry, xs):
            hh = carry
            block_slices, cache_slices = xs
            new_caches = []
            for spec, p, c in zip(cfg.pattern, block_slices, cache_slices):
                hh, nc = blocks_lib.block_decode(cfg, spec, p, hh, c, positions, positions3)
                new_caches.append(nc)
            return hh, tuple(new_caches)

        h, new_body = jax.lax.scan(body, h, (params["blocks"], caches["body"]))
        new_caches = {"body": new_body}
        if cfg.tail_pattern:
            new_tail = []
            for spec, p, c in zip(cfg.tail_pattern, params["tail"], caches["tail"]):
                h, nc = blocks_lib.block_decode(cfg, spec, p, h, c, positions, positions3)
                new_tail.append(nc)
            new_caches["tail"] = tuple(new_tail)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = (h[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        return hint(logits, "batch", "vocab"), new_caches
