"""Rotary position embeddings: standard RoPE and qwen2-vl's M-RoPE.

M-RoPE splits the head_dim/2 frequency bands into three sections
(temporal, height, width); each section rotates by its own position stream.
For text tokens all three positions coincide, recovering standard RoPE —
the property test in tests/test_rope.py checks exactly that.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S] int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
         x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin],
        axis=-1,
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions3: jnp.ndarray,
    theta: float = 10000.0,
    sections: Sequence[int] = (16, 24, 24),
) -> jnp.ndarray:
    """qwen2-vl M-RoPE. x: [..., S, H, Dh]; positions3: [..., S, 3] (t, h, w).

    ``sections`` are the per-axis frequency-band counts; they must sum to Dh/2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    # Pick which position stream drives each frequency band.
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions3.astype(jnp.float32)  # [..., S, 3]
    pos_per_band = jnp.take_along_axis(
        pos[..., None, :], sec_id[None, :, None].astype(jnp.int32) * jnp.ones(pos.shape[:-1] + (half, 1), jnp.int32),
        axis=-1,
    )[..., 0]  # [..., S, half]
    angles = pos_per_band * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
         x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin],
        axis=-1,
    )
    return out.astype(x.dtype)
