"""Attention: GQA with RoPE/M-RoPE, blockwise (flash-style) softmax, sliding
windows, and decode against (ring-buffered) KV caches.

Layout: all score/accumulator tensors use the FUSED head axis [B, H, ...] with
an explicit sharding hint on H ('heads' -> TP axis) inside every scan body —
GSPMD does not reliably propagate head sharding through the online-softmax
scan in the GQA-split [B, KV, G, ...] layout (measured: 16 GiB/device
unsharded score buffers on the 72B configs), so we pin it. KV heads are
repeated to H per chunk (transient, head-sharded, ~MBs) — the classic
GQA-bandwidth saving still holds where it matters (the KV *cache* and K/V
projections stay at KV width).

Three structured paths, all pure jnp:
  chunked_attention   — online-softmax scan over KV chunks; chunk bodies are
                        jax.checkpoint'ed (flash semantics under AD: per-chunk
                        probabilities are recomputed, not saved).
  windowed_attention  — scan over query chunks, each attending to a
                        structurally-sliced KV span: O(S*window) compiled FLOPs.
  decode_attention    — single-token query vs cache (linear in cache length).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import hint

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,C,KV,D] -> [B,C,H,D] (head-sharded via hint; transient per chunk)."""
    b, c, n_kv, d = k.shape
    g = n_heads // n_kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, c, n_kv, g, d)).reshape(b, c, n_heads, d)
    return hint(k, "batch", None, "heads", None)


def chunked_attention(
    q: jnp.ndarray,               # [B,Sq,H,D]
    k: jnp.ndarray,               # [B,Skv,KV,D]
    v: jnp.ndarray,               # [B,Skv,KV,D]
    *,
    positions_q: jnp.ndarray,     # [B,Sq] int32
    positions_kv: jnp.ndarray,    # [B,Skv] int32
    kv_valid: Optional[jnp.ndarray] = None,  # [B,Skv] bool
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions_kv = jnp.pad(positions_kv, ((0, 0), (0, pad)), constant_values=-1)
    valid = kv_valid if kv_valid is not None else jnp.ones((b, skv), bool)
    if pad:
        valid = jnp.pad(valid, ((0, 0), (0, pad)), constant_values=False)

    qh = hint(q.astype(jnp.float32) * (d ** -0.5), "batch", None, "heads", None)
    qh = qh.transpose(0, 2, 1, 3)                      # [B,H,Sq,D]
    kc = k.reshape(b, n_chunks, chunk, n_kv, d)
    vc = v.reshape(b, n_chunks, chunk, n_kv, d)
    pc = positions_kv.reshape(b, n_chunks, chunk)
    mc = valid.reshape(b, n_chunks, chunk)

    def body(carry, xs):
        acc, m, l = carry                               # [B,H,Sq,D], [B,H,Sq], [B,H,Sq]
        k_i, v_i, p_i, ok_i = xs                        # [B,C,KV,D], ..., [B,C], [B,C]
        k_r = _repeat_kv(k_i, h).astype(jnp.float32)    # [B,C,H,D]
        v_r = _repeat_kv(v_i, h).astype(jnp.float32)
        scores = jnp.einsum("bhqd,bchd->bhqc", qh, k_r)
        scores = hint(scores, "batch", "heads", None, None)
        ok = ok_i[:, None, :]
        if causal:
            ok = ok & (p_i[:, None, :] <= positions_q[:, :, None])
        if window is not None:
            ok = ok & (positions_q[:, :, None] - p_i[:, None, :] < window)
        scores = jnp.where(ok[:, None, :, :], scores, NEG_INF)  # [B,1,Sq,C] bcast on H
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p, v_r)
        acc = hint(acc * alpha[..., None] + pv, "batch", "heads", None, None)
        return (acc, m_new, l_new), None

    body = jax.checkpoint(body)  # flash semantics: recompute p in backward
    init = (
        hint(jnp.zeros((b, h, sq, d), jnp.float32), "batch", "heads", None, None),
        jnp.full((b, h, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
    )
    (acc, _, l), _ = jax.lax.scan(
        body, init,
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2), mc.transpose(1, 0, 2)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.where(l[..., None] > 0, out, 0.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)    # [B,Sq,H,D]


def windowed_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    positions: jnp.ndarray,       # [B,S] shared q/kv positions (self-attention)
    window: int,
    q_chunk: int = 512,
) -> jnp.ndarray:
    """Causal sliding-window self-attention with structural O(S*window) cost."""
    b, s_orig, h, d = q.shape
    n_kv = k.shape[2]
    q_chunk = min(q_chunk, s_orig)
    pad_s = (-s_orig) % q_chunk
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad_s)), constant_values=-1)
    s = s_orig + pad_s
    span = (-(-window // q_chunk)) * q_chunk + q_chunk  # kv span per q chunk

    front = span - q_chunk
    kp = jnp.pad(k, ((0, 0), (front, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (front, 0), (0, 0), (0, 0)))
    pos_p = jnp.pad(positions, ((0, 0), (front, 0)), constant_values=-1)

    qh = hint(q.astype(jnp.float32) * (d ** -0.5), "batch", None, "heads", None)
    qh = qh.transpose(0, 2, 1, 3)                       # [B,H,S,D]

    def body(_, i):
        q_i = jax.lax.dynamic_slice_in_dim(qh, i * q_chunk, q_chunk, axis=2)   # [B,H,cq,D]
        pq_i = jax.lax.dynamic_slice_in_dim(positions, i * q_chunk, q_chunk, axis=1)
        k_i = jax.lax.dynamic_slice_in_dim(kp, i * q_chunk, span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, i * q_chunk, span, axis=1)
        pk_i = jax.lax.dynamic_slice_in_dim(pos_p, i * q_chunk, span, axis=1)
        k_r = _repeat_kv(k_i, h).astype(jnp.float32)
        v_r = _repeat_kv(v_i, h).astype(jnp.float32)
        scores = jnp.einsum("bhqd,bchd->bhqc", q_i, k_r)
        scores = hint(scores, "batch", "heads", None, None)
        ok = ((pk_i[:, None, :] <= pq_i[:, :, None])
              & (pq_i[:, :, None] - pk_i[:, None, :] < window)
              & (pk_i[:, None, :] >= 0))
        scores = jnp.where(ok[:, None, :, :], scores, NEG_INF)
        p_max = scores.max(axis=-1, keepdims=True)
        p = jnp.exp(scores - p_max)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhqc,bchd->bhqd", p / jnp.maximum(l, 1e-30), v_r)
        return None, hint(o, "batch", "heads", None, None)

    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, jnp.arange(s // q_chunk))
    # outs: [n, B, H, cq, D] -> [B, n*cq = S, H, D]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    if pad_s:
        out = out[:, :s_orig]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,               # [B,1,H,D]
    k_cache: jnp.ndarray,         # [B,W,KV,D]
    v_cache: jnp.ndarray,         # [B,W,KV,D]
    cache_pos: jnp.ndarray,       # [B,W] int32, -1 = empty slot
    positions_q: jnp.ndarray,     # [B,1]
    *,
    window: Optional[int] = None,
    chunk: int = 8192,
) -> jnp.ndarray:
    """One-token attention over a (possibly ring-buffered) cache.

    Chunked over the cache so 500k-long caches only materialize [B,H,chunk]
    score tiles per step.
    """
    valid = cache_pos >= 0
    return chunked_attention(
        q, k_cache, v_cache,
        positions_q=positions_q, positions_kv=cache_pos, kv_valid=valid,
        causal=True, window=window, chunk=chunk,
    )
