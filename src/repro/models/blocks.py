"""Block assembly: (mixer -> residual) + (FFN -> residual), both pre-normed.

``block_param_defs`` is the single source of truth for parameter shapes, dtypes
and logical sharding axes; init, eval_shape, and the dist layer all derive from
it. Stacked leading axis R (pattern repeats) is added by the model.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2, moe as moe_lib
from repro.models.common import hint, rms_norm, swiglu
from repro.models.rope import apply_mrope, apply_rope


def moe_dims(cfg: ModelConfig) -> moe_lib.MoEDims:
    return moe_lib.MoEDims(
        n_experts=cfg.n_experts,
        n_experts_padded=cfg.n_experts_padded or cfg.n_experts,
        top_k=cfg.top_k,
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff,
        capacity_factor=cfg.capacity_factor,
        router_act=cfg.router_act,
        renorm_topk=cfg.renorm_topk,
    )


def mamba_dims(cfg: ModelConfig) -> mamba2.MambaDims:
    return mamba2.MambaDims(
        d_model=cfg.d_model,
        d_inner=cfg.d_inner,
        n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
        chunk=cfg.ssm_chunk,
    )


# ---------------------------------------------------------------------------
# Parameter definitions: name -> (shape, dtype, logical_axes)
# ---------------------------------------------------------------------------

def block_param_defs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    dt = cfg.activation_dtype
    d, hd = cfg.d_model, cfg.head_dim
    defs: dict = {"ln1": ((d,), dt, (None,))}
    if spec.ffn:
        defs["ln2"] = ((d,), dt, (None,))

    if spec.mixer == "attn":
        defs.update({
            "wq": ((d, cfg.n_heads * hd), dt, (None, "heads")),
            "wk": ((d, cfg.n_kv_heads * hd), dt, (None, "heads")),
            "wv": ((d, cfg.n_kv_heads * hd), dt, (None, "heads")),
            "wo": ((cfg.n_heads * hd, d), dt, ("heads", None)),
        })
        if cfg.qkv_bias:
            defs.update({
                "bq": ((cfg.n_heads * hd,), dt, ("heads",)),
                "bk": ((cfg.n_kv_heads * hd,), dt, ("heads",)),
                "bv": ((cfg.n_kv_heads * hd,), dt, ("heads",)),
            })
    elif spec.mixer == "mamba":
        defs.update({f"ssm_{k}": v for k, v in mamba2.mamba_param_defs(mamba_dims(cfg), dt).items()})
    else:
        raise ValueError(spec.mixer)

    if not spec.ffn:
        return defs
    if spec.moe:
        md = moe_dims(cfg)
        shapes = moe_lib.moe_param_shapes(md, cfg.n_shared_experts, dt)
        logical = {
            "router": (None, None),
            "w_gate": ("expert", None, None),
            "w_up": ("expert", None, None),
            "w_down": ("expert", None, None),
            "shared_w_gate": (None, "ff"),
            "shared_w_up": (None, "ff"),
            "shared_w_down": ("ff", None),
        }
        defs.update({f"moe_{k}": (shp, dt_, logical[k]) for k, (shp, dt_) in shapes.items()})
    else:
        if cfg.mlp_variant == "swiglu":
            defs.update({
                "w_gate": ((d, cfg.d_ff), dt, (None, "ff")),
                "w_up": ((d, cfg.d_ff), dt, (None, "ff")),
                "w_down": ((cfg.d_ff, d), dt, ("ff", None)),
            })
        else:  # gelu MLP (hubert)
            defs.update({
                "w1": ((d, cfg.d_ff), dt, (None, "ff")),
                "b1": ((cfg.d_ff,), dt, ("ff",)),
                "w2": ((cfg.d_ff, d), dt, ("ff", None)),
                "b2": ((d,), dt, (None,)),
            })
    return defs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _rope_qk(cfg: ModelConfig, spec: LayerSpec, q, k, positions, positions3):
    if not spec.use_rope:
        return q, k
    if cfg.mrope:
        assert positions3 is not None
        return (apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections))
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


def _ffn(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    if spec.moe:
        moe_params = {k[len("moe_"):]: v for k, v in p.items() if k.startswith("moe_")}
        y = moe_lib.moe_ffn(moe_params, x.reshape(b * s, d), moe_dims(cfg), cfg.moe_impl)
        return y.reshape(b, s, d)
    if cfg.mlp_variant == "swiglu":
        h = swiglu(hint(x @ p["w_gate"], "batch", "seq", "ff"),
                   hint(x @ p["w_up"], "batch", "seq", "ff"))
        return h @ p["w_down"]
    h = jax.nn.gelu((x @ p["w1"]) + p["b1"])
    return (h @ p["w2"]) + p["b2"]


def block_forward(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: dict,
    h: jnp.ndarray,                  # [B,S,D]
    positions: jnp.ndarray,          # [B,S]
    positions3: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
):
    """Training/prefill forward for one block. Optionally returns the decode cache."""
    cache = None
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        q, k, v = _project_qkv(cfg, p, x)
        q, k = _rope_qk(cfg, spec, q, k, positions, positions3)
        # hint q on the fused head axis only; k/v keep the propagated kv-head
        # sharding (kv_heads may not divide the TP width — forcing it causes
        # involuntary reshards)
        q = hint(q, "batch", None, "heads", None)
        if spec.window is not None and cfg.causal:
            out = attn_lib.windowed_attention(q, k, v, positions=positions,
                                              window=spec.window, q_chunk=min(cfg.q_chunk, q.shape[1]))
        else:
            out = attn_lib.chunked_attention(q, k, v, positions_q=positions,
                                             positions_kv=positions, causal=cfg.causal,
                                             window=spec.window, chunk=cfg.attn_chunk)
        b, s, _, _ = out.shape
        mixer_out = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
        if return_cache:
            w = spec.window if spec.window is not None else None
            if w is not None and w < k.shape[1]:
                # ring state: scatter all positions into the ring; later writes win
                slots = positions % w
                kk = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[jnp.arange(b)[:, None], slots].set(k)
                vv = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[jnp.arange(b)[:, None], slots].set(v)
                pp = jnp.full((b, w), -1, jnp.int32).at[jnp.arange(b)[:, None], slots].set(positions)
                cache = {"k": kk, "v": vv, "pos": pp}
            else:
                cache = {"k": k, "v": v, "pos": positions}
    elif spec.mixer == "mamba":
        ssm_params = {k[len("ssm_"):]: v for k, v in p.items() if k.startswith("ssm_")}
        if return_cache:
            mixer_out, (conv_tail, state) = mamba2.mamba_forward(
                ssm_params, x, mamba_dims(cfg), return_cache=True)
            cache = {"conv": conv_tail, "state": state}
        else:
            mixer_out = mamba2.mamba_forward(ssm_params, x, mamba_dims(cfg))
    else:
        raise ValueError(spec.mixer)

    h = h + mixer_out
    if spec.ffn:
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + _ffn(cfg, spec, p, x)
    h = hint(h, "batch", "seq", None)
    return (h, cache) if return_cache else h


def block_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: dict,
    h: jnp.ndarray,                  # [B,1,D]
    cache: dict,
    positions: jnp.ndarray,          # [B,1]
    positions3: Optional[jnp.ndarray] = None,
):
    """One-token decode for one block; returns (h', cache')."""
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        q, k, v = _project_qkv(cfg, p, x)
        q, k = _rope_qk(cfg, spec, q, k, positions, positions3)
        w = cache["k"].shape[1]
        b = h.shape[0]
        slot = (positions[:, 0] % w).astype(jnp.int32)
        bidx = jnp.arange(b)
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        pos_cache = cache["pos"].at[bidx, slot].set(positions[:, 0])
        out = attn_lib.decode_attention(
            q, k_cache, v_cache, pos_cache, positions,
            window=spec.window, chunk=cfg.decode_chunk)
        mixer_out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    elif spec.mixer == "mamba":
        ssm_params = {k[len("ssm_"):]: v for k, v in p.items() if k.startswith("ssm_")}
        mixer_out, (ring, state) = mamba2.mamba_decode_step(
            ssm_params, x, (cache["conv"], cache["state"]), mamba_dims(cfg))
        new_cache = {"conv": ring, "state": state}
    else:
        raise ValueError(spec.mixer)

    h = h + mixer_out
    if spec.ffn:
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + _ffn(cfg, spec, p, x)
    return h, new_cache


def block_cache_defs(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int) -> dict:
    """name -> (shape, dtype) for one block's decode cache."""
    dt = cfg.activation_dtype
    if spec.mixer == "attn":
        w = min(spec.window, max_len) if spec.window is not None else max_len
        return {
            "k": ((batch, w, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": ((batch, w, cfg.n_kv_heads, cfg.head_dim), dt),
            "pos": ((batch, w), jnp.int32),
        }
    md = mamba_dims(cfg)
    return {
        "conv": ((batch, md.d_conv - 1, md.d_inner + 2 * md.d_state), dt),
        "state": ((batch, md.n_heads, md.head_dim, md.d_state), jnp.float32),
    }
