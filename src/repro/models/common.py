"""Shared model plumbing: logical-axis sharding hints, norms, initializers.

Sharding is expressed against *logical* axes ("batch", "seq", "heads", "ff",
"expert", "vocab", ...). The trainer/server installs a logical->mesh mapping
(contextvar); model code never mentions mesh axes. Outside any mapping (unit
tests, FL simulation) hints are no-ops, so the same model runs on one CPU
device unchanged.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

_AXIS_RULES: contextvars.ContextVar[Optional[Mapping[str, Optional[str]]]] = (
    contextvars.ContextVar("repro_axis_rules", default=None)
)
_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def axis_rules(rules: Mapping[str, Optional[str]], mesh=None):
    """Install logical->mesh axis mapping (e.g. {"heads": "model", "batch": "data"}).

    Under a partial-manual shard_map, pass only the *auto* axes (the manual axes
    are already fixed by the shard_map specs).
    """
    t1 = _AXIS_RULES.set(dict(rules))
    t2 = _MESH.set(mesh)
    try:
        yield
    finally:
        _AXIS_RULES.reset(t1)
        _MESH.reset(t2)


def hint(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """with_sharding_constraint against logical axes; no-op without rules.

    If two logical axes map to the same mesh axis (e.g. 'seq' and 'ff' both ->
    'model'), the LAST occurrence wins — feature dims trail sequence dims in
    our layouts, and Megatron-style layouts shard features inside blocks and
    sequence between them.
    """
    rules = _AXIS_RULES.get()
    if rules is None:
        return x
    spec = [rules.get(name) if name is not None else None for name in logical]
    seen = {}
    for i, s in enumerate(spec):
        if s is None:
            continue
        key = tuple(s) if isinstance(s, (list, tuple)) else s
        if key in seen:
            spec[seen[key]] = None  # earlier duplicate loses
        seen[key] = i
    if all(s is None for s in spec):
        return x
    from repro.dist import compat
    if compat.HAS_ABSTRACT_MESH_CTX:
        # Inside shard_map / set_mesh, the ambient mesh is an AbstractMesh
        # (with Manual axis types under shard_map); a NamedSharding built from
        # the concrete mesh MISMATCHES it and the constraint is dropped. A bare
        # PartitionSpec resolves against the ambient mesh, which is what we want.
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not getattr(am, "empty", False) and am.axis_names:
            return jax.lax.with_sharding_constraint(x, P(*spec))
        mesh = _MESH.get()
        if mesh is not None:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
        return x
    # jax 0.4.x: no ambient abstract mesh. Constraints may not name a manual
    # axis, and the compat shard_map takes EVERY mesh axis manual — null those
    # entries (the shard_map specs already fix their placement).
    from repro.dist.sharding import _entry_names
    manual = compat.manual_axis_names()
    if manual:
        spec = [None if s is not None and set(_entry_names(s)) & manual else s
                for s in spec]
        if all(s is None for s in spec):
            return x
    mesh = _MESH.get()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    return x


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Initializers (used by smoke tests / examples; dry-run uses eval_shape only)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub
