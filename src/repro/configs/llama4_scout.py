"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) per-expert
d_ff=8192 vocab=202048, MoE 16e top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Every layer is MoE (Scout's interleave step = 1) with sigmoid top-1 routing and
an always-on shared expert of the same width — 17B active / ~100B+ total.
Early-fusion frontend is a STUB (text-token path only; the multimodal
projector is out of scope). iRoPE chunked attention is not modeled => treated
as pure full attention, so long_500k is skipped (DESIGN.md §6).
Trains in ``streamed`` mode.
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        pattern=(LayerSpec(mixer="attn", moe=True),),
        n_experts=16,
        n_experts_padded=16,
        top_k=1,
        moe_d_ff=8192,
        n_shared_experts=1,
        router_act="sigmoid",
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn", moe=True),),
        n_experts=4,
        n_experts_padded=4,
        top_k=1,
        moe_d_ff=32,
        n_shared_experts=1,
        router_act="sigmoid",
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16, capacity_factor=4.0,
    )
