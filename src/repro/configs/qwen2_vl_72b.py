"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 —
M-RoPE (3-section rotary), dynamic resolution. [arXiv:2409.12191; hf]

Backbone only per the assignment: the vision frontend is a STUB —
``input_specs()`` provides precomputed patch/token embeddings [B, S, d_model]
plus the 3-channel M-RoPE position ids [B, S, 3]. head_dim = 8192/64 = 128;
M-RoPE sections (16, 24, 24) sum to head_dim/2. Pure full attention =>
long_500k skipped. Uses the streamed trainer (72B params).
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        pattern=(LayerSpec(mixer="attn"),),
        qkv_bias=True,
        input_kind="embeddings",
        mrope=True,
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn"),),
        qkv_bias=True,
        input_kind="embeddings",
        mrope=True,
        mrope_sections=(4, 2, 2),
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16,
    )
