"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only, wav2vec2-style transformer backbone. [arXiv:2106.07447; unverified]

Modality frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings [B, T, d_model] (the conv feature extractor is out of scope per the
assignment). Loss is per-frame unit classification over the 504-unit codebook
(the HuBERT masked-unit objective simplified to full-frame prediction).
Encoder-only => no decode shapes; gelu MLP, bidirectional attention.
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        pattern=(LayerSpec(mixer="attn"),),
        causal=False,
        input_kind="embeddings",
        mlp_variant="gelu",
        supports_decode=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=32,
        pattern=(LayerSpec(mixer="attn"),),
        causal=False,
        input_kind="embeddings",
        mlp_variant="gelu",
        supports_decode=False,
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16,
    )
