"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

62 = 10 x (5 local + 1 global) + 2 trailing local layers (tail_pattern).
Window 1024 (gemma3's sliding_window). Long-context decode is supported: 52/62
layers hold only a 1024-slot ring cache.
"""

from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn", window=1024)
_GLOBAL = LayerSpec(mixer="attn", window=None)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        tail_pattern=(_LOCAL, _LOCAL),
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke",
        family="dense",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn", window=8),) * 5 + (LayerSpec(mixer="attn"),),
        tail_pattern=(LayerSpec(mixer="attn", window=8),) * 2,
        tie_embeddings=True,
        supports_long_context=True,
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16,
    )
