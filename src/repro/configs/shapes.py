"""The assigned input-shape set and per-arch applicability rules.

Shapes (identical for all 10 LM-family archs):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (serve)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token, 32k cache)
  long_500k    seq 524,288 global_batch 1     -> serve_step (1 new token, 500k cache)

Skip rules (DESIGN.md §6): encoder-only archs have no decode; long_500k only for
archs with a sub-quadratic mechanism (SSM / hybrid / sliding-window).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: no sub-quadratic mechanism for 500k"
    if shape.kind == "prefill" and not cfg.supports_decode:
        # encoder: 'prefill' is just the 32k encoder forward (no cache emitted)
        return True, ""
    return True, ""


def all_cells(cfg: ModelConfig):
    """[(shape, runs, reason)] for the four assigned shapes."""
    out = []
    for s in SHAPES.values():
        runs, reason = applicable(cfg, s)
        out.append((s, runs, reason))
    return out
