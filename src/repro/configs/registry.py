"""Architecture registry: ``--arch <id>`` resolution, smoke variants, and the
per-arch execution profile (trainer mode, dry-run batch sharding)."""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs import (
    gemma3_27b,
    granite_34b,
    hubert_xlarge,
    jamba15_large,
    llama4_scout,
    mamba2_370m,
    qwen15_4b,
    qwen25_32b,
    qwen2_moe_a27b,
    qwen2_vl_72b,
)
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    module: object
    trainer_mode: str      # simple | streamed  (DESIGN.md §3)


_ENTRIES = [
    ArchEntry("gemma3-27b", gemma3_27b, "simple"),
    ArchEntry("qwen2.5-32b", qwen25_32b, "simple"),
    ArchEntry("granite-34b", granite_34b, "simple"),
    ArchEntry("qwen1.5-4b", qwen15_4b, "simple"),
    ArchEntry("mamba2-370m", mamba2_370m, "simple"),
    ArchEntry("hubert-xlarge", hubert_xlarge, "simple"),
    ArchEntry("qwen2-vl-72b", qwen2_vl_72b, "streamed"),
    ArchEntry("jamba-1.5-large-398b", jamba15_large, "streamed"),
    ArchEntry("qwen2-moe-a2.7b", qwen2_moe_a27b, "simple"),
    ArchEntry("llama4-scout-17b-a16e", llama4_scout, "streamed"),
]

REGISTRY = {e.arch_id: e for e in _ENTRIES}
ARCH_IDS = [e.arch_id for e in _ENTRIES]


def get_entry(arch_id: str) -> ArchEntry:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}") from None


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    e = get_entry(arch_id)
    return e.module.smoke_config() if smoke else e.module.config()


def trainer_mode(arch_id: str) -> str:
    return get_entry(arch_id).trainer_mode
