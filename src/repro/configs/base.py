"""Config schema for the architecture zoo.

A model is ``n_layers`` blocks arranged as ``n_repeats`` repetitions of a
``pattern`` (a tuple of LayerSpec). Homogeneous models have a length-1 pattern;
gemma3's 5:1 local:global is a length-6 pattern; jamba's attn:mamba 1:7 with
alternating MoE is a length-8 pattern. The training/serving loops scan over
repeats with stacked per-position parameters, so HLO size is O(|pattern|), not
O(n_layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"            # attn | mamba
    window: Optional[int] = None   # sliding-window width (attn only); None = global
    use_rope: bool = True
    moe: bool = False              # routed-experts FFN instead of dense
    ffn: bool = True               # False: mixer-only block (pure mamba2 stacks)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    tail_pattern: Tuple[LayerSpec, ...] = ()  # remainder blocks after the scan
    d_head: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    causal: bool = True
    input_kind: str = "tokens"     # tokens | embeddings (audio/vlm frontend stubs)
    mlp_variant: str = "swiglu"    # swiglu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False
    mrope_sections: Tuple[int, ...] = ()
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    n_experts_padded: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_act: str = "softmax"
    renorm_topk: bool = False
    moe_impl: str = "gather"
    # --- Mamba/SSD ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # --- execution ---
    dtype: str = "bfloat16"
    attn_chunk: int = 1024         # kv-chunk for global attention
    q_chunk: int = 512             # q-chunk for windowed attention
    loss_chunk: int = 512          # seq-chunk for the softmax-xent scan
    decode_chunk: int = 8192       # kv-chunk for decode attention
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save matmul outputs) — §Perf H4
    # which serving shapes are valid (see DESIGN.md §6 skip rules)
    supports_decode: bool = True
    supports_long_context: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.tail_pattern)
        assert body % len(self.pattern) == 0, (self.name, self.n_layers, len(self.pattern))
        return body // len(self.pattern)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic total parameter count (used for 6ND roofline sanity)."""
        from repro.models.model import Model  # local import to avoid cycle
        import jax
        import math
        shapes = Model(self).param_shapes()
        return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k routed experts)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        from repro.models.model import Model
        import jax
        import math
        shapes = Model(self).param_shapes()
        moe_leaves = 0
        routed_active = 0
        def walk(path, leaf):
            nonlocal moe_leaves, routed_active
            p = "/".join(str(k) for k in path)
            if ("moe_w_" in p) and "shared" not in p and self.n_experts > 0:
                if len(leaf.shape) >= 3:  # [R, E, ...] stacked expert weights
                    n = math.prod(leaf.shape)
                    moe_leaves += n
                    routed_active += n // self.n_experts_padded * self.top_k
        jax.tree_util.tree_map_with_path(walk, shapes)
        return total - moe_leaves + routed_active
