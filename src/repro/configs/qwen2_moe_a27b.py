"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) per-expert d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 routed experts padded to 64 for even expert-parallel sharding over the
16-way model axis (router never selects the 4 null experts). The shared-expert
block is a dense SwiGLU of width 4x1408 = 5632 (matching the HF
shared_expert_intermediate_size).
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        pattern=(LayerSpec(mixer="attn", moe=True),),
        qkv_bias=True,
        n_experts=60,
        n_experts_padded=64,
        top_k=4,
        moe_d_ff=1408,
        n_shared_experts=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn", moe=True),),
        qkv_bias=True,
        n_experts=6,
        n_experts_padded=8,
        top_k=4,
        moe_d_ff=32,
        n_shared_experts=2,
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16, capacity_factor=4.0,
    )
