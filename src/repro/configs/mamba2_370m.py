"""mamba2-370m [ssm]: 48L d_model=1024 attn-free, vocab=50280, ssm_state=128 —
SSD (state-space duality). [arXiv:2405.21060; unverified]

Pure mamba2 stack: mixer-only blocks (no FFN), tied embeddings.
Long-context decode is O(1)-state, so long_500k runs.
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=1,            # unused (attn-free)
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        pattern=(LayerSpec(mixer="mamba", ffn=False),),
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        tie_embeddings=True,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=256,
        pattern=(LayerSpec(mixer="mamba", ffn=False),),
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
        tie_embeddings=True,
        supports_long_context=True,
        dtype="float32",
        loss_chunk=16,
    )
