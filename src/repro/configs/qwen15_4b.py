"""qwen1.5-4b [dense]: 40L d_model=2560 20H (kv=20, full MHA) d_ff=6912
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_ff=6912,
        vocab_size=151936,
        pattern=(LayerSpec(mixer="attn"),),
        qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn"),),
        qkv_bias=True,
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16,
    )
