"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave. [arXiv:2403.19887; hf]

Pattern (length 8, repeated 9x): mamba at every position except index 4 (attn),
MoE FFN on odd positions, dense FFN on even — 9 attention layers (1:7), 36 MoE
layers, matching the Jamba block layout. Attention layers carry no RoPE (Jamba
relies on the Mamba layers for position information).

TPU adaptation: the Mamba mixers use our SSD (mamba2) formulation with
d_state=16 as in Jamba's Mamba config (Jamba uses Mamba-1 selective scan; SSD
is the MXU-native equivalent — see DESIGN.md). ~398B total params; trains in
``streamed`` mode (FSDP over data x model + per-superblock vote).
long_500k runs: only 9/72 layers hold a 500k KV cache.
"""

from repro.configs.base import LayerSpec, ModelConfig

_MAMBA_DENSE = LayerSpec(mixer="mamba")
_MAMBA_MOE = LayerSpec(mixer="mamba", moe=True)
_ATTN_DENSE = LayerSpec(mixer="attn", use_rope=False)


def _pattern():
    # positions 0..7; attn replaces mamba at position 4; MoE on odd positions
    out = []
    for i in range(8):
        if i == 4:
            out.append(_ATTN_DENSE)
        elif i % 2 == 1:
            out.append(_MAMBA_MOE)
        else:
            out.append(_MAMBA_DENSE)
    return tuple(out)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        pattern=_pattern(),
        n_experts=16,
        n_experts_padded=16,
        top_k=2,
        moe_d_ff=24576,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke",
        family="hybrid",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        pattern=tuple(
            LayerSpec(mixer="attn", use_rope=False) if i == 4
            else LayerSpec(mixer="mamba", moe=(i % 2 == 1))
            for i in range(8)
        ),
        n_experts=4,
        n_experts_padded=4,
        top_k=2,
        moe_d_ff=32,
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=8,
        supports_long_context=True,
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16, capacity_factor=4.0,
    )
