"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576
vocab=49152 — llama-arch code model. [arXiv:2405.04324; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        pattern=(LayerSpec(mixer="attn"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn"),),
        dtype="float32",
        attn_chunk=16, q_chunk=8, loss_chunk=16,
    )
