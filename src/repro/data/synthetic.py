"""Deterministic synthetic data pipelines (offline container: no datasets).

LM tokens: a seeded Zipfian-ish unigram stream with injected bigram structure so
losses actually *decrease* under training (pure uniform tokens give a flat
optimum at log V). Image-like data: class-conditional Gaussians over pixel
space with per-class means on a low-dimensional manifold — linearly separable
enough that the paper's ordering of methods is observable, hard enough that
convergence takes real optimization.

Every batch is a pure function of (seed, step) — restarts and elastic rescales
reproduce the exact same stream, which the fault-tolerance tests rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bigram_rank: int = 64     # structure strength


def _bigram_table(vocab: int, rank: int, seed: int) -> np.ndarray:
    """Low-rank 'next token' preference table (vocab -> preferred successor)."""
    rng = np.random.RandomState(seed ^ 0xB16_AA)
    return rng.randint(0, vocab, size=(rank,), dtype=np.int64)


def lm_batch(cfg: LMStreamConfig, step: int) -> dict:
    """One global batch: {'inputs','labels','positions'} int32 numpy arrays."""
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2**31 - 1))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # Zipf-ish marginal
    base = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64) % v
    # inject deterministic bigram structure on 50% of positions
    table = _bigram_table(v, cfg.bigram_rank, cfg.seed)
    follow = rng.rand(b, s) < 0.5
    nxt = table[base[:, :-1] % cfg.bigram_rank]
    seq = base.copy()
    seq[:, 1:][follow] = nxt[follow]
    inputs = seq[:, :-1].astype(np.int32)
    labels = seq[:, 1:].astype(np.int32)
    positions = np.broadcast_to(np.arange(s, dtype=np.int32), (b, s)).copy()
    return {"inputs": inputs, "labels": labels, "positions": positions}


def lm_stream(cfg: LMStreamConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


# ---------------------------------------------------------------------------
# Image-like classification data (paper experiments)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageDataConfig:
    n_classes: int = 10
    shape: tuple = (28, 28, 1)       # fashion-mnist-like; (32, 32, 3) cifar-like
    n_train: int = 10000
    n_test: int = 2000
    noise: float = 0.9
    seed: int = 0


def make_image_dataset(cfg: ImageDataConfig):
    """Returns (x_train, y_train, x_test, y_test) float32/int32 numpy arrays."""
    rng = np.random.RandomState(cfg.seed ^ 0x1A6E)
    d = int(np.prod(cfg.shape))
    # class means on a random low-dim manifold, normalized
    basis = rng.randn(16, d).astype(np.float32)
    codes = rng.randn(cfg.n_classes, 16).astype(np.float32)
    means = codes @ basis
    means /= np.linalg.norm(means, axis=1, keepdims=True)

    def sample(n, seed):
        r = np.random.RandomState(seed)
        y = r.randint(0, cfg.n_classes, size=n).astype(np.int32)
        x = means[y] + cfg.noise / np.sqrt(d) * r.randn(n, d).astype(np.float32)
        return x.reshape((n,) + cfg.shape).astype(np.float32), y

    x_tr, y_tr = sample(cfg.n_train, cfg.seed + 1)
    x_te, y_te = sample(cfg.n_test, cfg.seed + 2)
    return x_tr, y_tr, x_te, y_te
