"""Heterogeneous federated partitions: Dirichlet(alpha) label skew
(Hsu et al. 2019), exactly as the paper's §6.2 setup."""

from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_workers: int, alpha: float,
                        seed: int = 0, min_per_worker: int = 8) -> list[np.ndarray]:
    """Returns per-worker index arrays. Each worker's class mix ~ Dir(alpha);
    alpha -> 0 = single-class workers, alpha -> inf = IID."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    props = rng.dirichlet([alpha] * n_classes, size=n_workers)  # [M, C]
    # normalize per class so every example is assigned exactly once
    class_share = props / np.maximum(props.sum(axis=0, keepdims=True), 1e-12)
    workers: list[list[int]] = [[] for _ in range(n_workers)]
    for c in range(n_classes):
        counts = np.floor(class_share[:, c] * len(by_class[c])).astype(int)
        # distribute remainder deterministically
        rem = len(by_class[c]) - counts.sum()
        order = np.argsort(-class_share[:, c])
        counts[order[:rem]] += 1
        start = 0
        for m in range(n_workers):
            workers[m].extend(by_class[c][start:start + counts[m]])
            start += counts[m]
    out = []
    all_idx = np.arange(len(labels))
    for m in range(n_workers):
        idx = np.array(sorted(workers[m]), dtype=np.int64)
        if len(idx) < min_per_worker:  # top up uniformly (paper keeps all workers active)
            extra = rng.choice(all_idx, size=min_per_worker - len(idx), replace=False)
            idx = np.concatenate([idx, extra])
        out.append(idx)
    return out


def heterogeneity_stats(labels: np.ndarray, parts: list[np.ndarray]) -> dict:
    n_classes = int(labels.max()) + 1
    ent = []
    for idx in parts:
        p = np.bincount(labels[idx], minlength=n_classes).astype(float)
        p /= max(p.sum(), 1.0)
        ent.append(-np.sum(p * np.log(np.maximum(p, 1e-12))))
    return {"mean_label_entropy": float(np.mean(ent)),
            "max_entropy": float(np.log(n_classes))}
