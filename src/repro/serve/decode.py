"""Serving step builders (prefill / decode) — plain jit + GSPMD.

The paper's technique lives in the training exchange; serving is included to
prove the parallelism layer covers the assigned inference shapes. Decode cells
lower ``serve_step`` = one new token against a seq_len-deep cache; long_500k
(batch 1) shards the cache *sequence* axis across the worker axes and lets
GSPMD insert the distributed-softmax reductions.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.dist.sharding import ACT_RULES_SERVE, cache_shardings_tree, tp_param_shardings
from repro.models.common import axis_rules
from repro.models.model import Model


def build_decode_step(model: Model, mesh, *, worker_axes: Sequence[str] = ("data",),
                      shard_seq: bool = False):
    """Returns (jit'd step, params_shardings, cache_shardings_builder)."""
    rules = dict(ACT_RULES_SERVE)
    rules["batch"] = tuple(worker_axes) if not shard_seq else None

    def step(params, caches, batch):
        with axis_rules(rules, mesh):
            return model.decode_step(params, caches, batch)

    return jax.jit(step, donate_argnums=(1,))


def build_prefill(model: Model, mesh, *, worker_axes: Sequence[str] = ("data",),
                  with_cache: bool = True):
    rules = dict(ACT_RULES_SERVE)
    rules["batch"] = tuple(worker_axes)

    if with_cache and model.cfg.supports_decode:
        def step(params, batch):
            with axis_rules(rules, mesh):
                h, caches = model.prefill(params, batch)
                logits = (h[:, -1] @ model.head_weight(params)).astype(jnp.float32)
                return logits, caches
    else:
        # encoder-only 'prefill': the full forward + per-frame logits-loss probe
        def step(params, batch):
            with axis_rules(rules, mesh):
                h = model.forward_hidden(params, batch)
                return model.head_loss(params, h, batch["labels"])

    return jax.jit(step)


def serve_input_specs(cfg, shape, *, mesh, worker_axes=("data",), shard_seq=False):
    """ShapeDtypeStructs (with shardings) for one decode cell: (params, caches, batch)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = Model(cfg)
    b = shape.global_batch
    wa = tuple(worker_axes) if len(worker_axes) > 1 else worker_axes[0]

    params_sh = tp_param_shardings(model, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        model.param_shapes(), params_sh)

    cache_shapes = model.cache_shapes(b, shape.seq_len)
    cache_sh = cache_shardings_tree(cache_shapes, mesh, worker_axes=worker_axes,
                                    shard_seq=shard_seq)
    cache_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cache_sh)

    bspec = P(wa) if not shard_seq else P()
    bsh = NamedSharding(mesh, bspec)
    if cfg.input_kind == "tokens":
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsh)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.activation_dtype, sharding=bsh)
    batch_sds = {
        "inputs": inputs,
        "positions": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsh),
    }
    if cfg.mrope:
        batch_sds["positions3"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32, sharding=bsh)
    return params_sds, cache_sds, batch_sds
