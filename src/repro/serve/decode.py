"""Serving step builders (prefill / decode) — plain jit + GSPMD — plus online
weight-update ingestion over the training wire.

The paper's technique lives in the training exchange; serving is included to
prove the parallelism layer covers the assigned inference shapes. Decode cells
lower ``serve_step`` = one new token against a seq_len-deep cache; long_500k
(batch 1) shards the cache *sequence* axis across the worker axes and lets
GSPMD insert the distributed-softmax reductions.

``build_update_ingest`` keeps a serving fleet in lockstep with a live training
job: the trainer broadcasts each round's server *decision* — the quorum-gated
sign of the vote sum, a ternary tensor shipped on the same 2-bit packed wire
format the uplink uses (0.25 B/coord downlink), or, for mean-server trainers
whose decision is a float delta, the qsgd8-quantized 8-bit ``packed8`` wire
(1 B/coord + one f32 scale, ``encode_weight_update8``) — and every replica
applies it through ``engine.server_apply``, i.e. the identical fused kernels
the trainers run. Replica params therefore stay bitwise equal to the training
params (2-bit wire) or quantization-faithful to them (8-bit float deltas)
without ever shipping weights.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.algorithm import CompressionConfig
from repro.dist.sharding import ACT_RULES_SERVE, cache_shardings_tree, tp_param_shardings
from repro.models.common import axis_rules
from repro.models.model import Model


def build_decode_step(model: Model, mesh, *, worker_axes: Sequence[str] = ("data",),
                      shard_seq: bool = False):
    """Returns (jit'd step, params_shardings, cache_shardings_builder)."""
    rules = dict(ACT_RULES_SERVE)
    rules["batch"] = tuple(worker_axes) if not shard_seq else None

    def step(params, caches, batch):
        with axis_rules(rules, mesh):
            return model.decode_step(params, caches, batch)

    return jax.jit(step, donate_argnums=(1,))


def build_prefill(model: Model, mesh, *, worker_axes: Sequence[str] = ("data",),
                  with_cache: bool = True):
    rules = dict(ACT_RULES_SERVE)
    rules["batch"] = tuple(worker_axes)

    if with_cache and model.cfg.supports_decode:
        def step(params, batch):
            with axis_rules(rules, mesh):
                h, caches = model.prefill(params, batch)
                logits = (h[:, -1] @ model.head_weight(params)).astype(jnp.float32)
                return logits, caches
    else:
        # encoder-only 'prefill': the full forward + per-frame logits-loss probe
        def step(params, batch):
            with axis_rules(rules, mesh):
                h = model.forward_hidden(params, batch)
                return model.head_loss(params, h, batch["labels"])

    return jax.jit(step)


def encode_weight_update8(update: jnp.ndarray, *, seed, counter_base=0,
                          backend: Optional[str] = None):
    """Trainer-side 8-bit downlink encoder: a float server update tensor ->
    ``(payload, scale)`` where ``payload`` is the canonical (rows, LANES) int8
    sign*level view (1 B/coord) and ``scale`` the f32 decode scale — the
    qsgd8 quantizer applied to the *downlink*, for mean-server trainers whose
    decision is a float delta rather than a ternary sign. The replica applies
    ``p - lr * scale * levels`` via ``build_update_ingest(wire='packed8')``,
    stochastic-rounding driven by the same counter stream as the uplink."""
    from repro.core.compressors import qsgd8_scale
    from repro.kernels import common as kcommon
    from repro.kernels.pack8.ops import qsgd8_pack8_op
    from repro.kernels.pack8.ref import qsgd8_levels_ref

    backend = engine.resolve_backend(backend)
    scale = qsgd8_scale(update)
    if backend == "jnp":
        levels = qsgd8_levels_ref(update, scale, seed, counter_base)
        payload, _ = kcommon.to_2d(levels.reshape(-1))
    else:
        payload = qsgd8_pack8_op(update, scale, seed, counter_base,
                                 interpret=(backend == "interpret"))
    return payload, scale.astype(jnp.float32)


def encode_weight_update(vote_sum: jnp.ndarray, *, quorum: int = 1,
                         backend: Optional[str] = None) -> jnp.ndarray:
    """Trainer-side downlink encoder: integer vote sum -> 2-bit packed ternary
    decision, ``where(|v| >= quorum, sign(v), 0)`` in the pack2bit canonical
    wire format. ``build_update_ingest`` is the inverse+apply. For scaled
    servers the per-round decode scale rides next to the payload (one f32),
    exactly like the uplink's ``CompressedGrad.scale`` — pass it to the ingest
    step as ``scales``."""
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import pack2bit_op
    from repro.kernels.pack2bit.ref import pack2bit_ref

    backend = engine.resolve_backend(backend)
    v = vote_sum.astype(jnp.int32)
    step = jnp.where(jnp.abs(v) >= quorum, jnp.sign(v), 0).astype(jnp.int8)
    if backend == "jnp":
        view, _ = kcommon.to_2d(step.reshape(-1))
        return pack2bit_ref(view)
    return pack2bit_op(step, interpret=(backend == "interpret"))


def build_update_ingest(model: Model, mesh, *, lr, quorum: int = 1,
                        wire: str = "packed2bit", backend: Optional[str] = None,
                        donate: bool = True):
    """jit'd ``(params, updates, scales=None) -> params``: online weight-update
    ingestion routed through ``engine.server_apply`` (the fused vote_update
    path).

    ``wire`` selects the downlink message format per leaf:
      - ``"packed2bit"``: uint8 (rows, LANES//4) canonical views from
        ``encode_weight_update`` — 0.25 B/coord on the wire; decoded by the
        fused unpack kernel (backend-dispatched) straight into the update.
      - ``"packed8"``: int8 (rows, LANES) canonical sign*level views from
        ``encode_weight_update8`` — 1 B/coord; ``scales`` is REQUIRED (the
        qsgd8 decode scale per leaf) and the replica applies the dequantized
        float delta ``p - lr * scale * levels`` (mean rule, n_sel=1).
      - ``"int8"``: raw ternary (or small-int vote-sum) tensors in leaf shape.

    ``scales`` (optional pytree of f32 scalars matching ``params``) carries a
    shared per-leaf decode scale next to the ternary payload — the downlink
    twin of a scale-carrying compressor's ``CompressedGrad.scale`` (TernGrad's
    magnitude-shared s_t); the replica applies ``p - lr * scale * decision``.
    Without it, decisions apply at unit scale (the sign-family servers).

    The quorum deadband is applied by whichever side signs: packed updates
    arrive already ternary (the encoder gated them), so they are applied with
    quorum 1; int wires carry the raw sums and are gated here. Both routes are
    bitwise-identical to the trainer's own ``server_apply``.
    """
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import unpack2bit_op
    from repro.kernels.pack2bit.ref import unpack2bit_ref

    if wire not in ("packed2bit", "packed8", "int8"):
        raise ValueError(
            f"unknown update wire {wire!r}; known: packed2bit | packed8 | int8")
    if wire == "packed2bit" and quorum != 1:
        raise ValueError(
            "the packed2bit wire carries already-gated ternary decisions — "
            "apply the quorum deadband trainer-side in encode_weight_update"
            "(vote_sum, quorum=...); a replica-side quorum here would be "
            "silently ignored. Use wire='int8' to gate on the replica.")
    if wire == "packed8" and quorum != 1:
        raise ValueError(
            "the packed8 wire carries dequantized float deltas (sign*level * "
            "scale), not votes — a quorum deadband does not apply. Use a "
            "ternary wire to gate updates.")
    backend = engine.resolve_backend(backend)
    # the ingest config only selects the server rule; the decision tensor is
    # compressor-agnostic (any ternary uplink produces the same wire format)
    cfg = CompressionConfig(server="majority_vote")

    def ingest(params, updates, scales=None):
        def leaf(p, u, scale=None):
            if wire == "packed8":
                # 8-bit downlink: canonical int8 sign*level view -> leaf
                # levels; the mean rule with n_sel=1 applies the dequantized
                # delta p - lr * scale * levels
                levels = kcommon.from_2d(u, p.size, p.shape)
                new_p, _ = engine.server_apply(
                    p, levels, cfg, lr=lr, server="mean", n_sel=1.0,
                    scale=scale, backend=backend)
                return new_p
            if wire == "packed2bit":
                if backend == "jnp":
                    votes = kcommon.from_2d(unpack2bit_ref(u), p.size, p.shape)
                else:
                    votes = unpack2bit_op(u, p.size, p.shape,
                                          interpret=(backend == "interpret"))
                q = 1   # the encoder already applied the deadband
            else:
                votes, q = u, quorum
            if scale is not None:
                # scaled downlink (packed2bit only): the payload is already the
                # gated aggregate ternary decision, so the mean rule with
                # n_sel=1 applies p - lr * scale * decision
                new_p, _ = engine.server_apply(
                    p, votes, cfg, lr=lr, server="mean", n_sel=1.0,
                    scale=scale, backend=backend)
                return new_p
            new_p, _ = engine.server_apply(p, votes, cfg, lr=lr, quorum=q,
                                           backend=backend)
            return new_p
        if wire == "packed8":
            if scales is None:
                raise ValueError(
                    "the packed8 downlink is meaningless without its decode "
                    "scales — pass the per-leaf f32 scales from "
                    "encode_weight_update8")
            return jax.tree_util.tree_map(leaf, params, updates, scales)
        if scales is None:
            return jax.tree_util.tree_map(leaf, params, updates)
        if wire != "packed2bit":
            raise ValueError(
                "scaled ingestion needs the packed2bit wire (already-"
                "aggregated ternary decisions); the int8 wire carries raw "
                "vote sums whose scale-free gating happens replica-side")
        return jax.tree_util.tree_map(leaf, params, updates, scales)

    return jax.jit(ingest, donate_argnums=(0,) if donate else ())


def serve_input_specs(cfg, shape, *, mesh, worker_axes=("data",), shard_seq=False):
    """ShapeDtypeStructs (with shardings) for one decode cell: (params, caches, batch)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = Model(cfg)
    b = shape.global_batch
    wa = tuple(worker_axes) if len(worker_axes) > 1 else worker_axes[0]

    params_sh = tp_param_shardings(model, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        model.param_shapes(), params_sh)

    cache_shapes = model.cache_shapes(b, shape.seq_len)
    cache_sh = cache_shardings_tree(cache_shapes, mesh, worker_axes=worker_axes,
                                    shard_seq=shard_seq)
    cache_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cache_sh)

    bspec = P(wa) if not shard_seq else P()
    bsh = NamedSharding(mesh, bspec)
    if cfg.input_kind == "tokens":
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsh)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.activation_dtype, sharding=bsh)
    batch_sds = {
        "inputs": inputs,
        "positions": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsh),
    }
    if cfg.mrope:
        batch_sds["positions3"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32, sharding=bsh)
    return params_sds, cache_sds, batch_sds
