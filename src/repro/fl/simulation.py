"""M-worker federated simulation of Algorithms 1 & 2 — the paper's §6 engine.

One jit'd round on flattened parameter vectors:

  select |S| workers -> each runs tau compressed local steps (Alg. 2) or one
  gradient (Alg. 1) -> uplink Q(., B_g) -> server mean + C(.) [+ EF] -> update.

Workers are vmapped; per-worker batches are drawn from Dirichlet-partitioned
shards with per-(round, worker) seeds, so runs are deterministic end-to-end.
The same core.algorithm compressors drive the mesh trainers — this module IS
the paper's experiment, the trainers are its production deployment.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, prng
from repro.core.algorithm import (UPLINK_SALT, CompressionConfig,
                                  local_update_source)
from repro.core.encoding import baseline_bits_per_round
from repro.fl.models import accuracy, xent_loss


@dataclasses.dataclass
class FLConfig:
    n_workers: int = 100
    participation: float = 1.0      # fraction sampled per round
    rounds: int = 200
    batch_size: int = 128
    lr: float = 0.01                # eta: THE server step size (Alg. 1/2 line 12)
    local_lr: float = 0.01          # eta_L: the inner local step size (Alg. 2 only)
    comp: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)
    seed: int = 0
    eval_every: int = 10
    quorum: int = 1                 # vote-server deadband (majority_vote only)
    # elastic participation (any set -> weighted, participation-normalized
    # aggregation): per-GLOBAL-worker vote weights (len n_workers), a quorum
    # expressed as a fraction of realized participation W, and a per-round
    # report-dropout rate on TOP of sampling (chaos: crashed/straggling
    # reporters). None/0.0 everywhere = the legacy fixed-count path.
    worker_weights: Optional[tuple] = None
    q_frac: Optional[float] = None
    dropout: float = 0.0


def _worker_batch_idx(key, shard_sizes, batch):
    """Per-worker minibatch indices into each worker's shard (uniform w/ repl.)."""
    return jax.random.randint(key, (batch,), 0, shard_sizes)


def build_round_fn(loss_fn: Callable, cfg: FLConfig, x_parts, y_parts):
    """x_parts: [M, shard, ...] stacked per-worker data (padded to equal shard).

    Worker compression and server math both route through the shared engine
    (core.engine / core.algorithm) — this module owns only the experiment
    harness: worker sampling, per-worker data draws, the magnitude-sharing
    max over the sampled set, and eval bookkeeping. The server step uses
    exactly eta = cfg.lr; cfg.local_lr is eta_L, consumed only by the Alg. 2
    inner loop inside local_update_source.
    """
    comp = cfg.comp
    backend = engine.resolve_backend()
    server_rule = comp.server if engine.is_vote_server(comp) else "mean"
    share_linf = engine.needs_shared_linf(comp)
    m = cfg.n_workers
    n_sel = max(1, int(round(cfg.participation * m)))
    shard_len = x_parts.shape[1]
    # elastic participation: any elastic field set switches the aggregation
    # to the weighted, participation-normalized form (same ParticipationSpec
    # validation the mesh trainers use — loud and build-time)
    spec = None
    if (cfg.worker_weights is not None or cfg.q_frac is not None
            or cfg.dropout > 0.0):
        from repro.dist import collectives
        spec = collectives.ParticipationSpec(
            weights=cfg.worker_weights, q_frac=cfg.q_frac,
            dropout=cfg.dropout)
        engine.check_participation_server(server_rule, comp.compressor)
        if spec.weights is not None and len(spec.weights) != m:
            raise ValueError(
                f"worker_weights cover {len(spec.weights)} workers but the "
                f"simulation has n_workers={m} (weights are per GLOBAL "
                f"worker id, not per sampled slot)")
        # the quorum normalizes to whoever reports: a fraction of W, not a
        # fixed count out of |S|
        q_frac = spec.resolve_q_frac(cfg.quorum, n_sel)

    def worker_source(v, widx, key, round_idx):
        """One worker's uplink *input* (gradient, or Alg. 2 local-step sum)
        plus its uplink stream seed. Splitting source from Q(.) lets the
        shared_max protocol (TernGrad, Appendix B) reduce max_m ||src_m||_inf
        over the sampled workers before anyone quantizes."""
        wseed = prng.fold_seed(jnp.uint32(cfg.seed), 0x5EED) + widx.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        wseed = wseed + round_idx.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)

        def grad_at(w, salt):
            kb = jax.random.fold_in(key, salt)
            idx = jax.random.randint(kb, (cfg.batch_size,), 0, shard_len)
            xb = x_parts[widx][idx]
            yb = y_parts[widx][idx]
            return jax.grad(loss_fn)(w, xb, yb)

        if comp.local_steps == 1:
            return grad_at(v, 0), wseed
        src = local_update_source(v, lambda w, c: grad_at(w, c + 1), comp,
                                  eta_l=cfg.local_lr, seed=wseed, backend=backend)
        return src, prng.fold_seed(wseed, UPLINK_SALT)

    def worker_msg(src, seed, shared):
        """Q(src, B): one worker's decoded uplink message + stats."""
        msg = engine.compress_leaf(src, comp, seed, shared_linf=shared,
                                   backend=backend)
        dec = msg.values.astype(jnp.float32) * msg.scale
        nnz = jnp.sum(jnp.abs(jnp.sign(msg.values)).astype(jnp.float32))
        return dec, nnz

    @jax.jit
    def round_fn(v, ef, round_idx, key):
        ksel, kw = jax.random.split(jax.random.fold_in(key, round_idx))
        sel = jax.random.permutation(ksel, m)[:n_sel]
        keys = jax.random.split(kw, n_sel)
        srcs, seeds = jax.vmap(lambda w, k: worker_source(v, w, k, round_idx))(sel, keys)
        if spec is not None:
            # the reporting set is the sampled set minus chaos dropouts;
            # w_eff = static per-worker weight x report bit (exact 0.0 for a
            # silent worker, so its message contributes exact zeros)
            from repro.train import sampling
            rmask = jax.vmap(lambda w: sampling.report_mask(
                jnp.uint32(cfg.seed), round_idx, w, spec.dropout))(sel)
            w_eff = (spec.weights_array(m)[sel]
                     * rmask.astype(jnp.float32))
        # the magnitude-sharing all-reduce(max) over the sampled set S
        # (elastic: over the REPORTING set — a crashed worker's magnitude
        # cannot ride a wire it never sent)
        if share_linf:
            mags = jnp.max(jnp.abs(srcs.astype(jnp.float32)),
                           axis=tuple(range(1, srcs.ndim)))
            if spec is not None:
                mags = jnp.where(rmask, mags, 0.0)
            shared = jnp.max(mags)
        else:
            shared = None
        dec, nnz = jax.vmap(lambda s, sd: worker_msg(s, sd, shared))(srcs, seeds)
        if spec is not None:
            # weighted vote: sum_m w_m * msg_m over reporters, normalized to
            # the realized participation W = sum_reporting w_m
            wv = jnp.sum(dec * w_eff[:, None], axis=0)
            wtot = jnp.sum(w_eff)
            if server_rule == "majority_vote":
                v, ef = engine.server_apply(
                    v, wv, comp, lr=cfg.lr, ef=ef, part_total=wtot,
                    q_frac=q_frac, backend=backend)
            else:
                v, ef = engine.server_apply(
                    v, wv, comp, lr=cfg.lr, ef=ef, n_sel=wtot,
                    server="mean", backend=backend)
            return v, ef, jnp.mean(nnz * rmask.astype(jnp.float32))
        vote_sum = jnp.sum(dec, axis=0)
        v, ef = engine.server_apply(
            v, vote_sum, comp, lr=cfg.lr, ef=ef, n_sel=jnp.float32(n_sel),
            server=server_rule, quorum=cfg.quorum, backend=backend)
        return v, ef, jnp.mean(nnz)

    return round_fn


def run_fl(
    v0: jnp.ndarray,
    apply_fn: Callable,
    cfg: FLConfig,
    x_parts: np.ndarray, y_parts: np.ndarray,
    x_test: np.ndarray, y_test: np.ndarray,
    *,
    log: Optional[Callable[[str], None]] = None,
) -> dict:
    """Returns {'acc': [(round, acc)], 'bits_per_round': float, 'final_acc': float}."""
    loss_fn = xent_loss(apply_fn)
    round_fn = build_round_fn(loss_fn, cfg, jnp.asarray(x_parts), jnp.asarray(y_parts))
    v = v0
    ef = jnp.zeros_like(v0)
    key = jax.random.PRNGKey(cfg.seed)
    accs, nnzs = [], []
    d = int(v0.size)
    for r in range(cfg.rounds):
        v, ef, nnz = round_fn(v, ef, jnp.int32(r), key)
        nnzs.append(float(nnz))
        if (r + 1) % cfg.eval_every == 0 or r == cfg.rounds - 1:
            acc = accuracy(apply_fn, v, jnp.asarray(x_test), jnp.asarray(y_test))
            accs.append((r + 1, acc))
            if log:
                log(f"[fl] round {r+1}: acc={acc:.4f} nnz={nnz:.0f}")
    mean_nnz = float(np.mean(nnzs)) if nnzs else 0.0
    # spec-driven bit model: uplink_bits on the registry row picks golomb
    # ternary coding vs dense sign vs level8 vs fp32 — no name branching
    bits = baseline_bits_per_round(d, cfg.comp.compressor, nnz=mean_nnz)
    n_sel = max(1, int(round(cfg.participation * cfg.n_workers)))
    return {
        "acc": accs,
        "final_acc": accs[-1][1] if accs else float("nan"),
        "mean_nnz": mean_nnz,
        "uplink_bits_per_round": bits * n_sel,
        "d": d,
    }


def stack_partitions(x, y, parts) -> tuple[np.ndarray, np.ndarray]:
    """Per-worker shards stacked to [M, shard_max, ...] (wrap-padded)."""
    shard = max(len(p) for p in parts)
    xs, ys = [], []
    for idx in parts:
        reps = np.resize(idx, shard)
        xs.append(x[reps])
        ys.append(y[reps])
    return np.stack(xs), np.stack(ys)
