"""Small models for the paper's §6 experiments, on flattened parameter vectors.

The FL simulation works on a single ravelled parameter vector per worker (the
paper's math is coordinate-wise), so models expose init -> (vector, apply_fn).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def mlp_fashion(key, in_dim: int = 784, hidden=(256, 128), n_classes: int = 10):
    """The paper's Fashion-MNIST net: 784-256-128-10 MLP with ReLU."""
    ks = jax.random.split(key, len(hidden) + 1)
    dims = (in_dim,) + tuple(hidden) + (n_classes,)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(ks[i], (a, b)) * (a ** -0.5)
        params[f"b{i}"] = jnp.zeros((b,))
    vec, unravel = ravel_pytree(params)
    n_layers = len(dims) - 1

    def apply_fn(v, x):
        p = unravel(v)
        h = x.reshape(x.shape[0], -1)
        for i in range(n_layers):
            h = h @ p[f"w{i}"] + p[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return vec, apply_fn


def cnn_cifar(key, shape=(32, 32, 3), n_classes: int = 10, width: int = 32):
    """Reduced VGG-style CNN for the CIFAR-10 analog (VGG-9 scaled down for the
    1-core CPU budget; same block structure: 2 conv blocks + dense)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c = shape[-1]
    params = {
        "c1": jax.random.normal(k1, (3, 3, c, width)) * (9 * c) ** -0.5,
        "c2": jax.random.normal(k2, (3, 3, width, 2 * width)) * (9 * width) ** -0.5,
        "w1": jax.random.normal(k3, ((shape[0] // 4) * (shape[1] // 4) * 2 * width, 128))
               * ((shape[0] // 4) * (shape[1] // 4) * 2 * width) ** -0.5,
        "b1": jnp.zeros((128,)),
        "w2": jax.random.normal(k4, (128, n_classes)) * 128 ** -0.5,
        "b2": jnp.zeros((n_classes,)),
    }
    vec, unravel = ravel_pytree(params)

    def apply_fn(v, x):
        p = unravel(v)
        h = x
        h = jax.lax.conv_general_dilated(h, p["c1"], (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = jax.lax.conv_general_dilated(h, p["c2"], (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    return vec, apply_fn


def xent_loss(apply_fn: Callable):
    def loss(v, x, y):
        logits = apply_fn(v, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - tgt)
    return loss


def accuracy(apply_fn: Callable, v, x, y, batch: int = 512) -> float:
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = apply_fn(v, x[i:i + batch])
        correct += int((jnp.argmax(logits, -1) == y[i:i + batch]).sum())
    return correct / n
