"""§6.1: Rosenbrock minimization with 100 heterogeneous workers (Figs 1-2).

Heterogeneity: worker m sees v_m * F(.) with sum(v_m) = 1 and 80 of 100 v_m
negative (Eq. 11) — the signs of 80 workers' gradients OPPOSE the true
gradient, the adversarial regime where deterministic signSGD provably
diverges and sparsign's magnitude-awareness saves the vote.

Note: the paper's Eq. 10 prints F_i = 100(x_{i+1} - x_i^2) + (1 - x_i)^2 —
missing the square on the first term vs the standard Rosenbrock used by
Safaryan & Richtarik; we implement the standard form (their reference).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prng
from repro.core.compressors import get_spec


def rosenbrock(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2 + (1.0 - x[:-1]) ** 2)


def make_heterogeneity(m: int = 100, n_neg: int = 80, seed: int = 0,
                       neg_mass: float = 0.8) -> np.ndarray:
    """v with sum=1 and n_neg negative entries (Eq. 11).

    The paper's construction fixes only the count and the sum; the regime its
    figures show is 'many wrong signs, little wrong mass': 80 workers carry
    negative scales of small total magnitude (neg_mass), the 20 positive
    workers carry 1 + neg_mass. Majority-by-heads (signSGD) is then wrong with
    probability ~1 while magnitude-weighted voting (sparsign) recovers the true
    sign — exactly the separation Fig. 1 plots.
    """
    rng = np.random.RandomState(seed)
    neg = rng.uniform(0.5, 1.5, size=n_neg)
    neg *= neg_mass / neg.sum()
    pos = rng.uniform(0.5, 1.5, size=m - n_neg)
    pos *= (1.0 + neg_mass) / pos.sum()
    v = np.concatenate([-neg, pos])
    rng.shuffle(v)
    return v


@dataclasses.dataclass
class RosenbrockResult:
    values: np.ndarray          # F(x_t)
    wrong_agg: np.ndarray       # per-round wrong-aggregation probability
    x_final: np.ndarray


def run(
    compressor: str = "sparsign",
    budget: float = 0.01,
    *,
    m: int = 100,
    n_sel: int = 10,
    rounds: int = 300,
    d: int = 10,
    lr: float = 2e-4,
    seed: int = 0,
) -> RosenbrockResult:
    """signSGD ('sign') vs SPARSIGNSGD ('sparsign') under Eq. 11 heterogeneity."""
    v_scales = jnp.asarray(make_heterogeneity(m, seed=seed))
    x = jnp.full((d,), -0.5)
    grad_f = jax.grad(rosenbrock)
    key = jax.random.PRNGKey(seed)
    # spec lookup, not name branching: any ternary registry row votes here
    # ('sign' ignores budget/seed by its own signature — same bits as before)
    spec = get_spec(compressor)

    @jax.jit
    def round_fn(x, r, key):
        g_true = grad_f(x)                        # true global gradient direction
        g_workers = v_scales[:, None] * g_true[None, :]   # [M, d]
        ksel = jax.random.fold_in(key, r)
        sel = jax.random.permutation(ksel, m)[:n_sel]
        mask = jnp.zeros((m,), bool).at[sel].set(True)

        def msg(gm, widx):
            wseed = prng.fold_seed(jnp.uint32(seed), 7) + widx.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) \
                    + jnp.uint32(r) * jnp.uint32(0x85EBCA6B)
            return spec.api(gm, budget=budget, seed=wseed).values

        votes = jax.vmap(msg)(g_workers, jnp.arange(m))   # [M, d] int8
        votes = jnp.where(mask[:, None], votes, jnp.int8(0))
        vote_sum = jnp.sum(votes.astype(jnp.int32), axis=0)
        agg = jnp.sign(vote_sum)
        wrong = jnp.mean((agg != jnp.sign(g_true)).astype(jnp.float32))
        x = x - lr * agg.astype(x.dtype)
        return x, wrong

    values, wrongs = [], []
    for r in range(rounds):
        x, wrong = round_fn(x, jnp.int32(r), key)
        values.append(float(rosenbrock(x)))
        wrongs.append(float(wrong))
    return RosenbrockResult(values=np.array(values), wrong_agg=np.array(wrongs),
                            x_final=np.asarray(x))
