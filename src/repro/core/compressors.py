"""Gradient compressors: the paper's ``sparsign`` (Def. 1) plus every baseline
from §6 / Appendix B, as pure composable JAX functions.

All worker-side compressors share the signature::

    compress(g, *, budget, seed, counter_base=0) -> CompressedGrad

where ``g`` is a float array, ``budget`` the paper's ``B`` (scalar or per-coord),
``seed`` a uint32 stream seed and ``counter_base`` the logical index of g's first
coordinate (used when a large tensor is compressed shard-by-shard so that every
coordinate keeps its layout-invariant Bernoulli draw).

Ternary compressors return int8 arrays with values in {-1, 0, +1}; the wire
scaling (if any — TernGrad/QSGD rescale by a norm) is carried separately in
``scale`` so that bit accounting stays honest.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import prng


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedGrad:
    """A compressed gradient message.

    values: int8 ternary {-1,0,+1} (sign-family) or int8/float payload.
    scale:  scalar float multiplier applied at decode time (1.0 for sparsign /
            signSGD — they are scale-free by design, the whole point of the paper).
    """

    values: jnp.ndarray
    scale: jnp.ndarray

    def decode(self) -> jnp.ndarray:
        return self.values.astype(jnp.float32) * self.scale


def _counters(g: jnp.ndarray, counter_base) -> jnp.ndarray:
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(g.shape)
    return idx + jnp.asarray(counter_base, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# The paper's compressor (Definition 1)
# ---------------------------------------------------------------------------

def sparsign(g: jnp.ndarray, *, budget, seed, counter_base=0) -> CompressedGrad:
    """Magnitude-aware stochastic ternarization (Def. 1).

    Q(g_i) = sign(g_i) w.p. min(|g_i| * B_i, 1) else 0.

    Probabilities > 1 are clipped (Remark 7 — equivalent to gradient clipping).
    Scale-free: the receiver only ever needs the ternary symbol.
    """
    p = jnp.clip(jnp.abs(g).astype(jnp.float32) * jnp.asarray(budget, jnp.float32), 0.0, 1.0)
    u = prng.uniform01(seed, _counters(g, counter_base))
    keep = u < p
    vals = jnp.where(keep, jnp.sign(g).astype(jnp.int8), jnp.int8(0))
    return CompressedGrad(values=vals, scale=jnp.float32(1.0))


# ---------------------------------------------------------------------------
# Baselines (Appendix B)
# ---------------------------------------------------------------------------

def sign_compressor(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """signSGD (Bernstein et al. 2018): deterministic sign. sign(0)=0 (jnp.sign)."""
    return CompressedGrad(values=jnp.sign(g).astype(jnp.int8), scale=jnp.float32(1.0))


def scaled_sign(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """Scaled signSGD (Karimireddy et al. 2019): (||g||_1 / d) * sign(g)."""
    d = g.size
    scale = jnp.sum(jnp.abs(g)).astype(jnp.float32) / jnp.float32(d)
    return CompressedGrad(values=jnp.sign(g).astype(jnp.int8), scale=scale)


def noisy_sign(g, *, budget=1.0, seed=0, counter_base=0) -> CompressedGrad:
    """Noisy signSGD (Chen et al. 2020a): sign(g + n), n ~ N(0, sigma^2).

    ``budget`` is reused as sigma (the tuned noise std in Appendix B).
    Gaussian noise from two counter-stream uniforms via Box-Muller.
    """
    c = _counters(g, counter_base)
    u1 = prng.uniform01(prng.fold_seed(seed, 1), c)
    u2 = prng.uniform01(prng.fold_seed(seed, 2), c)
    # Guard u1=0 for the log.
    u1 = jnp.maximum(u1, jnp.float32(1e-12))
    n = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    noisy = g.astype(jnp.float32) + jnp.asarray(budget, jnp.float32) * n
    return CompressedGrad(values=jnp.sign(noisy).astype(jnp.int8), scale=jnp.float32(1.0))


def _stochastic_ternary(g, norm, seed, counter_base) -> jnp.ndarray:
    """sign(g_i) w.p. |g_i|/norm else 0 — shared by TernGrad/1-bit QSGD."""
    p = jnp.clip(jnp.abs(g).astype(jnp.float32) / jnp.maximum(norm, 1e-12), 0.0, 1.0)
    u = prng.uniform01(seed, _counters(g, counter_base))
    return jnp.where(u < p, jnp.sign(g).astype(jnp.int8), jnp.int8(0))


def qsgd_1bit_l2(g, *, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """1-bit L2-norm QSGD (Alistarh et al. 2017, s=1): ||g||_2 * sign * Bernoulli(|g|/||g||_2)."""
    norm = jnp.linalg.norm(g.astype(jnp.float32).reshape(-1))
    vals = _stochastic_ternary(g, norm, seed, counter_base)
    return CompressedGrad(values=vals, scale=norm.astype(jnp.float32))


def qsgd_1bit_linf(g, *, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """1-bit L-inf-norm QSGD: replaces ||.||_2 with ||.||_inf."""
    norm = jnp.max(jnp.abs(g.astype(jnp.float32)))
    vals = _stochastic_ternary(g, norm, seed, counter_base)
    return CompressedGrad(values=vals, scale=norm.astype(jnp.float32))


def terngrad(g, *, budget=None, seed=0, counter_base=0, shared_max: Optional[jnp.ndarray] = None) -> CompressedGrad:
    """TernGrad (Wen et al. 2017): s_t * sign(g) * Bernoulli(|g|/s_t).

    ``shared_max`` is the magnitude-sharing protocol value max_m ||g_m||_inf; when
    None it degrades to the local L-inf norm (single-worker TernGrad).
    """
    s_t = shared_max if shared_max is not None else jnp.max(jnp.abs(g.astype(jnp.float32)))
    vals = _stochastic_ternary(g, s_t, seed, counter_base)
    return CompressedGrad(values=vals, scale=jnp.asarray(s_t, jnp.float32))


def qsgd(g, *, s: int, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """Full QSGD with s quantization levels (Appendix B Eq. 42-43). Used by the
    FedCom baseline (8-bit => s = 2**8 - 1 levels). Payload is int8-like small ints
    times scale/s; we keep values as int32 level*sign for exact bit accounting.
    ``budget`` is accepted (and ignored) for registry-signature compatibility —
    the level count s, not a magnitude budget, sets this family's rate."""
    gf = g.astype(jnp.float32)
    norm = jnp.maximum(jnp.linalg.norm(gf.reshape(-1)), 1e-12)
    r = jnp.abs(gf) * (s / norm)
    l = jnp.floor(r)
    frac = r - l
    u = prng.uniform01(seed, _counters(g, counter_base))
    level = l + (u < frac).astype(jnp.float32)
    vals = (jnp.sign(gf) * level).astype(jnp.int32)
    return CompressedGrad(values=vals, scale=(norm / s).astype(jnp.float32))


def identity(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """Uncompressed baseline (D-SGD)."""
    return CompressedGrad(values=g, scale=jnp.float32(1.0))


# ---------------------------------------------------------------------------
# Registry / pytree-level application
# ---------------------------------------------------------------------------

COMPRESSORS: dict[str, Callable] = {
    "sparsign": sparsign,
    "sign": sign_compressor,
    "scaled_sign": scaled_sign,
    "noisy_sign": noisy_sign,
    "qsgd_1bit_l2": qsgd_1bit_l2,
    "qsgd_1bit_linf": qsgd_1bit_linf,
    "terngrad": terngrad,
    "qsgd8": partial(qsgd, s=255),   # FedCom 8-bit baseline: 2**8 - 1 levels
    "identity": identity,
}


def get_compressor(name: str) -> Callable:
    try:
        return COMPRESSORS[name]
    except KeyError:
        raise KeyError(f"unknown compressor {name!r}; known: {sorted(COMPRESSORS)}") from None


def compress_leaf_chunked(fn, g, *, budget, seed, counter_base=0, max_chunk: int = 1 << 23):
    """Apply a ternary compressor to a large leaf in chunks.

    Stream-identical to one-shot compression (counter = flat coordinate index),
    but bounds the transient u32/f32 RNG buffers to max_chunk coordinates —
    without this, compressing an embedding table materializes index/uniform
    arrays as large as the table itself (the Pallas kernel regenerates them
    in-register on TPU; this is the jnp path's equivalent).
    """
    n = g.size
    if n <= max_chunk:
        return fn(g, budget=budget, seed=seed, counter_base=counter_base)
    k = -(-n // max_chunk)
    while n % k:
        k += 1
    chunk = n // k
    flat = g.reshape(-1)
    base = jnp.asarray(counter_base, jnp.uint32)

    def body(_, i):
        seg = jax.lax.dynamic_slice(flat, (i * chunk,), (chunk,))
        msg = fn(seg, budget=budget, seed=seed,
                 counter_base=base + (i * chunk).astype(jnp.uint32))
        return None, msg.values

    _, vals = jax.lax.scan(body, None, jnp.arange(k))
    # chunking is only valid for scale-free compressors (sparsign/sign/noisy):
    # norm-carrying ones (qsgd/terngrad) must see the whole tensor at once
    return CompressedGrad(values=vals.reshape(g.shape), scale=jnp.float32(1.0))


SCALE_FREE = ("sparsign", "sign", "noisy_sign")


def leaf_counter_bases(tree) -> list[int]:
    """Starting logical-coordinate index for each leaf of a gradient pytree.

    Gives every parameter coordinate in the model a fixed global index so that
    per-leaf compression draws from disjoint slices of one logical stream.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    bases, acc = [], 0
    for leaf in leaves:
        bases.append(acc)
        acc += int(leaf.size)
    return bases


def compress_tree(grads, *, name: str, budget, seed, extra_salt: int = 0):
    """Apply a compressor leaf-wise with disjoint counter ranges.

    Returns a pytree of CompressedGrad mirroring ``grads``.
    """
    fn = get_compressor(name)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    bases = leaf_counter_bases(grads)
    out = [
        fn(leaf, budget=budget, seed=prng.fold_seed(seed, extra_salt), counter_base=base)
        for leaf, base in zip(leaves, bases)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
