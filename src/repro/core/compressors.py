"""Gradient compressors: the paper's ``sparsign`` (Def. 1) plus every baseline
from §6 / Appendix B — and the declarative ``CompressorSpec`` registry that
makes each of them a first-class citizen of the engine's kernel/wire dispatch.

All worker-side compressors share the public signature::

    compress(g, *, budget, seed, counter_base=0) -> CompressedGrad

where ``g`` is a float array, ``budget`` the paper's ``B`` (scalar or per-coord),
``seed`` a uint32 stream seed and ``counter_base`` the logical index of g's first
coordinate (used when a large tensor is compressed shard-by-shard so that every
coordinate keeps its layout-invariant Bernoulli draw).

Ternary compressors return int8 arrays with values in {-1, 0, +1}; the wire
scaling (if any — TernGrad/QSGD rescale by a norm) is carried separately in
``scale`` so that bit accounting stays honest.

The registry (``SPECS``) is the machine-readable half: per compressor it names
the *normalized* jnp value function, the Pallas kernel op, the fused
``->pack2bit`` op (or None -> two-pass fallback), ternariness, the scale
protocol and the server decode rule — so ``engine.compress_leaf``,
``engine.server_apply`` and the VoteWire format negotiation are pure table
lookups with no compressor-name branching anywhere.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import prng
from repro.kernels.golomb.ops import sparsign_golomb_op
from repro.kernels.pack8.ops import qsgd8_op, qsgd8_pack8_op
from repro.kernels.pack8.ref import QSGD8_LEVELS, qsgd8_levels_ref
from repro.kernels.sparsign.ops import sparsign_op
from repro.kernels.sparsign_pack2bit.ops import sparsign_pack2bit_op
from repro.kernels.ternary.ops import (noisy_sign_op, noisy_sign_pack2bit_op,
                                       sign_op, sign_pack2bit_op,
                                       stochastic_ternary_op,
                                       stochastic_ternary_pack2bit_op)
from repro.kernels.ternary.ref import ternary_compress_ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedGrad:
    """A compressed gradient message.

    values: int8 ternary {-1,0,+1} (sign-family) or int8/float payload.
    scale:  scalar float multiplier applied at decode time (1.0 for sparsign /
            signSGD — they are scale-free by design, the whole point of the paper).
    """

    values: jnp.ndarray
    scale: jnp.ndarray

    def decode(self) -> jnp.ndarray:
        return self.values.astype(jnp.float32) * self.scale


def _counters(g: jnp.ndarray, counter_base) -> jnp.ndarray:
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(g.shape)
    return idx + jnp.asarray(counter_base, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Local-scale resolvers (CompressorSpec.local_scale)
# ---------------------------------------------------------------------------

def _scale_l1_mean(g: jnp.ndarray) -> jnp.ndarray:
    """||g||_1 / d — scaled signSGD (Karimireddy et al. 2019)."""
    return jnp.sum(jnp.abs(g)).astype(jnp.float32) / jnp.float32(g.size)


def _scale_l2(g: jnp.ndarray) -> jnp.ndarray:
    """||g||_2 — 1-bit L2 QSGD."""
    return jnp.linalg.norm(g.astype(jnp.float32).reshape(-1))


def _scale_linf(g: jnp.ndarray) -> jnp.ndarray:
    """||g||_inf — 1-bit L-inf QSGD / (local) TernGrad."""
    return jnp.max(jnp.abs(g.astype(jnp.float32)))


def _scale_qsgd(g: jnp.ndarray, s: int) -> jnp.ndarray:
    """max(||g||_2, eps) / s — the per-level decode scale of s-level QSGD."""
    return jnp.maximum(_scale_l2(g), 1e-12) / jnp.float32(s)


# ---------------------------------------------------------------------------
# Normalized value functions (CompressorSpec.values): (g, param, seed,
# counter_base) -> values array. ``param`` is the scale for scale-carrying
# compressors and the budget/sigma for the scale-free ones — the same scalar
# the Pallas ops take, so jnp and kernel paths are argument-for-argument twins
# (the ternary ones are the kernel rules' oracles, mirroring kernels/ternary/
# ops.py's per-rule partials).
# ---------------------------------------------------------------------------

_sparsign_values = partial(ternary_compress_ref, rule="sparsign")
_sign_values = partial(ternary_compress_ref, rule="sign")
_noisy_sign_values = partial(ternary_compress_ref, rule="noisy_sign")
_stochastic_ternary_values = partial(ternary_compress_ref, rule="stochastic_ternary")


def _qsgd_level_values(g, param, seed, counter_base):
    """Signed stochastic levels of s-level QSGD; param = norm/s (the decode
    scale), so level = stochastic_round(|g| / param)."""
    gf = g.astype(jnp.float32)
    r = jnp.abs(gf) / jnp.maximum(jnp.asarray(param, jnp.float32), 1e-20)
    l = jnp.floor(r)
    u = prng.uniform01(seed, _counters(g, counter_base))
    level = l + (u < (r - l)).astype(jnp.float32)
    return (jnp.sign(gf) * level).astype(jnp.int32)


def _identity_values(g, param, seed, counter_base):
    return g


# ---------------------------------------------------------------------------
# Public compressors (Def. 1 + Appendix B) — thin scale-wrapping shims over
# the normalized value functions, kept for direct use and the tests' API.
# ---------------------------------------------------------------------------

def sparsign(g: jnp.ndarray, *, budget, seed, counter_base=0) -> CompressedGrad:
    """Magnitude-aware stochastic ternarization (Def. 1).

    Q(g_i) = sign(g_i) w.p. min(|g_i| * B_i, 1) else 0.

    Probabilities > 1 are clipped (Remark 7 — equivalent to gradient clipping).
    Scale-free: the receiver only ever needs the ternary symbol.
    """
    vals = _sparsign_values(g, budget, seed, counter_base)
    return CompressedGrad(values=vals, scale=jnp.float32(1.0))


def sign_compressor(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """signSGD (Bernstein et al. 2018): deterministic sign. sign(0)=0 (jnp.sign)."""
    return CompressedGrad(values=jnp.sign(g).astype(jnp.int8), scale=jnp.float32(1.0))


def scaled_sign(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """Scaled signSGD (Karimireddy et al. 2019): (||g||_1 / d) * sign(g)."""
    return CompressedGrad(values=jnp.sign(g).astype(jnp.int8), scale=_scale_l1_mean(g))


def noisy_sign(g, *, budget=1.0, seed=0, counter_base=0) -> CompressedGrad:
    """Noisy signSGD (Chen et al. 2020a): sign(g + n), n ~ N(0, sigma^2).

    ``budget`` is reused as sigma (the tuned noise std in Appendix B).
    Gaussian noise from two counter-stream uniforms via Box-Muller.
    """
    vals = _noisy_sign_values(g, budget, seed, counter_base)
    return CompressedGrad(values=vals, scale=jnp.float32(1.0))


def qsgd_1bit_l2(g, *, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """1-bit L2-norm QSGD (Alistarh et al. 2017, s=1): ||g||_2 * sign * Bernoulli(|g|/||g||_2)."""
    norm = _scale_l2(g)
    vals = _stochastic_ternary_values(g, norm, seed, counter_base)
    return CompressedGrad(values=vals, scale=norm.astype(jnp.float32))


def qsgd_1bit_linf(g, *, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """1-bit L-inf-norm QSGD: replaces ||.||_2 with ||.||_inf."""
    norm = _scale_linf(g)
    vals = _stochastic_ternary_values(g, norm, seed, counter_base)
    return CompressedGrad(values=vals, scale=norm.astype(jnp.float32))


def terngrad(g, *, budget=None, seed=0, counter_base=0, shared_max: Optional[jnp.ndarray] = None) -> CompressedGrad:
    """TernGrad (Wen et al. 2017): s_t * sign(g) * Bernoulli(|g|/s_t).

    ``shared_max`` is the magnitude-sharing protocol value max_m ||g_m||_inf; when
    None it degrades to the local L-inf norm (single-worker TernGrad). The mesh
    trainers and the FL sim supply it via the engine's ``shared_linf`` hook
    (psum-max over the worker axes) — the Appendix B baseline.
    """
    s_t = shared_max if shared_max is not None else _scale_linf(g)
    vals = _stochastic_ternary_values(g, s_t, seed, counter_base)
    return CompressedGrad(values=vals, scale=jnp.asarray(s_t, jnp.float32))


def qsgd(g, *, s: int, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """Full QSGD with s quantization levels (Appendix B Eq. 42-43), any s.
    Payload is int32 level*sign for exact bit accounting at arbitrary s; the
    registered 8-bit baseline is the dedicated ``qsgd8`` below (whose levels
    are clipped into the int8 wire domain). ``budget`` is accepted (and
    ignored) for registry-signature compatibility — the level count s, not a
    magnitude budget, sets this family's rate."""
    scale = _scale_qsgd(g, s)
    vals = _qsgd_level_values(g, scale, seed, counter_base)
    return CompressedGrad(values=vals, scale=scale.astype(jnp.float32))


def qsgd8(g, *, budget=None, seed=0, counter_base=0) -> CompressedGrad:
    """FedCom-style 8-bit QSGD: 1 sign bit + 7 level bits, s = 2**7 - 1 = 127.

    The signed stochastic level rides the ``pack8`` wire losslessly as one
    int8 byte per coordinate (levels clip at 127 — reachable only by a float
    ulp when a single coordinate carries the whole norm, where an unclipped
    128 would wrap to -128 on the wire). The level rule lives in
    ``kernels.pack8.ref.qsgd8_levels_ref``, shared bitwise by this shim, the
    engine's jnp path and the fused Pallas kernel."""
    scale = _scale_qsgd(g, QSGD8_LEVELS)
    vals = qsgd8_levels_ref(g, scale, seed, counter_base)
    return CompressedGrad(values=vals, scale=scale.astype(jnp.float32))


def identity(g, *, budget=None, seed=None, counter_base=0) -> CompressedGrad:
    """Uncompressed baseline (D-SGD)."""
    return CompressedGrad(values=g, scale=jnp.float32(1.0))


def qsgd8_scale(g: jnp.ndarray) -> jnp.ndarray:
    """The qsgd8 decode scale max(||g||_2, eps) / 127 — public alias for
    callers quantizing outside the registry (e.g. the 8-bit downlink)."""
    return _scale_qsgd(g, QSGD8_LEVELS)


# ---------------------------------------------------------------------------
# The CompressorSpec registry
# ---------------------------------------------------------------------------

#: scale protocols: how the decode-time scale is produced.
#:   none       — scale-free (scale == 1); param fed to the kernels is the budget
#:   local_norm — each worker's own norm (local_scale); per-worker, so ternary
#:                messages can only ride the decoded-float wire under a mean server
#:   shared_max — TernGrad's magnitude sharing: one psum-max'd ||g||_inf shared
#:                by all workers, so ternary votes + a single scalar ride the wire
SCALE_PROTOCOLS = ("none", "local_norm", "shared_max")

#: server decode rules: what the aggregated message means to the server.
#:   sign        — scale-free ternary votes; any server rule consumes the raw sums
#:   scaled_sign — ternary votes * scale; vote servers use raw votes (one worker
#:                 one vote), the mean server multiplies the vote mean by the scale
#:   dequant     — non-ternary payload; decoded floats, mean server only
SERVER_DECODES = ("sign", "scaled_sign", "dequant")

#: densest lossless wire encoding of one worker message — what the message
#: payload looks like on the byte-exchange wires (``engine.wire_mode`` and the
#: ``VoteWire`` negotiation key on this, with no name branching):
#:   pack2  — ternary symbols, 2-bit packed canonical view (0.25 B/coord)
#:   golomb — ternary symbols, Golomb/RLE entropy-coded byte stream at a
#:            plan-time capacity (~(2+b)*p bits/coord; kernels/golomb) —
#:            needs the gather wire, falls back to int8 psum votes elsewhere
#:   pack8  — int8 sign*level canonical view + one f32 scale (1 B/coord + 4 B)
#:   float  — no sub-float encoding; decoded fp32 psum only (4 B/coord)
WIRE_FORMATS = ("pack2", "golomb", "pack8", "float")

#: information-theoretic uplink bit model of one worker message (paper §6 /
#: Eq. 12 accounting — ``core.encoding.baseline_bits_per_round`` keys on this,
#: with no name branching):
#:   dense_sign     — 1 bit/coord (sign family; the 32-bit scale is negligible)
#:   golomb_ternary — Golomb-coded nonzero positions + 1 sign bit/nonzero + one
#:                    32-bit scale (sparse ternary family, Eq. 12)
#:   level8         — 8 bits/coord + one 32-bit decode scale (pack8 wire)
#:   fp32           — 32 bits/coord (uncompressed)
UPLINK_BIT_MODELS = ("dense_sign", "golomb_ternary", "level8", "fp32")


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    """One row of the compressor capability table — everything the engine and
    the wire layer need to know, as data. ``api`` is the public compressor
    (original keyword signature); ``values`` is the normalized jnp reference
    ``(g, param, seed, counter_base) -> values`` that mirrors the kernel ops
    argument-for-argument."""

    name: str
    api: Callable
    values: Callable
    is_ternary: bool
    scale_protocol: str = "none"
    local_scale: Optional[Callable] = None      # g -> f32 scalar (protocol != none)
    pallas_op: Optional[Callable] = None        # (g, param, seed, base, *, interpret=)
    fused_pack_op: Optional[Callable] = None    # fused ->wire-payload variant, or None
    server_decode: str = "sign"
    chunkable: bool = False                     # jnp path may stream in chunks
    wire_format: str = "pack2"                  # pack2 | pack8 | float (WIRE_FORMATS)
    #: HBM contract of the fused wire op: ((dtype_name, max_elems), ...) — at
    #: most ``max_elems`` elements of that dtype may materialize between ops
    #: when tracing ``fused_pack_op``. The jaxpr auditor
    #: (``repro.analysis.jaxpr_audit.check_fused_uplink``) enforces it as
    #: ``NoHbmIntermediate`` rules — the declarative form of the old
    #: hand-written int8/int32 pins.
    hbm_limits: tuple = ()
    #: information-theoretic uplink accounting (UPLINK_BIT_MODELS) — keys
    #: ``core.encoding.baseline_bits_per_round``
    uplink_bits: str = "dense_sign"

    def __post_init__(self):
        assert self.scale_protocol in SCALE_PROTOCOLS, self.scale_protocol
        assert self.server_decode in SERVER_DECODES, self.server_decode
        assert self.wire_format in WIRE_FORMATS, self.wire_format
        assert self.uplink_bits in UPLINK_BIT_MODELS, self.uplink_bits
        assert (self.scale_protocol == "none") == (self.local_scale is None), self.name
        # ternary <=> a ternary-symbol wire codebook (flat 2-bit or the
        # entropy-coded stream); pack8/float are the non-ternary rows
        assert (self.wire_format in ("pack2", "golomb")) == self.is_ternary, \
            self.name
        if self.fused_pack_op is not None:
            assert self.wire_format != "float", \
                f"{self.name}: a fused pack op needs a packed wire format"
            # a fused wire op without a declared HBM contract is an unaudited
            # kernel — the whole point of the fusion is checkable, so declare it
            assert self.hbm_limits, \
                f"{self.name}: fused_pack_op requires declared hbm_limits"
        for dtype, limit in self.hbm_limits:
            assert isinstance(dtype, str) and isinstance(limit, int) and limit >= 0, \
                (self.name, dtype, limit)

    @property
    def scale_shared(self) -> bool:
        """Is the decode scale identical on every worker (so ternary votes can
        ride the integer/packed wire even under a mean server)?"""
        return self.scale_protocol in ("none", "shared_max")

    def resolve_scale(self, g, shared_linf=None) -> Optional[jnp.ndarray]:
        """The decode-time scale for one leaf, or None for scale-free specs.
        ``shared_linf`` (the psum-max'd worker L-inf) feeds the shared_max
        protocol; absent, it degrades to the local norm — which is only the
        single-worker semantics. ``engine.compress_leaf`` refuses that degrade
        inside a mapped (multi-worker) context, where it would silently
        reintroduce per-worker TernGrad drift; the fallback here serves the
        public single-worker API and the tests only."""
        if self.scale_protocol == "none":
            return None
        if self.scale_protocol == "shared_max" and shared_linf is not None:
            return jnp.asarray(shared_linf, jnp.float32)
        return self.local_scale(g)


#: the fused-ternary HBM contract: gradient -> packed wire bytes with ZERO
#: int8 ternary elements at the HBM level (the two-pass chain has >= n)
_TERNARY_FUSED_HBM = (("int8", 0),)

SPECS: dict[str, CompressorSpec] = {spec.name: spec for spec in (
    CompressorSpec(
        name="sparsign", api=sparsign, values=_sparsign_values,
        is_ternary=True, scale_protocol="none",
        pallas_op=sparsign_op, fused_pack_op=sparsign_pack2bit_op,
        server_decode="sign", chunkable=True,
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="golomb_ternary"),
    CompressorSpec(
        # the same Def. 1 compressor as 'sparsign' (identical ternary stream,
        # seeds, budget semantics) on the entropy-coded wire: Golomb/RLE-coded
        # zero runs + sign bits at plan-time capacity instead of the flat
        # 2-bit codebook — sub-0.5 bits/coord at paper-regime sparsity
        name="sparsign_golomb", api=sparsign, values=_sparsign_values,
        is_ternary=True, scale_protocol="none",
        pallas_op=sparsign_op, fused_pack_op=sparsign_golomb_op,
        server_decode="sign", chunkable=True, wire_format="golomb",
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="golomb_ternary"),
    CompressorSpec(
        name="sign", api=sign_compressor, values=_sign_values,
        is_ternary=True, scale_protocol="none",
        pallas_op=sign_op, fused_pack_op=sign_pack2bit_op,
        server_decode="sign",
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="dense_sign"),
    CompressorSpec(
        name="scaled_sign", api=scaled_sign, values=_sign_values,
        is_ternary=True, scale_protocol="local_norm", local_scale=_scale_l1_mean,
        pallas_op=sign_op, fused_pack_op=sign_pack2bit_op,
        server_decode="scaled_sign",
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="dense_sign"),
    CompressorSpec(
        name="noisy_sign", api=noisy_sign, values=_noisy_sign_values,
        is_ternary=True, scale_protocol="none",
        pallas_op=noisy_sign_op, fused_pack_op=noisy_sign_pack2bit_op,
        server_decode="sign", chunkable=True,
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="dense_sign"),
    CompressorSpec(
        name="qsgd_1bit_l2", api=qsgd_1bit_l2, values=_stochastic_ternary_values,
        is_ternary=True, scale_protocol="local_norm", local_scale=_scale_l2,
        pallas_op=stochastic_ternary_op,
        fused_pack_op=stochastic_ternary_pack2bit_op,
        server_decode="scaled_sign", chunkable=True,
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="golomb_ternary"),
    CompressorSpec(
        name="qsgd_1bit_linf", api=qsgd_1bit_linf, values=_stochastic_ternary_values,
        is_ternary=True, scale_protocol="local_norm", local_scale=_scale_linf,
        pallas_op=stochastic_ternary_op,
        fused_pack_op=stochastic_ternary_pack2bit_op,
        server_decode="scaled_sign", chunkable=True,
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="golomb_ternary"),
    CompressorSpec(
        name="terngrad", api=terngrad, values=_stochastic_ternary_values,
        is_ternary=True, scale_protocol="shared_max", local_scale=_scale_linf,
        pallas_op=stochastic_ternary_op,
        fused_pack_op=stochastic_ternary_pack2bit_op,
        server_decode="scaled_sign", chunkable=True,
        hbm_limits=_TERNARY_FUSED_HBM, uplink_bits="golomb_ternary"),
    CompressorSpec(
        # FedCom 8-bit baseline: 1 sign bit + 7 level bits (s = 127), so one
        # worker message is exactly 1 B/coord on the pack8 wire + one f32 scale
        name="qsgd8", api=qsgd8, values=qsgd8_levels_ref,
        is_ternary=False, scale_protocol="local_norm",
        local_scale=partial(_scale_qsgd, s=QSGD8_LEVELS),
        pallas_op=qsgd8_op, fused_pack_op=qsgd8_pack8_op,
        server_decode="dequant", chunkable=True, wire_format="pack8",
        # int32 limit 1: the single scatter-start index of the to_2d
        # canonical-view pad — never an O(n) level tensor (the legacy generic
        # qsgd chain materializes >= n int32 levels)
        hbm_limits=(("int32", 1),), uplink_bits="level8"),
    CompressorSpec(
        name="identity", api=identity, values=_identity_values,
        is_ternary=False, scale_protocol="none",
        server_decode="dequant", wire_format="float", uplink_bits="fp32"),
)}


def get_spec(name: str) -> CompressorSpec:
    try:
        return SPECS[name]
    except KeyError:
        raise KeyError(f"unknown compressor {name!r}; known: {sorted(SPECS)}") from None


#: legacy view: compressor name -> public callable. Derived from the spec
#: table — do not add entries here; add a CompressorSpec instead.
COMPRESSORS: dict[str, Callable] = {name: spec.api for name, spec in SPECS.items()}


def get_compressor(name: str) -> Callable:
    return get_spec(name).api


# ---------------------------------------------------------------------------
# Chunked / pytree-level application
# ---------------------------------------------------------------------------

def chunked_values(values_fn, g, param, seed, counter_base=0, max_chunk: int = 1 << 23):
    """Apply a normalized value function to a large leaf in chunks.

    Stream-identical to one-shot compression (counter = flat coordinate index),
    but bounds the transient u32/f32 RNG buffers to max_chunk coordinates —
    without this, compressing an embedding table materializes index/uniform
    arrays as large as the table itself (the Pallas kernels regenerate them
    in-register on TPU; this is the jnp path's equivalent). Valid for any
    counter-indexed value function once ``param`` is resolved from the whole
    tensor — the per-chunk computation never needs global statistics.
    """
    n = g.size
    if n <= max_chunk:
        return values_fn(g, param, seed, counter_base)
    k = -(-n // max_chunk)
    while n % k:
        k += 1
    chunk = n // k
    flat = g.reshape(-1)
    base = jnp.asarray(counter_base, jnp.uint32)

    def body(_, i):
        seg = jax.lax.dynamic_slice(flat, (i * chunk,), (chunk,))
        return None, values_fn(seg, param, seed, base + (i * chunk).astype(jnp.uint32))

    _, vals = jax.lax.scan(body, None, jnp.arange(k))
    return vals.reshape(g.shape)


def compress_leaf_chunked(fn, g, *, budget, seed, counter_base=0, max_chunk: int = 1 << 23):
    """Legacy chunked entry point over a *public* compressor fn (scale-free
    family only — the chunks would each see a different norm otherwise)."""
    vals = chunked_values(
        lambda seg, p, s, cb: fn(seg, budget=p, seed=s, counter_base=cb).values,
        g, budget, seed, counter_base, max_chunk=max_chunk)
    return CompressedGrad(values=vals, scale=jnp.float32(1.0))


def leaf_counter_bases(tree) -> list[int]:
    """Starting logical-coordinate index for each leaf of a gradient pytree.

    Gives every parameter coordinate in the model a fixed global index so that
    per-leaf compression draws from disjoint slices of one logical stream.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    bases, acc = [], 0
    for leaf in leaves:
        bases.append(acc)
        acc += int(leaf.size)
    return bases


def compress_tree(grads, *, name: str, budget, seed, extra_salt: int = 0):
    """Apply a compressor leaf-wise with disjoint counter ranges.

    Returns a pytree of CompressedGrad mirroring ``grads``.
    """
    fn = get_compressor(name)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    bases = leaf_counter_bases(grads)
    out = [
        fn(leaf, budget=budget, seed=prng.fold_seed(seed, extra_salt), counter_base=base)
        for leaf, base in zip(leaves, bases)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
