"""Server-side aggregation rules C(.) and the majority vote.

On a real parameter server, C consumes (1/|S|) * sum_m Delta_m. In the TPU
mapping the sum over workers arrives as an integer vote count (psum of ternary
int8 over the worker axes); these helpers operate on either representation.
"""

from __future__ import annotations

import jax.numpy as jnp


def majority_vote(vote_sum: jnp.ndarray) -> jnp.ndarray:
    """C(.) = sign(.) over the summed ternary votes. Ties (0) stay 0.

    Accepts int8/int16/int32 vote sums (or float means); returns int8 ternary.
    """
    return jnp.sign(vote_sum).astype(jnp.int8)


def scaled_sign_server(x: jnp.ndarray) -> jnp.ndarray:
    """alpha-approximate server compressor C(x) = (||x||_1 / d) * sign(x).

    Karimireddy et al. 2019 show this is alpha-approximate with
    alpha = ||x||_1^2 / (d * ||x||_2^2) in (0, 1]. Used by EF-SPARSIGNSGD.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.sum(jnp.abs(xf)) / jnp.float32(x.size)
    return scale * jnp.sign(xf)


def alpha_of_scaled_sign(x: jnp.ndarray) -> jnp.ndarray:
    """The compression quality alpha for scaled-sign on input x (for tests/telemetry)."""
    xf = x.astype(jnp.float32).reshape(-1)
    l1 = jnp.sum(jnp.abs(xf))
    l2sq = jnp.maximum(jnp.sum(xf * xf), 1e-30)
    return (l1 * l1) / (x.size * l2sq)


def mean_server(x: jnp.ndarray) -> jnp.ndarray:
    """Uncompressed server aggregation (FedAvg-style mean passthrough)."""
    return x.astype(jnp.float32)


SERVER_AGGREGATORS = {
    "majority_vote": majority_vote,
    "scaled_sign": scaled_sign_server,
    "mean": mean_server,
}
