"""SPARSIGNSGD (Alg. 1) and EF-SPARSIGNSGD with local updates (Alg. 2), split
into the three roles every deployment composes:

  worker_message      — worker-side compression (optionally with tau local steps)
  (vote aggregation)  — a sum over workers: psum on a mesh, jnp.sum in the FL sim
  server_update       — C(.) + optional server-side error feedback

`repro.fl.simulation` composes them with an explicit M-worker loop (paper's
experiments); `repro.train.step_simple` / `step_streamed` compose them with mesh
collectives (the production path). Keeping one shared implementation is what
makes the reproduction and the production system provably the same algorithm.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, prng
from repro.core.aggregation import majority_vote, mean_server, scaled_sign_server
from repro.core.budgets import BudgetConfig
from repro.core.compressors import CompressedGrad, get_spec
from repro.core.error_feedback import EFState, ef_server_step

# Inner (Alg. 2) local steps accumulate ternary votes in int32 — exact for any
# tau in this range (each step contributes {-1, 0, +1} per coordinate).
MAX_LOCAL_STEPS = 2**31 - 1

# Canonical seed salts for the Alg. 2 worker loop. Historically fl.simulation
# salted the inner stream with 1000 while this module used 1001 — the drift is
# fixed by making everything route through local_update_message.
LOCAL_STEP_SALT = 1001   # inner sparsign stream (shared across the tau steps;
                         # the counter offset c * g.size separates them)
UPLINK_SALT = 2          # the final Q(sum, B_g) uplink stream


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Everything that defines the communication algorithm for one run."""

    compressor: str = "sparsign"         # worker uplink compressor Q
    budget: BudgetConfig = dataclasses.field(default_factory=BudgetConfig)  # B_g (uplink)
    server: str = "majority_vote"        # majority_vote | scaled_sign_ef | mean
    local_steps: int = 1                 # tau (Alg. 2); 1 recovers Alg. 1
    local_budget: Optional[float] = None # B_l for the inner compressed steps
    worker_sample_fraction: float = 1.0  # p_s
    vote_dtype: str = "int8"             # wire dtype for the ternary psum
    pack_wire: bool = False              # model the 2-bit packed wire format

    def __post_init__(self):
        tau = int(self.local_steps)
        if not 1 <= tau <= MAX_LOCAL_STEPS:
            raise ValueError(
                f"local_steps (tau) must be in [1, {MAX_LOCAL_STEPS}] — the "
                f"int32 local-vote accumulator is exact only in that range; "
                f"got {self.local_steps}")

    @property
    def is_ternary(self) -> bool:
        return get_spec(self.compressor).is_ternary


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------

def worker_message(
    g_local: jnp.ndarray,
    cfg: CompressionConfig,
    *,
    seed,
    counter_base=0,
    shared_linf=None,
    backend=None,
) -> CompressedGrad:
    """Q(g_m, B_m): one worker's uplink message for a single tensor."""
    return engine.compress_leaf(g_local, cfg, seed, counter_base,
                                shared_linf=shared_linf, backend=backend)


def local_update_source(
    w0,
    grad_fn: Callable,   # (w, c) -> local stochastic gradient at local step c
    cfg: CompressionConfig,
    *,
    eta_l: float,
    seed,
    counter_base=0,
    backend=None,
) -> jnp.ndarray:
    """Alg. 2 inner loop: tau compressed local steps; returns the float32 *sum*
    of the local compressed gradients (the uplink's input, pre-Q(., B_g)).

    Every inner step uses sparsign with budget B_l; the inner sum lives in
    [-tau, tau], accumulated in int32 (exact — tau is guarded against overflow
    by CompressionConfig). Split out from ``local_update_message`` so callers
    that need cross-worker statistics of the uplink input (TernGrad's shared
    max) can reduce over sources before compressing.
    """
    tau = int(cfg.local_steps)
    local_cfg = engine.local_step_config(cfg)
    inner_seed = prng.fold_seed(seed, LOCAL_STEP_SALT)

    def body(carry, c):
        w, acc = carry
        g = grad_fn(w, c)
        q = engine.compress_leaf(g, local_cfg, inner_seed,
                                 counter_base=counter_base + c * g.size,
                                 backend=backend)
        w = w - eta_l * q.values.astype(w.dtype)
        return (w, acc + q.values.astype(jnp.int32)), None

    (w_final, acc), _ = jax.lax.scan(body, (w0, jnp.zeros(w0.shape, jnp.int32)), jnp.arange(tau))
    del w_final
    return acc.astype(jnp.float32)


def local_update_message(
    w0,
    grad_fn: Callable,
    cfg: CompressionConfig,
    *,
    eta_l: float,
    seed,
    counter_base=0,
    shared_linf=None,
    backend=None,
) -> CompressedGrad:
    """Alg. 2 worker loop: ``local_update_source`` then Q(sum, B_g)."""
    src = local_update_source(w0, grad_fn, cfg, eta_l=eta_l, seed=seed,
                              counter_base=counter_base, backend=backend)
    return worker_message(src, cfg, seed=prng.fold_seed(seed, UPLINK_SALT),
                          counter_base=counter_base, shared_linf=shared_linf,
                          backend=backend)


# ---------------------------------------------------------------------------
# Server side
# ---------------------------------------------------------------------------

def server_update(
    vote_mean: jnp.ndarray,
    cfg: CompressionConfig,
    ef_state: Optional[EFState] = None,
) -> tuple[jnp.ndarray, Optional[EFState]]:
    """C(mean of worker messages) [+ EF]. Returns (g_tilde float32, new EF state).

    vote_mean is (1/|S|) sum_m decoded messages — for ternary compressors, the
    vote *sum* divided by |S| (majority_vote only needs the sign, so sums work
    identically; means keep the scaled-sign server compressor calibrated).
    """
    if cfg.server == "majority_vote":
        return majority_vote(vote_mean).astype(jnp.float32), ef_state
    if cfg.server == "mean":
        return mean_server(vote_mean), ef_state
    if cfg.server == "scaled_sign_ef":
        assert ef_state is not None, "scaled_sign_ef requires an EFState"
        return ef_server_step(ef_state, vote_mean, scaled_sign_server)
    raise ValueError(f"unknown server rule {cfg.server!r}")


# ---------------------------------------------------------------------------
# Reference single-tensor round (used by tests & the FL simulation)
# ---------------------------------------------------------------------------

def reference_round(
    w: jnp.ndarray,
    per_worker_grads: jnp.ndarray,   # [M, *w.shape] local gradients
    cfg: CompressionConfig,
    *,
    eta: float,
    seed,
    ef_state: Optional[EFState] = None,
    participation_mask: Optional[jnp.ndarray] = None,  # [M] bool
):
    """One full Algorithm-1 round on explicit per-worker gradients.

    This is the oracle the mesh implementation is tested against: identical
    seeds/counters => bitwise-identical updates.
    """
    m = per_worker_grads.shape[0]
    mask = participation_mask if participation_mask is not None else jnp.ones((m,), bool)

    def one(gm, widx):
        msg = worker_message(gm, cfg, seed=_worker_seed(seed, widx), counter_base=0)
        return msg.values.astype(jnp.float32) * msg.scale

    decoded = jax.vmap(one)(per_worker_grads, jnp.arange(m))
    decoded = jnp.where(mask.reshape((m,) + (1,) * (decoded.ndim - 1)), decoded, 0.0)
    n_sel = jnp.maximum(jnp.sum(mask), 1)
    vote_mean = jnp.sum(decoded, axis=0) / n_sel
    g_tilde, ef_state = server_update(vote_mean, cfg, ef_state)
    return w - eta * g_tilde.astype(w.dtype), ef_state


def _worker_seed(seed, widx):
    """Independent stream per worker (matches fl.simulation and train.step_*)."""
    return prng.fold_seed(seed, 0x5EED) + jnp.asarray(widx, jnp.uint32) * jnp.uint32(0x9E3779B9)


def worker_stream_seed(seed, widx):
    """Public alias: the per-worker sparsign stream seed."""
    return _worker_seed(seed, widx)
