"""Backend-dispatching compression/server engine — the one hot path every
consumer (train.step_simple, train.step_streamed, fl.simulation) goes through.

Three backends, bitwise-identical by construction (they share the counter-based
PRNG of ``repro.core.prng``, which the Pallas kernels regenerate in-register):

  pallas    — the fused TPU kernels: the per-compressor compress (and fused
              compress->pack2bit) ops named by the ``CompressorSpec`` registry,
              ``vote_update`` (majority-vote sign + SGD in one pass) and
              ``ef_server`` (fused Eq. 8 scaled-sign error feedback).
  interpret — the same kernels in Pallas interpret mode; runs on CPU and is
              what CI pins against the jnp reference.
  jnp       — the pure-jnp reference compressors/server math. Chunkable leaves
              are compressed in chunks to bound transient RNG buffers (the
              kernels need no chunking — RNG never touches HBM).

Selection: the ``backend=`` argument wins, else the ``REPRO_KERNEL_BACKEND``
env var (``auto|pallas|interpret|jnp``), else ``auto`` = pallas on TPU and jnp
everywhere else. Resolution happens at trace/build time, so a jitted train
step bakes its backend in.

All per-compressor capability questions — which kernel, which wire format,
which scale protocol, which server decode — are answered by the declarative
``CompressorSpec`` table (``repro.core.compressors.SPECS``); this module has
no compressor-name special cases.

Two primitives:

  compress_leaf(g, cfg, seed, counter_base)        — worker uplink Q(g, B)
  server_apply(p, vote_sum, cfg, ...)              — C(.) [+ EF] + SGD update

plus the small shared helpers (vote-server predicates, wire-mode negotiation,
per-leaf quorum broadcasting, local-step config) that keep server-rule and
compressor names out of the train/fl layers entirely.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core.budgets import BudgetConfig, resolve_budget
from repro.core.compressors import (CompressedGrad, CompressorSpec,
                                    chunked_values, get_spec)
from repro.dist import compat
from repro.kernels import common as kcommon
from repro.kernels.ef_server.ops import ef_server_op
from repro.kernels.ef_server.ref import ef_server_ref
from repro.kernels.golomb.ops import golomb_pack_op
from repro.kernels.golomb.ref import golomb_encode_ref
from repro.kernels.pack2bit.ops import pack2bit_op
from repro.kernels.pack2bit.ref import pack2bit_ref
from repro.kernels.vote_update.ops import (vote_update_op,
                                           weighted_vote_update_op)
from repro.kernels.vote_update.ref import (vote_update_ref,
                                           weighted_vote_update_ref)

if TYPE_CHECKING:  # avoid a runtime cycle: algorithm imports this module
    from repro.core.algorithm import CompressionConfig

ENV_VAR = "REPRO_KERNEL_BACKEND"
BACKENDS = ("pallas", "interpret", "jnp")

# server rules with a ternary integer vote wire (1-2 B/coord psum); everything
# else ships decoded floats and aggregates by mean
VOTE_SERVERS = ("majority_vote", "scaled_sign_ef")
SERVER_RULES = ("majority_vote", "scaled_sign_ef", "mean")

# how a compressor's messages ride the worker-axis wire (see wire_mode)
WIRE_MODES = ("votes", "scaled_votes", "pack8", "decoded")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Explicit argument > $REPRO_KERNEL_BACKEND > auto (pallas on TPU else jnp)."""
    b = backend if backend is not None else os.environ.get(ENV_VAR, "auto")
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if b not in BACKENDS:
        raise ValueError(f"unknown kernel backend {b!r}; known: {('auto',) + BACKENDS}")
    return b


def is_vote_server(cfg: "CompressionConfig") -> bool:
    return cfg.server in VOTE_SERVERS


def needs_server_ef(server: str) -> bool:
    """Does this server rule carry a (server-side) error-feedback residual?"""
    return server == "scaled_sign_ef"


def wire_mode(cfg: "CompressionConfig", vote_impl: Optional[str] = None) -> str:
    """How this (compressor, server, vote_impl) triple's uplink rides the
    worker wire — a pure CompressorSpec table lookup on ``spec.wire_format``:

      votes        — ternary symbols on the integer/packed vote wire, consumed
                     raw by a vote server (majority_vote / scaled_sign_ef).
      scaled_votes — ternary symbols on the integer/packed vote wire plus ONE
                     shared decode scale; the mean server multiplies the vote
                     mean by it. Requires a worker-invariant scale (protocol
                     none or shared_max).
      pack8        — int8 sign*level payload (1 B/coord) plus each worker's
                     f32 decode scale on the all-gather wire; the exchange
                     dequantizes into the mean server's float sum. Needs the
                     gather wire (``vote_impl='allgather_packed'``) — a psum
                     cannot reduce differently-scaled levels on the fabric,
                     so the psum/hier impls fall back to the decoded wire.
      decoded      — decoded float32 messages, psum + mean server (per-worker
                     scales on ternary wires, and the float wire format).

    The mode says what the symbols MEAN on the wire; how ternary symbols are
    *encoded* (flat 2-bit vs the Golomb entropy-coded stream) is the
    orthogonal ``wire_payload_format`` lookup — golomb-format specs ride the
    votes/scaled_votes modes unchanged.
    """
    spec = get_spec(cfg.compressor)
    if spec.wire_format == "float":
        return "decoded"
    if spec.wire_format == "pack8":
        return "pack8" if vote_impl == "allgather_packed" else "decoded"
    if is_vote_server(cfg):
        return "votes"
    return "scaled_votes" if spec.scale_shared else "decoded"


def wire_payload_format(cfg: "CompressionConfig", mode: str,
                        vote_impl: Optional[str] = None) -> str:
    """Which payload format the wire object should speak for this
    (compressor, wire mode, vote_impl) triple — the ``make_vote_wire``
    ``wire_format=`` argument, as a pure ``CompressorSpec`` table lookup.

    The entropy-coded stream needs the gather wire (a fabric psum cannot sum
    variable-length byte streams), so a golomb-format spec on the psum/hier
    impls rides plain int8 votes instead — the golomb twin of pack8's
    fall-back-to-decoded rule, and bitwise-identical votes either way."""
    if mode == "pack8":
        return "pack8"
    spec = get_spec(cfg.compressor)
    if (spec.wire_format == "golomb" and vote_impl == "allgather_packed"
            and mode in ("votes", "scaled_votes")):
        return "golomb"
    return "pack2"


def resolve_golomb_p(cfg: "CompressionConfig",
                     golomb_p: Optional[float] = None) -> float:
    """The plan-time nonzero fraction that sizes the golomb wire's static
    capacity: an explicit setting wins, else a ``target_sparsity`` budget's
    target IS the plan fraction. Anything else is a loud build-time error —
    guessing p would silently mis-size the capacity (overflow truncation or
    a padded wire that loses to pack2)."""
    if golomb_p is not None:
        p = float(golomb_p)
    elif cfg.budget.kind == "target_sparsity":
        p = float(cfg.budget.value)
    else:
        raise ValueError(
            f"the golomb wire needs a plan-time nonzero fraction to size its "
            f"static capacity: set the step config's golomb_p, or use a "
            f"budget of kind 'target_sparsity' (whose target is the plan "
            f"fraction). Budget kind {cfg.budget.kind!r} carries no nnz "
            f"fraction to plan against.")
    if not 0.0 < p < 1.0:
        raise ValueError(f"golomb plan fraction must be in (0,1), got {p}")
    return p


def resolve_ring_chunk_rows(ring_chunk_rows: Optional[int],
                            vote_impl: Optional[str]) -> Optional[int]:
    """Negotiate the ring-pipelined gather knob at step-build time: ``None``
    stays monolithic (the default), anything else must pair with the gather
    impl and be a positive sublane multiple. The psum/hier impls reduce on
    the fabric and never materialize a gathered tensor, so a ring request
    there is a configuration contradiction, not something to silently drop —
    mirror the wire_mode fallbacks' policy of failing loudly instead of
    misreporting the byte/HBM ledger."""
    if ring_chunk_rows is None:
        return None
    if vote_impl != "allgather_packed":
        raise ValueError(
            f"ring_chunk_rows={ring_chunk_rows!r} needs "
            f"vote_impl='allgather_packed' (the ring chunks a gathered "
            f"payload; vote_impl={vote_impl!r} has none) — drop the ring "
            f"knob or switch the vote wire")
    from repro.kernels import common as kcommon
    r = int(ring_chunk_rows)
    if r <= 0 or r % kcommon.SUBLANE_PAD != 0:
        raise ValueError(
            f"ring_chunk_rows must be a positive multiple of the sublane "
            f"tile ({kcommon.SUBLANE_PAD}), got {ring_chunk_rows!r} — see "
            f"collectives.DEFAULT_RING_CHUNK_ROWS for the documented default")
    return r


def check_participation_server(server: str, compressor: str) -> None:
    """Build-time gate for elastic participation: the weighted,
    participation-normalized vote family covers the majority-vote deadband
    (``|sum w_m sign_m| >= q_frac * W``) and the mean server (divide by the
    realized participation ``W`` instead of ``|S|``). ``scaled_sign_ef``
    keeps a server-side error-feedback residual whose scale calibration
    assumes the full fleet's mean delta — silently re-normalizing it to a
    shifting reporting set would corrupt the residual, so it must fail HERE,
    at step build, not mid-run."""
    if server == "scaled_sign_ef":
        raise ValueError(
            f"elastic participation (a ParticipationSpec) is incompatible "
            f"with server 'scaled_sign_ef' (compressor {compressor!r}): the "
            f"server-side EF residual is calibrated against the full fleet's "
            f"mean delta and cannot be participation-normalized per round. "
            f"Use server='majority_vote' or 'mean'.")


def needs_shared_linf(cfg: "CompressionConfig") -> bool:
    """Must the trainer all-reduce(max) the worker L-inf norms before
    compressing? True for the shared_max scale protocol (TernGrad's magnitude
    sharing) and the linf_share budget policy."""
    return (get_spec(cfg.compressor).scale_protocol == "shared_max"
            or cfg.budget.kind == "linf_share")


def local_budget_value(cfg: "CompressionConfig") -> float:
    """B_l for the tau inner steps of Alg. 2.

    Precedence: cfg.local_budget > cfg.budget.local_value > the uplink B
    itself when the budget is a fixed magnitude (the paper's B_l=10/B_g=1
    regime) > 1.0. Non-fixed budget kinds (target_sparsity etc.) never leak
    their ``value`` into B_l — it is not a magnitude there.
    """
    if cfg.local_budget is not None:
        return float(cfg.local_budget)
    if cfg.budget.local_value is not None:
        return float(cfg.budget.local_value)
    return float(cfg.budget.value) if cfg.budget.kind == "fixed" else 1.0


def local_step_config(cfg: "CompressionConfig") -> "CompressionConfig":
    """Config for the inner (Alg. 2) local steps: sparsign at fixed B_l."""
    return dataclasses.replace(
        cfg, compressor="sparsign",
        budget=BudgetConfig(kind="fixed", value=local_budget_value(cfg)),
        local_steps=1)


# ---------------------------------------------------------------------------
# Per-leaf quorum
# ---------------------------------------------------------------------------

def broadcast_quorum(quorum, like_tree):
    """Widen the server quorum deadband to a per-leaf tree.

    ``quorum`` is either a positive int (broadcast to every leaf) or a pytree
    *prefix* of ``like_tree`` (e.g. ``{"embed": 3, "blocks": 1, ...}`` against a
    parameter dict) whose leaves are positive ints. Returns a tree matching
    ``like_tree`` exactly, validated eagerly — step builders call this at build
    time so a malformed quorum tree fails before tracing, not mid-run.
    """
    def check(q):
        if isinstance(q, bool) or not isinstance(q, int) or q < 1:
            raise ValueError(
                f"quorum entries must be ints >= 1, got {q!r} ({type(q).__name__})")
        return q

    if isinstance(quorum, int) and not isinstance(quorum, bool):
        check(quorum)
        return jax.tree_util.tree_map(lambda _: quorum, like_tree)
    qdef = jax.tree_util.tree_structure(quorum)
    try:
        subtrees = qdef.flatten_up_to(like_tree)
    except ValueError as e:
        raise ValueError(
            f"quorum tree is not a prefix of the parameter tree: {e}") from None
    out = [jax.tree_util.tree_map(lambda _, q=check(q): q, sub)
           for q, sub in zip(jax.tree_util.tree_leaves(quorum), subtrees)]
    return jax.tree_util.tree_unflatten(qdef, out)


# ---------------------------------------------------------------------------
# Worker-side primitive
# ---------------------------------------------------------------------------

def compress_leaf(
    g: jnp.ndarray,
    cfg: "CompressionConfig",
    seed,
    counter_base=0,
    *,
    shared_linf=None,
    backend: Optional[str] = None,
    wire=None,
) -> CompressedGrad:
    """Q(g, B): one worker's uplink message for a single tensor leaf.

    Dispatch is a ``CompressorSpec`` lookup: compressors with a registered
    Pallas op take the fused kernel on the pallas/interpret backends (RNG
    regenerated in-register — no chunking needed at any size); everything
    else, and the jnp backend, runs the normalized reference path (chunked for
    the counter-indexed families).

    ``shared_linf`` is the psum-max'd worker L-inf (``needs_shared_linf``):
    it feeds both the ``linf_share`` budget policy and the ``shared_max``
    scale protocol (TernGrad's magnitude sharing).

    ``wire`` (a ``repro.dist.collectives.VoteWire``, or None) selects the
    message's *wire-native* format (``wire.native_format``, validated against
    the spec's declared ``wire_format``). When the wire wants a packed format
    — 2-bit codes or the Golomb entropy-coded stream for ternary
    compressors, int8 sign*level for pack8 —
    ``values`` is the packed canonical view, produced in one fused pass
    (gradient -> wire bytes, no int8 ternary / int32 level tensor in HBM)
    when the spec registers a ``fused_pack_op``, else compressed then packed.
    The bytes are identical either way; only the number of HBM round-trips
    differs. Scale-carrying compressors return their decode scale in
    ``msg.scale`` alongside the (packed) payload.
    """
    backend = resolve_backend(backend)
    spec: CompressorSpec = get_spec(cfg.compressor)
    if shared_linf is None and needs_shared_linf(cfg):
        mapped = compat.manual_axis_names()
        if mapped:
            raise ValueError(
                f"compressor {cfg.compressor!r} needs the magnitude-shared "
                f"worker L-inf (scale protocol "
                f"{spec.scale_protocol!r} / budget kind {cfg.budget.kind!r}) "
                f"but compress_leaf was called inside a mapped context (axes "
                f"{sorted(mapped)}) without shared_linf=. Degrading to the "
                f"per-worker local norm here would silently give every worker "
                f"its own TernGrad normalizer — the exact drift the sharing "
                f"protocol exists to kill. Reduce "
                f"collectives.worker_shared_linf over the worker axes and "
                f"pass it; the local-norm fallback is only valid for the "
                f"single-worker public API outside a mesh.")
    budget = resolve_budget(cfg.budget, g, shared_linf=shared_linf)
    scale = spec.resolve_scale(g, shared_linf=shared_linf)
    param = budget if scale is None else scale
    msg_scale = jnp.float32(1.0) if scale is None else scale.astype(jnp.float32)
    wire_fmt = wire.native_format if wire is not None else None
    want_packed = wire_fmt in ("pack2", "golomb", "pack8")
    if want_packed and spec.wire_format != wire_fmt:
        raise ValueError(
            f"the {wire_fmt!r} wire carries "
            f"{'int8 sign*level' if wire_fmt == 'pack8' else 'ternary'} "
            f"messages only; compressor {cfg.compressor!r} declares wire "
            f"format {spec.wire_format!r}")
    interpret = backend == "interpret"
    # the golomb wire's static capacity is sized by its plan-time nonzero
    # fraction — the fused/two-pass encoders must use the SAME p or the
    # payload shape disagrees with the wire ledger at trace time (loudly)
    fused_kwargs = {"p": wire.p} if wire_fmt == "golomb" else {}
    if backend != "jnp" and spec.pallas_op is not None:
        if want_packed and spec.fused_pack_op is not None:
            packed = spec.fused_pack_op(g, param, seed, counter_base,
                                        interpret=interpret, **fused_kwargs)
            return CompressedGrad(values=packed, scale=msg_scale)
        vals = spec.pallas_op(g, param, seed, counter_base, interpret=interpret)
    elif spec.chunkable:
        vals = chunked_values(spec.values, g, param, seed, counter_base)
    else:
        vals = spec.values(g, param, seed, counter_base)
    if want_packed:
        # two-pass fallback (specs without a fused kernel, and the jnp
        # reference backend): same wire bytes, one extra round-trip
        if wire_fmt == "pack8":
            # the pack8 payload IS the canonical int8 view of the levels
            view, _ = kcommon.to_2d(vals.reshape(-1))
            return CompressedGrad(values=view, scale=msg_scale)
        if wire_fmt == "golomb":
            if backend == "jnp":
                packed = golomb_encode_ref(vals, p=wire.p)
            else:
                packed = golomb_pack_op(vals, p=wire.p, interpret=interpret)
            return CompressedGrad(values=packed, scale=msg_scale)
        if backend == "jnp":
            view, _ = kcommon.to_2d(vals.reshape(-1))
            packed = pack2bit_ref(view)
        else:
            packed = pack2bit_op(vals, interpret=interpret)
        return CompressedGrad(values=packed, scale=msg_scale)
    return CompressedGrad(values=vals, scale=msg_scale)


def compress_leaf_rows(
    g: jnp.ndarray,
    cfg: "CompressionConfig",
    seed,
    counter_base=0,
    *,
    rows: int,
    shared_linf=None,
    backend: Optional[str] = None,
    wire=None,
) -> CompressedGrad:
    """``compress_leaf`` straight into a bucket slice: the wire-native message
    reshaped/trimmed to exactly ``rows`` canonical payload rows (the leaf's
    ``bucketing.LeafSlot`` slice). The compression itself — seeds,
    counter_base, budget/scale resolution — is byte-identical to the per-leaf
    path; only the buffer layout changes (packed canonical views drop their
    per-leaf sublane zero-pad rows, leaf-shaped votes pad into rows), so a
    slot's payload is bitwise the per-leaf wire message."""
    from repro.dist import bucketing  # lazy: dist layers import this module
    msg = compress_leaf(g, cfg, seed, counter_base, shared_linf=shared_linf,
                        backend=backend, wire=wire)
    return CompressedGrad(
        values=bucketing.as_rows(msg.values, wire.native_format, rows),
        scale=msg.scale)


# ---------------------------------------------------------------------------
# Server-side primitive
# ---------------------------------------------------------------------------

def server_apply(
    p: jnp.ndarray,
    vote_sum: jnp.ndarray,
    cfg: "CompressionConfig",
    *,
    lr,
    ef=None,
    n_sel=None,
    server: Optional[str] = None,
    scale=None,
    leaf_size: Optional[int] = None,
    l1_reduce: Optional[Callable] = None,
    quorum: int = 1,
    part_total=None,
    q_frac: Optional[float] = None,
    backend: Optional[str] = None,
):
    """C(sum of worker messages) [+ EF] + SGD for one leaf (or leaf shard).

    Returns ``(new_p, new_ef)`` with ``new_p`` in ``p.dtype``.

    - ``majority_vote``:  p - lr * sign(vote_sum); integer votes take the fused
      ``vote_update`` kernel on the pallas/interpret backends. ``ef`` passes
      through untouched.
    - ``scaled_sign_ef``: acc = vote_sum/n_sel + ef; scale = ||acc||_1/leaf_size
      (``l1_reduce`` hook lets streamed mode psum the partial L1 across FSDP
      shards); update = scale*sign(acc) via the fused ``ef_server`` kernel;
      new_ef = acc - update.
    - ``mean``:           p - lr * scale * vote_sum/n_sel. ``vote_sum`` is the
      sum of decoded float messages (the per-worker-scale wire, ``scale``
      None/1) or the raw ternary vote sum with ``scale`` the shared decode
      scale (the ``scaled_votes`` wire — TernGrad's magnitude-shared s_t).

    ``server`` overrides ``cfg.server`` (the non-ternary baselines always
    aggregate by mean regardless of the configured rule).

    Elastic participation (``part_total`` + ``q_frac``): ``vote_sum`` is the
    WEIGHTED f32 vote ``sum_m w_m * votes_m`` from the wire's weighted
    exchange and ``part_total`` the realized participation
    ``W = sum_reporting w_m`` (scalar, or per-coordinate on the psum wires).
    The majority-vote deadband normalizes to it: no step unless
    ``|vote_sum| >= q_frac * W`` (the fused ``weighted_vote_update`` kernel).
    Mean servers instead pass ``part_total`` as ``n_sel`` — the divisor IS
    the realized participation. ``scaled_sign_ef`` rejects elastic input
    (``check_participation_server`` — also enforced at step build).
    """
    backend = resolve_backend(backend)
    rule = server if server is not None else cfg.server
    lr = jnp.asarray(lr, jnp.float32)

    if part_total is not None:
        check_participation_server(rule, cfg.compressor)

    if rule == "majority_vote":
        if part_total is not None:
            if q_frac is None:
                raise ValueError(
                    "elastic majority vote needs q_frac (the quorum as a "
                    "fraction of realized participation) next to part_total")
            wv = vote_sum.astype(jnp.float32)
            if backend != "jnp":
                new_p = weighted_vote_update_op(
                    p, wv, part_total, lr, q_frac=float(q_frac),
                    interpret=(backend == "interpret"))
            else:
                new_p = weighted_vote_update_ref(p, wv, part_total, lr,
                                                 q_frac=float(q_frac))
            return new_p, ef
        if jnp.issubdtype(vote_sum.dtype, jnp.integer):
            if backend != "jnp":
                new_p = vote_update_op(p, vote_sum, lr, quorum=quorum,
                                       interpret=(backend == "interpret"))
            else:
                new_p = vote_update_ref(p, vote_sum, lr, quorum=quorum)
        else:
            # float votes (decoded-sum wire, e.g. the FL sim): sign directly —
            # the int-vote kernel/oracle would truncate fractional sums
            v = vote_sum
            step = (jnp.where(jnp.abs(v) >= quorum, jnp.sign(v), 0) if quorum > 1
                    else jnp.sign(v)).astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, ef

    if rule == "mean":
        assert n_sel is not None, "mean server needs n_sel (|S|)"
        upd = vote_sum.astype(jnp.float32) / jnp.maximum(jnp.asarray(n_sel, jnp.float32), 1.0)
        if scale is not None:
            upd = upd * jnp.asarray(scale, jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), ef

    if rule == "scaled_sign_ef":
        assert ef is not None and n_sel is not None, "scaled_sign_ef needs ef + n_sel"
        mean_delta = vote_sum.astype(jnp.float32) / jnp.maximum(
            jnp.asarray(n_sel, jnp.float32), 1.0)
        eff = ef.astype(jnp.float32)
        part = jnp.sum(jnp.abs(mean_delta + eff))
        if l1_reduce is not None:
            part = l1_reduce(part)
        size = leaf_size if leaf_size is not None else mean_delta.size
        srv_scale = part / jnp.float32(size)
        if backend != "jnp":
            upd, new_ef = ef_server_op(mean_delta, eff, srv_scale,
                                       interpret=(backend == "interpret"))
        else:
            upd, new_ef = ef_server_ref(mean_delta, eff, srv_scale)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_ef

    raise ValueError(f"unknown server rule {rule!r}; known: {SERVER_RULES}")
