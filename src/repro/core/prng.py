"""Counter-based hash RNG shared by the jnp reference path and the Pallas kernels.

The sparsign compressor needs one Bernoulli draw per gradient coordinate per
round. We derive it from ``mix(seed ^ hash(counter))`` where ``counter`` is the
*logical* (flattened, global) coordinate index. Because the stream is indexed by
logical coordinate — not by device or tile — compressed training is bitwise
reproducible across sharding layouts, and the Pallas kernel can regenerate the
exact same stream from ``(seed, block_start + iota)`` without reading random bits
from HBM (halving the memory traffic of the compression pass).

The mixer is the murmur3/splitmix 32-bit finalizer: not cryptographic, but it
passes the statistical bar for sparsification masks (empirically validated in
tests/test_prng.py against frequency/pair-correlation checks).
"""

from __future__ import annotations

import jax.numpy as jnp

# murmur3 finalizer constants
_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
_GOLDEN = jnp.uint32(0x9E3779B9)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 input."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_counter(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """uint32 hash of a (seed, counter) pair; counter is int32/uint32 array."""
    c = counter.astype(jnp.uint32) * _GOLDEN
    s = jnp.asarray(seed, dtype=jnp.uint32)
    return mix32(c ^ mix32(s + _GOLDEN))


def uniform01(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """float32 uniforms in [0, 1) from the counter stream.

    Uses the top 24 bits so the value is exactly representable in float32
    (identical on TPU/CPU, no rounding ambiguity at the Bernoulli threshold).
    """
    bits = hash_counter(seed, counter)
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def fold_seed(seed, *salts: int) -> jnp.ndarray:
    """Derive an independent stream seed (e.g. per round / per leaf / per worker)."""
    s = jnp.asarray(seed, dtype=jnp.uint32)
    for salt in salts:
        s = mix32(s ^ (jnp.asarray(salt, dtype=jnp.uint32) * _GOLDEN))
    return s
