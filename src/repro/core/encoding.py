"""Communication-bit accounting (paper §6, Eq. 12) + wire formats.

Two views of "how many bits does a round cost":

1. *Information-theoretic* (what the paper tabulates): sparse ternary streams are
   coded as Golomb-coded run lengths of the nonzero positions plus 1 sign bit per
   nonzero (Sattler et al. 2019a). Eq. 12:

       b_bar = b* + 1 / (1 - (1-p)^(2^b*)),
       b*    = 1 + floor(log2( log(phi - ?) ... ))   [see golomb_bstar]

   with p the nonzero (sparsity) ratio. Dense ternary costs log2(3) bits/coord;
   sign costs 1 bit/coord; fp32 costs 32.

2. *Physical TPU wire bytes*: what the HLO collectives actually move (int8 votes
   or 2-bit packed lanes). Reported by the dry-run; see launch/hlo_stats.py.

Keeping both lets us reproduce the paper's tables exactly while also reporting
honest hardware numbers.
"""

from __future__ import annotations

import math

GOLDEN_RATIO = (math.sqrt(5.0) + 1.0) / 2.0


def golomb_bstar(p: float) -> int:
    """Optimal Golomb parameter b* = 1 + floor(log2(log(phi-1)/log(1-p))).

    (Sattler et al. 2019a; the paper's Eq. 12 writes log(sqrt(5)+1/2) which is
    the same phi-based constant.) p is the nonzero ratio in (0, 1).
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"sparsity ratio p must be in (0,1), got {p}")
    num = math.log(GOLDEN_RATIO - 1.0)  # log(0.618...) < 0
    # log1p, not log(1-p): at p ~< 1e-17, 1.0-p rounds to 1.0 and log(1.0-p)
    # underflows to -0.0 -> ZeroDivisionError in the ratio below
    den = math.log1p(-p)                # < 0
    ratio = num / den
    if ratio <= 1.0:
        # p -> 1: run lengths are almost all zero; log2(ratio) -> -inf (and
        # int(floor(-inf)) raises), but the optimal parameter is simply b*=0
        return 0
    return max(0, 1 + int(math.floor(math.log2(ratio))))


def golomb_bits_per_index(p: float) -> float:
    """Average bits per nonzero index, Eq. 12."""
    bstar = golomb_bstar(p)
    # 1 - (1-p)^k via expm1(k*log1p(-p)): the direct form rounds to 1.0 - 1.0
    # = 0.0 at tiny p (ZeroDivisionError); the log-space form keeps the ~k*p
    # leading term exactly
    denom = -math.expm1((2.0 ** bstar) * math.log1p(-p))
    return bstar + 1.0 / denom


def ternary_stream_bits(d: int, nnz: int, *, coder: str = "golomb") -> float:
    """Total uplink bits for one worker's d-dim ternary message with nnz nonzeros.

    golomb: Eq. 12 position bits + 1 sign bit per nonzero (paper's accounting).
    dense:  log2(3) bits per coordinate (Wen et al. 2017).
    naive_index: log2(d) bits per nonzero index + 1 sign bit (Remark 8).
    packed2bit: the TPU wire format - 2 bits per coordinate.

    nnz <= 0 is a valid message (an all-zero round): the sparse coders
    (golomb, naive_index) ship nothing, but the dense coders still pay their
    d-proportional flat cost — the old blanket ``return 0.0`` short-circuit
    silently zeroed dense/packed2bit streams too.
    """
    if coder not in ("golomb", "dense", "naive_index", "packed2bit"):
        raise ValueError(f"unknown coder {coder!r}")
    if coder == "dense":
        return d * math.log2(3.0)
    if coder == "packed2bit":
        return d * 2.0
    if nnz <= 0:
        return 0.0
    p = min(max(nnz / d, 1e-12), 1.0 - 1e-12)
    if coder == "golomb":
        return nnz * (golomb_bits_per_index(p) + 1.0)
    return nnz * (math.log2(max(d, 2)) + 1.0)


def round_bits(
    d: int,
    nnz_per_worker: float,
    n_workers: int,
    *,
    coder: str = "golomb",
    downlink: str = "sign",
) -> float:
    """Worker->server bits for one communication round (the paper's tables count
    uplink only; downlink option included for completeness).

    downlink: 'sign' = 1 bit/coord broadcast, 'ternary' = Golomb again, 'free' =
    TPU majority-vote-by-psum (no broadcast at all).
    """
    up = n_workers * ternary_stream_bits(d, int(round(nnz_per_worker)), coder=coder)
    if downlink == "free":
        down = 0.0
    elif downlink == "sign":
        down = d
    elif downlink == "ternary":
        down = ternary_stream_bits(d, int(round(nnz_per_worker)), coder=coder)
    else:
        raise ValueError(downlink)
    return up + down


def baseline_bits_per_round(d: int, algorithm: str, *, nnz: float | None = None) -> float:
    """Uplink bits per worker per round for each §6 baseline.

    The bit model is a ``CompressorSpec`` lookup (``spec.uplink_bits``) — no
    algorithm-name branching, so a new registry row is automatically costable.
    """
    from repro.core.compressors import get_spec  # lazy: encoding is dependency-free

    try:
        model = get_spec(algorithm).uplink_bits
    except KeyError as e:
        raise ValueError(str(e)) from None
    if model == "dense_sign":
        return float(d)  # 1 bit per coordinate (+32 for the scale; negligible, matches paper)
    if model == "golomb_ternary":
        assert nnz is not None, "ternary methods need the realized nnz"
        return ternary_stream_bits(d, int(round(nnz)), coder="golomb") + 32.0
    if model == "fp32":
        return 32.0 * d
    # level8 — FedCom 8-bit QSGD on the pack8 wire: 1 sign bit + 7 level bits
    # per coordinate, plus the one 32-bit decode scale per message — the same
    # accounting the VoteWire ledger (wire_bytes + scalar_bytes) reports
    return 8.0 * d + 32.0
