"""Compression-budget (B) policies.

Definition 1 requires B_{m,i} <= 1/|g_{m,i}| for exact probabilities; Remark 7
notes that fixed budgets with probability clipping are equivalent to gradient
clipping and are what the paper's experiments use (B in {0.01, 0.1, 1}, and
B_l=10, B_g=1 for EF-SPARSIGNSGD). We support:

  fixed:      B constant (paper's experimental choice).
  linf_share: TernGrad-style magnitude sharing — B = 1 / max_m ||g_m||_inf,
              needs one scalar all-reduce(max) per round (32 bits of uplink).
  l2_norm:    B = sqrt(d) / ||g||_2 (keeps expected sparsity ~ |g| E[non-zeros]).
  target_sparsity: pick B so the *expected* nonzero fraction equals a target:
              E[nnz]/d = mean(min(|g| B, 1)) -> solved per tensor by a few
              bisection steps (monotone in B). This is the knob a production
              deployment actually wants ("spend at most k bits/coord").
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    kind: str = "fixed"          # fixed | linf_share | l2_norm | target_sparsity
    value: float = 1.0           # B for fixed; target nnz fraction for target_sparsity
    local_value: Optional[float] = None  # B_l for local steps (EF-SPARSIGNSGD); None -> value


def expected_sparsity(g: jnp.ndarray, budget) -> jnp.ndarray:
    """E[nnz]/d = mean(clip(|g| * B, 0, 1)) (Def. 1)."""
    return jnp.mean(jnp.clip(jnp.abs(g.astype(jnp.float32)) * budget, 0.0, 1.0))


def solve_budget_for_sparsity(g: jnp.ndarray, target: float, iters: int = 30) -> jnp.ndarray:
    """Bisection for B with mean(clip(|g|B,0,1)) == target. Monotone, so robust.

    GEOMETRIC bisection (halving log B, mid = sqrt(lo*hi)): the bracket spans
    up to [1e-12, 1/min|g|] ~ 1e32, and a linear split spends its iterations
    resolving the top of that range — with a heavy-tailed gradient (min
    nonzero |g| ~ 1e-11, so hi0 ~ 1e10) 30 linear halvings leave an interval
    of width ~10 around a solution of order 1, silently overshooting the
    target sparsity by 3x+. Log-space, 30 halvings resolve the full 32-decade
    bracket to < 1e-6 relative everywhere."""
    absg = jnp.abs(g.astype(jnp.float32)).reshape(-1)
    hi0 = 1.0 / jnp.maximum(jnp.min(jnp.where(absg > 0, absg, jnp.inf)), 1e-20)
    hi0 = jnp.minimum(hi0, jnp.float32(1e20))
    lo0 = jnp.minimum(jnp.float32(1e-12), hi0)

    def body(_, lohi):
        lo, hi = lohi
        # sqrt(lo)*sqrt(hi), not sqrt(lo*hi): lo*hi can overflow f32
        mid = jnp.sqrt(lo) * jnp.sqrt(hi)
        s = jnp.mean(jnp.clip(absg * mid, 0.0, 1.0))
        return jnp.where(s < target, mid, lo), jnp.where(s < target, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    return jnp.sqrt(lo) * jnp.sqrt(hi)


def resolve_budget(cfg: BudgetConfig, g: jnp.ndarray, *, shared_linf: Optional[jnp.ndarray] = None):
    """Returns the scalar B to feed sparsign for tensor ``g``."""
    if cfg.kind == "fixed":
        return jnp.float32(cfg.value)
    if cfg.kind == "linf_share":
        s = shared_linf if shared_linf is not None else jnp.max(jnp.abs(g.astype(jnp.float32)))
        return jnp.float32(1.0) / jnp.maximum(s, 1e-12)
    if cfg.kind == "l2_norm":
        n = jnp.linalg.norm(g.astype(jnp.float32).reshape(-1))
        return jnp.sqrt(jnp.float32(g.size)) / jnp.maximum(n, 1e-12) * jnp.float32(cfg.value)
    if cfg.kind == "target_sparsity":
        return solve_budget_for_sparsity(g, cfg.value)
    raise ValueError(f"unknown budget kind {cfg.kind!r}")
