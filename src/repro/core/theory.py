"""Executable versions of the paper's theory (Thm 1, Cor 1, Thm 2's kappa).

These are used by tests (Monte-Carlo vs closed-form bound) and by the
bench_theory_bound benchmark that reproduces the 'probability of wrong
aggregation' curves of Figs 1-2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wrong_aggregation_bound(p_bar, q_bar, m: int):
    """Theorem 1: P(wrong vote) <= [1 - (sqrt(q_bar) - sqrt(p_bar))^2]^M, valid
    when q_bar > p_bar."""
    p_bar = jnp.asarray(p_bar, jnp.float64) if jax.config.jax_enable_x64 else jnp.asarray(p_bar, jnp.float32)
    base = 1.0 - (jnp.sqrt(q_bar) - jnp.sqrt(p_bar)) ** 2
    return base ** m


def sparsign_pq(u: jnp.ndarray, budget, p_select=1.0):
    """Corollary 1: (p_bar, q_bar) for sparsign on fixed worker scalars u_m.

    A  = workers whose sign disagrees with sign(mean(u))  -> contribute to p_bar
    Ac = workers whose sign agrees                        -> contribute to q_bar
    """
    u = u.astype(jnp.float32)
    s = jnp.sign(jnp.mean(u))
    keep_prob = jnp.clip(jnp.abs(u) * budget, 0.0, 1.0) * p_select
    agree = jnp.sign(u) == s
    q_bar = jnp.mean(jnp.where(agree & (jnp.sign(u) != 0), keep_prob, 0.0))
    p_bar = jnp.mean(jnp.where(~agree & (jnp.sign(u) != 0), keep_prob, 0.0))
    return p_bar, q_bar


def deterministic_sign_pq(u: jnp.ndarray, p_select=1.0):
    """(p_bar, q_bar) for the deterministic sign compressor (signSGD): every
    selected worker always transmits its sign."""
    u = u.astype(jnp.float32)
    s = jnp.sign(jnp.mean(u))
    agree = (jnp.sign(u) == s) & (jnp.sign(u) != 0)
    disagree = (jnp.sign(u) != s) & (jnp.sign(u) != 0)
    return jnp.mean(jnp.where(disagree, p_select, 0.0)), jnp.mean(jnp.where(agree, p_select, 0.0))


def monte_carlo_wrong_aggregation(key, u: jnp.ndarray, budget, n_trials: int = 4096,
                                  p_select: float = 1.0, n_sampled: int | None = None):
    """Empirical P(sign(sum of sparsign votes) != sign(mean u)) by simulation.

    Ties (vote sum == 0) count as wrong (no update in the right direction),
    matching the X_m >= 0 event in the Thm 1 proof.
    """
    m = u.shape[0]
    s = jnp.sign(jnp.mean(u))

    def trial(k):
        k1, k2 = jax.random.split(k)
        if n_sampled is not None:
            sel = jax.random.permutation(k1, m)[:n_sampled]
            mask = jnp.zeros((m,), bool).at[sel].set(True)
        else:
            mask = jax.random.uniform(k1, (m,)) < p_select
        keep = jax.random.uniform(k2, (m,)) < jnp.clip(jnp.abs(u) * budget, 0.0, 1.0)
        votes = jnp.where(mask & keep, jnp.sign(u), 0.0)
        return jnp.sign(jnp.sum(votes)) != s

    wrong = jax.vmap(trial)(jax.random.split(key, n_trials))
    return jnp.mean(wrong.astype(jnp.float32))


def kappa(g_workers: jnp.ndarray, budget, p_select=1.0):
    """Theorem 2's kappa for one coordinate given the per-worker gradients
    g_workers [M]. kappa < 1/2 is the convergence-enabling event."""
    g = g_workers.astype(jnp.float32)
    m = g.shape[0]
    mean_g = jnp.mean(g)
    s = jnp.sign(mean_g)
    agree = jnp.sign(g) == s
    sum_agree = jnp.sum(jnp.where(agree, jnp.abs(g), 0.0)) / m
    sum_dis = jnp.sum(jnp.where(~agree, jnp.abs(g), 0.0)) / m
    denom = (jnp.sqrt(sum_agree) + jnp.sqrt(sum_dis)) ** 2
    ratio = jnp.abs(mean_g) / jnp.maximum(denom, 1e-20)
    return (1.0 - budget * p_select * ratio) ** m
