"""Server-side error feedback (Algorithm 2, Eq. 8) — the only stateful piece.

    g_tilde  = C(mean_delta + e)          # alpha-approximate compressor
    e'       = mean_delta + e - g_tilde   # residual for the next round

The residual lives on the *server only*; workers remain stateless, which is what
keeps the method compatible with partial participation (the paper's core
deployment argument vs EF-SIGNSGD / SSDM). In the TPU mapping the residual is
replicated across data ranks and updated identically everywhere (deterministic),
so it costs zero collectives.

Lemma 2: ||e||_2^2 <= beta * d for some beta — asserted in tests/test_ef.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import scaled_sign_server


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EFState:
    residual: jnp.ndarray  # float32, same shape as the (flattened or leaf) update


def init_ef(shape_like: jnp.ndarray) -> EFState:
    return EFState(residual=jnp.zeros(shape_like.shape, dtype=jnp.float32))


def ef_server_step(
    state: EFState,
    mean_delta: jnp.ndarray,
    server_compressor: Callable[[jnp.ndarray], jnp.ndarray] = scaled_sign_server,
) -> tuple[jnp.ndarray, EFState]:
    """One server round: returns (g_tilde, new_state)."""
    acc = mean_delta.astype(jnp.float32) + state.residual
    g_tilde = server_compressor(acc)
    return g_tilde, EFState(residual=acc - g_tilde)


def ef_server_step_tree(state_tree, mean_delta_tree, server_compressor=scaled_sign_server):
    """Leaf-wise EF over a gradient pytree. scaled-sign is applied per-leaf
    (per-tensor scaling — matches how the paper's single-vector math is deployed
    on a multi-tensor model; per-leaf scales are strictly more expressive)."""
    flat_s, treedef = jax.tree_util.tree_flatten(state_tree, is_leaf=lambda x: isinstance(x, EFState))
    flat_d = treedef.flatten_up_to(mean_delta_tree)
    outs, new_states = [], []
    for s, d in zip(flat_s, flat_d):
        g, ns = ef_server_step(s, d, server_compressor)
        outs.append(g)
        new_states.append(ns)
    return (
        jax.tree_util.tree_unflatten(treedef, outs),
        jax.tree_util.tree_unflatten(treedef, new_states),
    )


def init_ef_tree(tree) -> object:
    return jax.tree_util.tree_map(init_ef, tree)
