"""repro.core — the paper's contribution (SPARSIGNSGD / EF-SPARSIGNSGD) as
composable JAX building blocks."""

from repro.core.algorithm import (
    CompressionConfig,
    local_update_message,
    local_update_source,
    reference_round,
    server_update,
    worker_message,
    worker_stream_seed,
)
from repro.core.budgets import BudgetConfig, expected_sparsity, resolve_budget
from repro.core.compressors import (
    COMPRESSORS,
    SPECS,
    CompressedGrad,
    CompressorSpec,
    compress_tree,
    get_compressor,
    get_spec,
    sparsign,
)
from repro.core.engine import compress_leaf, resolve_backend, server_apply
from repro.core.error_feedback import EFState, ef_server_step, init_ef
from repro.core.aggregation import majority_vote, scaled_sign_server

__all__ = [
    "CompressionConfig",
    "compress_leaf",
    "resolve_backend",
    "server_apply",
    "BudgetConfig",
    "CompressedGrad",
    "CompressorSpec",
    "COMPRESSORS",
    "SPECS",
    "EFState",
    "compress_tree",
    "ef_server_step",
    "expected_sparsity",
    "get_compressor",
    "get_spec",
    "init_ef",
    "local_update_message",
    "local_update_source",
    "majority_vote",
    "reference_round",
    "resolve_budget",
    "scaled_sign_server",
    "server_update",
    "sparsign",
    "worker_message",
    "worker_stream_seed",
]
