"""Pure-jnp oracle for 2-bit ternary packing.

Wire format: *block-interleaved* packing over the canonical (rows, LANES) view.
Byte j of a row packs the 4 ternary symbols at columns
(j, j + L/4, j + 2L/4, j + 3L/4) — contiguous lane slices, so the TPU kernel is
pure vector ops (no sub-lane shuffles). Codes: 0 -> 00, +1 -> 01, -1 -> 10.

Any decoder must use the same (documented) permutation; unpack(pack(x)) == x is
the property tests enforce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _encode(t: jnp.ndarray) -> jnp.ndarray:
    """ternary int8 {-1,0,1} -> 2-bit code uint8 {2,0,1}."""
    return jnp.where(t < 0, jnp.uint8(2), t.astype(jnp.uint8))


def _decode(c: jnp.ndarray) -> jnp.ndarray:
    """2-bit code -> ternary int8. Code 3 (invalid) decodes as 0."""
    return jnp.where(c == 1, jnp.int8(1), jnp.where(c == 2, jnp.int8(-1), jnp.int8(0)))


def pack2bit_ref(t2d: jnp.ndarray) -> jnp.ndarray:
    """(rows, L) int8 ternary -> (rows, L//4) uint8."""
    rows, lanes = t2d.shape
    q = lanes // 4
    c0 = _encode(t2d[:, 0 * q:1 * q])
    c1 = _encode(t2d[:, 1 * q:2 * q])
    c2 = _encode(t2d[:, 2 * q:3 * q])
    c3 = _encode(t2d[:, 3 * q:4 * q])
    return c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)


def unpack2bit_ref(p2d: jnp.ndarray) -> jnp.ndarray:
    """(rows, L//4) uint8 -> (rows, L) int8 ternary."""
    parts = [_decode((p2d >> (2 * k)) & jnp.uint8(3)) for k in range(4)]
    return jnp.concatenate(parts, axis=1)


def unpack2bit_sum_ref(gathered: jnp.ndarray) -> jnp.ndarray:
    """(M, rows, L//4) packed worker votes -> (rows, L) int32 vote sum.

    Oracle for the fused decode+accumulate kernel: vmapped decode then sum
    (deliberately materializes the int8 tensor the kernel avoids)."""
    ternary = jax.vmap(unpack2bit_ref)(gathered)
    return jnp.sum(ternary.astype(jnp.int32), axis=0)


def unpack2bit_wsum_ref(gathered: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(M, rows, L//4) packed worker votes + (M,) f32 weights -> (rows, L)
    f32 weighted vote sum ``sum_m weights[m] * votes_m``.

    Oracle for the elastic-participation decode: the python loop accumulates
    strictly in worker order, the association the fused kernel's unrolled
    accumulator reproduces (for weights == 1 the ternary products are exact
    integers, so the sum is bitwise the int32 ``unpack2bit_sum_ref`` stream
    up to dtype)."""
    m, rows, q = gathered.shape
    acc = jnp.zeros((rows, q * 4), jnp.float32)
    for i in range(m):
        acc = acc + unpack2bit_ref(gathered[i]).astype(jnp.float32) * weights[i]
    return acc
