"""Public pack/unpack ops over arbitrary-shape ternary tensors."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.pack2bit.kernel import (pack2bit_2d, unpack2bit_2d,
                                           unpack2bit_sum_2d,
                                           unpack2bit_wsum_2d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack2bit_op(t: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """int8 ternary (any shape) -> packed uint8 of the canonical 2D view.

    Returns the (rows, LANES//4) packed array; pair with ``unpack2bit_op(packed,
    orig_size, orig_shape)`` to invert. The canonical view is part of the wire
    format (see ref.py docstring).
    """
    if interpret is None:
        interpret = common.default_interpret()
    view, _ = common.to_2d(t.reshape(-1))
    br = common.block_rows_for(view.shape[0])
    return pack2bit_2d(view, block_rows=br, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n", "shape", "interpret"))
def unpack2bit_op(packed: jnp.ndarray, n: int, shape, *, interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = common.default_interpret()
    br = common.block_rows_for(packed.shape[0])
    t2d = unpack2bit_2d(packed, block_rows=br, interpret=interpret)
    return common.from_2d(t2d, n, shape)


@functools.partial(jax.jit, static_argnames=("n", "shape", "interpret"))
def unpack2bit_sum_op(gathered: jnp.ndarray, n: int, shape, *,
                      interpret: bool | None = None) -> jnp.ndarray:
    """(M, rows, LANES//4) gathered packed votes -> int32 vote sum in ``shape``.

    Fused decode+accumulate (see unpack2bit_sum_2d); the decode side of the
    ``allgather_packed`` wire. Block rows shrink with M so the (M, block, q)
    input block stays within a ~2 MiB VMEM budget at any worker count.
    """
    if interpret is None:
        interpret = common.default_interpret()
    m, rows, q = gathered.shape
    want = max(common.SUBLANE_PAD, min(common.DEFAULT_BLOCK_ROWS, (1 << 21) // max(1, m * q)))
    br = common.block_rows_for(rows, want=want)
    total2d = unpack2bit_sum_2d(gathered, block_rows=br, interpret=interpret)
    return common.from_2d(total2d, n, shape)


@functools.partial(jax.jit, static_argnames=("n", "shape", "interpret"))
def unpack2bit_wsum_op(gathered: jnp.ndarray, weights: jnp.ndarray, n: int,
                       shape, *, interpret: bool | None = None) -> jnp.ndarray:
    """(M, rows, LANES//4) gathered packed votes + (M,) f32 per-worker weights
    -> f32 weighted vote sum ``sum_m weights[m] * votes_m`` in ``shape``.

    The elastic-participation decode of the ``allgather_packed`` wire: weights
    ride the gather as a billed side channel; a dropped worker (zero payload,
    zero weight) contributes exact zeros. Same VMEM budget rule as
    ``unpack2bit_sum_op``.
    """
    if interpret is None:
        interpret = common.default_interpret()
    m, rows, q = gathered.shape
    want = max(common.SUBLANE_PAD, min(common.DEFAULT_BLOCK_ROWS, (1 << 21) // max(1, m * q)))
    br = common.block_rows_for(rows, want=want)
    w = weights.astype(jnp.float32).reshape(1, m)
    total2d = unpack2bit_wsum_2d(gathered, w, block_rows=br, interpret=interpret)
    return common.from_2d(total2d, n, shape)
