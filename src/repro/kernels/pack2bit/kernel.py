"""Pallas TPU kernels: 2-bit block-interleaved pack/unpack of ternary streams.

The packed stream is the uplink wire format when a ring all-gather vote is
cheaper than the int8 all-reduce (small worker counts / DCN inter-pod hop):
2 bits/coord vs 8. Pack reads 4 int8 lanes-blocks and writes 1 uint8 block
(5 B/coord-quad moved vs 8 unfused); unpack is the mirror image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _pack_kernel(t_ref, out_ref, *, quarter: int):
    t = t_ref[...]
    c0 = common.encode2bit(t[:, 0 * quarter:1 * quarter])
    c1 = common.encode2bit(t[:, 1 * quarter:2 * quarter])
    c2 = common.encode2bit(t[:, 2 * quarter:3 * quarter])
    c3 = common.encode2bit(t[:, 3 * quarter:4 * quarter])
    out_ref[...] = c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)


def _unpack_kernel(p_ref, out_ref, *, quarter: int):
    p = p_ref[...]

    def dec(c):
        return jnp.where(c == 1, jnp.int8(1), jnp.where(c == 2, jnp.int8(-1), jnp.int8(0)))

    for k in range(4):
        out_ref[:, k * quarter:(k + 1) * quarter] = dec((p >> (2 * k)) & jnp.uint8(3))


def _unpack_sum_kernel(p_ref, out_ref, *, quarter: int):
    # p_ref block: (M, block_rows, quarter) uint8 — all workers' packed votes
    # for this row block. Decode and accumulate in VMEM; only the int32 vote
    # sum (the psum-equivalent payload) is ever written back.
    p = p_ref[...]

    def dec(c):
        return jnp.where(c == 1, jnp.int32(1), jnp.where(c == 2, jnp.int32(-1), jnp.int32(0)))

    for k in range(4):
        codes = (p >> (2 * k)) & jnp.uint8(3)
        out_ref[:, k * quarter:(k + 1) * quarter] = jnp.sum(dec(codes), axis=0)


def _unpack_wsum_kernel(w_ref, p_ref, out_ref, *, quarter: int, m: int):
    # Elastic-participation decode: (M, block_rows, quarter) packed votes plus
    # (1, M) f32 per-worker weights in SMEM (the pack8 scales idiom). The
    # accumulator unrolls strictly in worker order so the float sum associates
    # exactly like the eager-loop oracle; a masked-out worker's zero payload
    # AND zero weight both force exact-zero contributions.
    p = p_ref[...]

    def dec(c):
        return jnp.where(c == 1, jnp.float32(1.0),
                         jnp.where(c == 2, jnp.float32(-1.0), jnp.float32(0.0)))

    for k in range(4):
        codes = (p >> (2 * k)) & jnp.uint8(3)
        # zero seed (not acc = first term): a zero weight times a -1 vote is
        # -0.0, and the oracle's 0.0 + (-0.0) == +0.0 must be reproduced
        acc = jnp.zeros_like(dec(codes[0]))
        for i in range(m):
            acc = acc + dec(codes[i]) * w_ref[0, i]
        out_ref[:, k * quarter:(k + 1) * quarter] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pack2bit_2d(t2d: jnp.ndarray, *, block_rows: int, interpret: bool) -> jnp.ndarray:
    rows, lanes = t2d.shape
    q = lanes // 4
    return pl.pallas_call(
        functools.partial(_pack_kernel, quarter=q),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, q), jnp.uint8),
        interpret=interpret,
    )(t2d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def unpack2bit_sum_2d(p3d: jnp.ndarray, *, block_rows: int, interpret: bool) -> jnp.ndarray:
    """(M, rows, q) packed worker votes -> (rows, 4q) int32 vote sum.

    Fused decode+accumulate for the all-gather wire: the gathered 2-bit bytes
    are read once and reduced in VMEM, so the (M, rows, LANES) int8 ternary
    tensor of the unfused vmap(unpack)->sum chain never touches HBM
    (0.25*M + 4 B/coord moved vs 0.25*M + M + M*4 + 4)."""
    m, rows, q = p3d.shape
    lanes = q * 4
    return pl.pallas_call(
        functools.partial(_unpack_sum_kernel, quarter=q),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((m, block_rows, q), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(p3d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def unpack2bit_wsum_2d(p3d: jnp.ndarray, w: jnp.ndarray, *, block_rows: int,
                       interpret: bool) -> jnp.ndarray:
    """(M, rows, q) packed worker votes + (1, M) f32 weights -> (rows, 4q)
    f32 weighted vote sum (the elastic-participation decode of the
    ``allgather_packed`` wire). Same fused decode+accumulate discipline as
    ``unpack2bit_sum_2d`` with the per-worker weights riding in SMEM."""
    m, rows, q = p3d.shape
    lanes = q * 4
    return pl.pallas_call(
        functools.partial(_unpack_wsum_kernel, quarter=q, m=m),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, block_rows, q), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        interpret=interpret,
    )(w, p3d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def unpack2bit_2d(p2d: jnp.ndarray, *, block_rows: int, interpret: bool) -> jnp.ndarray:
    rows, q = p2d.shape
    lanes = q * 4
    return pl.pallas_call(
        functools.partial(_unpack_kernel, quarter=q),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, q), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int8),
        interpret=interpret,
    )(p2d)
