"""Oracle for the fused vote->parameter-update: w' = w - eta * sign(votes).

Optionally applies a quorum threshold (beyond-paper knob): coordinates with
|votes| < quorum produce no update — a robustness/deadband filter on top of the
majority vote (quorum=1 is the paper's rule: any nonzero sum moves).
"""

from __future__ import annotations

import jax.numpy as jnp


def vote_update_ref(w: jnp.ndarray, votes: jnp.ndarray, eta, quorum: int = 1) -> jnp.ndarray:
    v = votes.astype(jnp.int32)
    step = jnp.where(jnp.abs(v) >= quorum, jnp.sign(v), 0).astype(jnp.float32)
    return (w.astype(jnp.float32) - jnp.float32(eta) * step).astype(w.dtype)
