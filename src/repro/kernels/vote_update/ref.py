"""Oracle for the fused vote->parameter-update: w' = w - eta * sign(votes).

Optionally applies a quorum threshold (beyond-paper knob): coordinates with
|votes| < quorum produce no update — a robustness/deadband filter on top of the
majority vote (quorum=1 is the paper's rule: any nonzero sum moves).
"""

from __future__ import annotations

import jax.numpy as jnp


def vote_update_ref(w: jnp.ndarray, votes: jnp.ndarray, eta, quorum: int = 1) -> jnp.ndarray:
    v = votes.astype(jnp.int32)
    step = jnp.where(jnp.abs(v) >= quorum, jnp.sign(v), 0).astype(jnp.float32)
    return (w.astype(jnp.float32) - jnp.float32(eta) * step).astype(w.dtype)


def weighted_vote_update_ref(w: jnp.ndarray, wvotes: jnp.ndarray,
                             wtot: jnp.ndarray, eta, q_frac: float) -> jnp.ndarray:
    """Elastic-participation oracle: w' = w - eta * sign(sum_m w_m sign_m)
    where the deadband is ``|sum_m w_m sign_m| >= q_frac * W`` — the quorum
    normalizes to the realized participation ``W = sum_reporting w_m``
    (``wtot``, per coordinate or broadcastable scalar) instead of a fixed
    integer M-quorum. With uniform weights and full participation (W = M,
    q_frac = quorum/M) this is bitwise ``vote_update_ref``: f32 sums of
    ternary votes are exact integers up to 2^24 and the threshold product
    recovers the integer quorum exactly on power-of-two fleets."""
    v = wvotes.astype(jnp.float32)
    thr = jnp.float32(q_frac) * jnp.broadcast_to(
        jnp.asarray(wtot, jnp.float32), v.shape)
    step = jnp.where(jnp.abs(v) >= thr, jnp.sign(v), jnp.float32(0.0))
    return (w.astype(jnp.float32) - jnp.float32(eta) * step).astype(w.dtype)
