"""Public fused vote->update op."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.vote_update.kernel import vote_update_2d, weighted_vote_update_2d


@functools.partial(jax.jit, static_argnames=("quorum", "interpret"))
def vote_update_op(w: jnp.ndarray, votes: jnp.ndarray, eta, *, quorum: int = 1,
                   interpret: bool | None = None) -> jnp.ndarray:
    """w' = w - eta * sign(votes) with quorum deadband; any shape, w dtype preserved."""
    if interpret is None:
        interpret = common.default_interpret()
    w2, n = common.to_2d(w.reshape(-1))
    v2, _ = common.to_2d(votes.reshape(-1))
    br = common.block_rows_for(w2.shape[0])
    eta_bits = jax.lax.bitcast_convert_type(jnp.asarray(eta, jnp.float32), jnp.uint32)
    scalars = jnp.stack([eta_bits, jnp.asarray(quorum, jnp.uint32)]).reshape(1, 2)
    out2 = vote_update_2d(w2, v2, scalars, block_rows=br, interpret=interpret)
    return common.from_2d(out2, n, w.shape)


@functools.partial(jax.jit, static_argnames=("q_frac", "interpret"))
def weighted_vote_update_op(w: jnp.ndarray, wvotes: jnp.ndarray, wtot,
                            eta, *, q_frac: float,
                            interpret: bool | None = None) -> jnp.ndarray:
    """Elastic update: w' = w - eta * sign(wvotes) with the
    participation-normalized deadband ``|wvotes| >= q_frac * wtot``; any
    shape, w dtype preserved. ``wtot`` (realized participation
    ``sum_reporting w_m``) may be a scalar or per-coordinate array —
    broadcast before the canonical view so padded tail coordinates see
    wtot = 0, where the zero-vote sign already produces no step."""
    if interpret is None:
        interpret = common.default_interpret()
    w2, n = common.to_2d(w.reshape(-1))
    v2, _ = common.to_2d(wvotes.astype(jnp.float32).reshape(-1))
    t = jnp.broadcast_to(jnp.asarray(wtot, jnp.float32), wvotes.shape)
    t2, _ = common.to_2d(t.reshape(-1))
    br = common.block_rows_for(w2.shape[0])
    eta_bits = jax.lax.bitcast_convert_type(jnp.asarray(eta, jnp.float32), jnp.uint32)
    qf_bits = jax.lax.bitcast_convert_type(jnp.asarray(q_frac, jnp.float32), jnp.uint32)
    scalars = jnp.stack([eta_bits, qf_bits]).reshape(1, 2)
    out2 = weighted_vote_update_2d(w2, v2, t2, scalars, block_rows=br,
                                   interpret=interpret)
    return common.from_2d(out2, n, w.shape)
