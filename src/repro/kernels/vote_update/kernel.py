"""Pallas TPU kernel: fused majority-vote sign + SGD update.

Consumes the int8/int32 vote sums straight out of the psum collective and
applies w' = w - eta * sign(votes) (with optional quorum deadband) in one pass:
read w (2/4 B) + votes (1/4 B), write w' — versus sign->cast->scale->sub jnp
chain at ~4 passes. The weight buffers are the largest arrays a round touches,
so this is the top memory-roofline win of the optimizer tail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _kernel(scalars_ref, w_ref, v_ref, out_ref):
    eta = jax.lax.bitcast_convert_type(scalars_ref[0, 0], jnp.float32)
    quorum = scalars_ref[0, 1].astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)
    step = jnp.where(jnp.abs(v) >= quorum, jnp.sign(v), 0).astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (w - eta * step).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def vote_update_2d(w2d, v2d, scalars, *, block_rows: int, interpret: bool):
    rows, lanes = w2d.shape
    spec_w = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    spec_v = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec_w, spec_v],
        out_specs=spec_w,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), w2d.dtype),
        interpret=interpret,
    )(scalars, w2d, v2d)


def _wkernel(scalars_ref, w_ref, v_ref, t_ref, out_ref):
    # scalars: [eta bits, q_frac bits] — both f32 payloads in SMEM uint32
    eta = jax.lax.bitcast_convert_type(scalars_ref[0, 0], jnp.float32)
    q_frac = jax.lax.bitcast_convert_type(scalars_ref[0, 1], jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    thr = q_frac * t_ref[...].astype(jnp.float32)
    step = jnp.where(jnp.abs(v) >= thr, jnp.sign(v), jnp.float32(0.0))
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (w - eta * step).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def weighted_vote_update_2d(w2d, v2d, t2d, scalars, *, block_rows: int,
                            interpret: bool):
    """Fused elastic update: w' = w - eta * sign(v) where |v| clears the
    participation-normalized deadband q_frac * W per coordinate. Same grid /
    block discipline as ``vote_update_2d`` with one extra f32 operand (the
    per-coordinate realized participation W)."""
    rows, lanes = w2d.shape
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        _wkernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), w2d.dtype),
        interpret=interpret,
    )(scalars, w2d, v2d, t2d)
