"""Pallas TPU kernel: fused sparsign -> 2-bit packed uplink wire.

One HBM pass from gradient to wire bytes: read g (2 or 4 B/coord), write the
block-interleaved 2-bit stream (0.25 B/coord). The Bernoulli draws are
regenerated in-register from the counter hash (identical stream to
``repro.core.prng`` / the standalone sparsign kernel) and the ternary symbols
are encoded and packed while still in VMEM — the int8 ternary tensor never
exists in HBM. The unfused ``pack2bit_op(sparsign_op(g))`` chain moves
(4+1) + (1+0.25) B/coord over two kernel launches; this kernel moves 4.25 in
one, so the ``allgather_packed`` uplink stops paying for a wire format it
immediately re-reads.

Tiling matches the constituent kernels: canonical (rows, 512) f32/bf16 input
blocks, (rows, 128) uint8 output blocks, grid over row blocks. Bitwise
equality with the two-pass chain is pinned by tests/test_wire.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import RNG_GOLDEN, encode2bit, mix32


def _kernel(scalars_ref, g_ref, out_ref, *, block_rows: int, lanes: int):
    # scalars: [seed, counter_base, budget_bits] packed as uint32 in SMEM.
    seed = scalars_ref[0, 0]
    counter_base = scalars_ref[0, 1]
    budget = jax.lax.bitcast_convert_type(scalars_ref[0, 2], jnp.float32)

    r0 = pl.program_id(0) * block_rows
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 1)
    idx = (jnp.uint32(r0) + rows) * jnp.uint32(lanes) + cols + counter_base

    # counter-hash RNG (kernels/common.mix32 — mirrors repro.core.prng exactly)
    c = idx * RNG_GOLDEN
    bits = mix32(c ^ mix32(seed + RNG_GOLDEN))
    u = (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))

    g = g_ref[...].astype(jnp.float32)
    p = jnp.clip(jnp.abs(g) * budget, 0.0, 1.0)
    t = jnp.where(u < p, jnp.sign(g), 0.0).astype(jnp.int8)

    # pack2bit's block-interleaved encoding, still in VMEM: byte j packs the
    # symbols at lane columns (j, j+L/4, j+2L/4, j+3L/4); 0->00, +1->01, -1->10
    quarter = lanes // 4
    c0 = encode2bit(t[:, 0 * quarter:1 * quarter])
    c1 = encode2bit(t[:, 1 * quarter:2 * quarter])
    c2 = encode2bit(t[:, 2 * quarter:3 * quarter])
    c3 = encode2bit(t[:, 3 * quarter:4 * quarter])
    out_ref[...] = c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sparsign_pack2bit_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *,
                         block_rows: int, interpret: bool):
    """g2d: (rows, LANES) f32/bf16; scalars: (1,3) uint32 [seed, base, budget-bits].

    Returns the (rows, LANES//4) uint8 packed wire of sparsign(g2d)."""
    rows, lanes = g2d.shape
    q = lanes // 4
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows, lanes=lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, q), jnp.uint8),
        interpret=interpret,
    )(scalars, g2d)
