"""Pure-jnp oracle for the fused sparsign->pack2bit kernel: the two-pass
composition over the shared canonical view. Bitwise-identical to the kernel by
construction of its constituents."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.pack2bit.ref import pack2bit_ref
from repro.kernels.sparsign.ref import sparsign_ref


def sparsign_pack2bit_ref(g: jnp.ndarray, budget, seed, counter_base=0) -> jnp.ndarray:
    """(any shape) -> (rows, LANES//4) uint8 packed canonical wire."""
    t = sparsign_ref(g, budget, seed, counter_base)
    view, _ = common.to_2d(t.reshape(-1))
    return pack2bit_ref(view)
