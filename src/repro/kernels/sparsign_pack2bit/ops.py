"""jit'd public wrapper for the fused sparsign->pack2bit kernel: arbitrary
shapes/dtypes, pad -> canonical 2D -> fused kernel -> packed canonical wire.

The output is the (rows, LANES//4) uint8 *canonical-view* packed stream — the
same bytes ``pack2bit_op(sparsign_op(g, ...))`` produces, in one HBM pass.
Invert with ``unpack2bit_op(packed, g.size, g.shape)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.sparsign_pack2bit.kernel import sparsign_pack2bit_2d


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def sparsign_pack2bit_op(
    g: jnp.ndarray,
    budget,
    seed,
    counter_base=0,
    *,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """2-bit packed sparsign wire of ``g`` (any shape, f32/bf16), fused.

    Zero padding of the canonical view is harmless: sparsign(0) == 0 and the
    2-bit code of 0 is 0, exactly what the two-pass chain repads with.
    """
    if interpret is None:
        interpret = common.default_interpret()
    view, _ = common.to_2d(g.reshape(-1))
    br = block_rows or common.block_rows_for(view.shape[0])
    budget_bits = jax.lax.bitcast_convert_type(jnp.asarray(budget, jnp.float32), jnp.uint32)
    scalars = jnp.stack(
        [jnp.asarray(seed, jnp.uint32), jnp.asarray(counter_base, jnp.uint32), budget_bits]
    ).reshape(1, 3)
    return sparsign_pack2bit_2d(view, scalars, block_rows=br, interpret=interpret)
