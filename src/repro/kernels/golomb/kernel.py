"""Pallas kernels: fused sparsign -> Golomb/RLE entropy-coded uplink wire,
the encode-only (two-pass) variant, and the fused gather decode-sum.

One HBM pass from gradient to wire bytes: read g (2 or 4 B/coord), write the
entropy-coded stream (~(2+b)*p bits/coord at plan fraction p — sub-0.5
bits/coord in the paper regime, vs pack2bit's flat 2). The Bernoulli draws
are regenerated in-register from the counter hash (identical stream to
``repro.core.prng`` / the sparsign kernel) and the ternary symbols are coded
while still in VMEM — the int8 ternary tensor never exists in HBM. Emission
and decode are the SAME helpers the jnp reference uses
(``kernels.golomb.ref``), so kernel == ref bitwise holds by construction.

Sequential entropy coding needs the whole message in one kernel instance, so
these kernels run a single-cell grid with the full canonical view as one
block (VMEM-bounded by the engine's chunking for huge leaves; bucket slots
are per-leaf messages and stay small). The emission helper leans on gather/
scatter/prefix-sum jnp ops that interpret mode executes directly; a
streaming-grid TPU lowering (per-block carry of bit offsets in SMEM) is the
real-TPU half of ROADMAP's hardware validation pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import RNG_GOLDEN, mix32
from repro.kernels.golomb import ref as golomb_ref


def _encode_kernel(scalars_ref, g_ref, out_ref, *, rows: int, lanes: int,
                   b: int, out_rows: int):
    # scalars: [seed, counter_base, budget_bits] packed as uint32 in SMEM.
    seed = scalars_ref[0, 0]
    counter_base = scalars_ref[0, 1]
    budget = jax.lax.bitcast_convert_type(scalars_ref[0, 2], jnp.float32)

    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 1)
    idx = r * jnp.uint32(lanes) + c + counter_base

    # counter-hash RNG (kernels/common.mix32 — mirrors repro.core.prng exactly)
    hbits = mix32((idx * RNG_GOLDEN) ^ mix32(seed + RNG_GOLDEN))
    u = (hbits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))

    g = g_ref[...].astype(jnp.float32)
    prob = jnp.clip(jnp.abs(g) * budget, 0.0, 1.0)
    t = jnp.where(u < prob, jnp.sign(g), 0.0).astype(jnp.int8)

    out_ref[...] = golomb_ref.emit_stream(t.reshape(-1), b=b, rows=out_rows)


@functools.partial(jax.jit, static_argnames=("b", "out_rows", "interpret"))
def sparsign_golomb_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *,
                       b: int, out_rows: int, interpret: bool):
    """g2d: (rows, LANES) f32/bf16; scalars: (1,3) uint32 [seed, base, budget].

    Returns the (out_rows, ROW_BYTES) uint8 entropy-coded wire of
    sparsign(g2d) — out_rows is the static plan-time capacity
    (``ref.golomb_rows``)."""
    rows, lanes = g2d.shape
    return pl.pallas_call(
        functools.partial(_encode_kernel, rows=rows, lanes=lanes,
                          b=b, out_rows=out_rows),
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((rows, lanes), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((out_rows, golomb_ref.ROW_BYTES),
                               lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, golomb_ref.ROW_BYTES),
                                       jnp.uint8),
        interpret=interpret,
    )(scalars, g2d)


def _pack_kernel(t_ref, out_ref, *, b: int, out_rows: int):
    out_ref[...] = golomb_ref.emit_stream(t_ref[...].reshape(-1), b=b,
                                          rows=out_rows)


@functools.partial(jax.jit, static_argnames=("b", "out_rows", "interpret"))
def golomb_pack_2d(t2d: jnp.ndarray, *, b: int, out_rows: int, interpret: bool):
    """Encode an existing ternary canonical view (rows, LANES) int8 — the
    second launch of the two-pass chain the fused kernel replaces."""
    rows, lanes = t2d.shape
    return pl.pallas_call(
        functools.partial(_pack_kernel, b=b, out_rows=out_rows),
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, lanes), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((out_rows, golomb_ref.ROW_BYTES),
                               lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, golomb_ref.ROW_BYTES),
                                       jnp.uint8),
        interpret=interpret,
    )(t2d)


def _decode_sum_kernel(gathered_ref, out_ref, *, n: int, b: int):
    out_ref[...] = golomb_ref.decode_sum_workers(gathered_ref[...], n, b=b)


@functools.partial(jax.jit, static_argnames=("n", "b", "interpret"))
def ungolomb_sum(gathered: jnp.ndarray, *, n: int, b: int, interpret: bool):
    """(M, rows, ROW_BYTES) gathered payloads -> (n,) int32 vote sum, workers
    accumulated in strict gather order (the shared ref helper)."""
    m, rows, width = gathered.shape
    return pl.pallas_call(
        functools.partial(_decode_sum_kernel, n=n, b=b),
        grid=(1,),
        in_specs=[pl.BlockSpec((m, rows, width), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(gathered)


def _decode_wsum_kernel(w_ref, gathered_ref, out_ref, *, n: int, b: int):
    # w_ref: (1, M) f32 per-worker weights in SMEM (the pack8 scales idiom)
    out_ref[...] = golomb_ref.decode_wsum_workers(
        gathered_ref[...], w_ref[0, :], n, b=b)


@functools.partial(jax.jit, static_argnames=("n", "b", "interpret"))
def ungolomb_wsum(gathered: jnp.ndarray, w: jnp.ndarray, *, n: int, b: int,
                  interpret: bool):
    """(M, rows, ROW_BYTES) gathered payloads + (1, M) f32 weights -> (n,)
    f32 weighted vote sum, workers accumulated in strict gather order (the
    shared ref helper — kernel == ref bitwise by construction)."""
    m, rows, width = gathered.shape
    return pl.pallas_call(
        functools.partial(_decode_wsum_kernel, n=n, b=b),
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, rows, width), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(w, gathered)
