"""jit'd public wrappers for the Golomb/RLE wire kernels: arbitrary
shapes/dtypes, pad -> canonical 2D -> kernel -> (rows, ROW_BYTES) uint8
entropy-coded payload (or back, for the decode-sum).

``sparsign_golomb_op`` matches the registry's ``fused_pack_op`` contract
``(g, param, seed, counter_base, *, interpret=)`` — the plan-time nonzero
fraction ``p`` is keyword-only with a paper-regime default so spec-generic
audits can trace it; the engine passes the wire's configured ``p``
explicitly, and capacity (the static output row count) is a pure function of
``(g.size, p)`` shared with the wire ledger (``ref.golomb_rows``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.golomb import ref as golomb_ref
from repro.kernels.golomb.kernel import (golomb_pack_2d, sparsign_golomb_2d,
                                         ungolomb_sum, ungolomb_wsum)

#: default plan-time nonzero fraction (paper-regime 5%) — only for
#: spec-generic tracing; real wires pass their configured p
DEFAULT_P = 0.05


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def sparsign_golomb_op(
    g: jnp.ndarray,
    budget,
    seed,
    counter_base=0,
    *,
    p: float = DEFAULT_P,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Entropy-coded sparsign wire of ``g`` (any shape, f32/bf16), fused:
    gradient -> coded bytes in one HBM pass, no int8 ternary intermediate.

    Zero padding of the canonical view is harmless: sparsign(0) == 0 emits no
    code, so padded and unpadded messages code identically."""
    if interpret is None:
        interpret = common.default_interpret()
    n = int(g.size)
    view, _ = common.to_2d(g.reshape(-1))
    budget_bits = jax.lax.bitcast_convert_type(
        jnp.asarray(budget, jnp.float32), jnp.uint32)
    scalars = jnp.stack(
        [jnp.asarray(seed, jnp.uint32), jnp.asarray(counter_base, jnp.uint32),
         budget_bits]).reshape(1, 3)
    return sparsign_golomb_2d(view, scalars, b=golomb_ref.rice_b(p),
                              out_rows=golomb_ref.golomb_rows(n, p),
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def golomb_pack_op(
    t: jnp.ndarray,
    *,
    p: float = DEFAULT_P,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Encode an existing ternary message (any shape, int8) — the second
    launch of the two-pass chain (``golomb_pack_op(sparsign_op(g, ...))``),
    byte-identical to the fused op."""
    if interpret is None:
        interpret = common.default_interpret()
    n = int(t.size)
    view, _ = common.to_2d(t.reshape(-1).astype(jnp.int8))
    return golomb_pack_2d(view, b=golomb_ref.rice_b(p),
                          out_rows=golomb_ref.golomb_rows(n, p),
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("size", "shape", "p", "interpret"))
def ungolomb_sum_op(
    gathered: jnp.ndarray,
    size: int,
    shape,
    *,
    p: float = DEFAULT_P,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(M, rows, ROW_BYTES) gathered payloads -> int32 vote sum of ``shape``,
    workers accumulated in strict gather order (pinned against
    ``ref.ungolomb_sum_ref``)."""
    if interpret is None:
        interpret = common.default_interpret()
    total = ungolomb_sum(gathered, n=size, b=golomb_ref.rice_b(p),
                         interpret=interpret)
    return total.reshape(shape)


@functools.partial(jax.jit, static_argnames=("size", "shape", "p", "interpret"))
def ungolomb_wsum_op(
    gathered: jnp.ndarray,
    weights: jnp.ndarray,
    size: int,
    shape,
    *,
    p: float = DEFAULT_P,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(M, rows, ROW_BYTES) gathered payloads + (M,) f32 per-worker weights ->
    f32 weighted vote sum ``sum_m weights[m] * votes_m`` of ``shape``, workers
    accumulated in strict gather order (pinned against
    ``ref.ungolomb_wsum_ref``). The elastic-participation decode of the
    golomb gather wire: weights ride the gather as a billed side channel."""
    if interpret is None:
        interpret = common.default_interpret()
    m = int(gathered.shape[0])
    w = weights.astype(jnp.float32).reshape(1, m)
    total = ungolomb_wsum(gathered, w, n=size, b=golomb_ref.rice_b(p),
                          interpret=interpret)
    return total.reshape(shape)
