"""Pure-jnp reference for the Golomb/RLE entropy-coded ternary wire — THE
format definition every other party is pinned against bitwise: the fused
Pallas encoder (kernels/golomb/kernel.py calls the same emission helper), the
fused decode-sum, the ``GolombWire`` exchange, and the byte ledger.

Wire format of one worker message (one leaf, n true coordinates, plan-time
nonzero fraction p):

  * payload buffer: ``(rows, ROW_BYTES)`` uint8, ``rows`` fixed at plan/build
    time by ``golomb_rows(n, p)`` — flattened row-major it IS the byte stream.
  * bytes 0-3:  uint32 little-endian count of *shipped* nonzeros.
  * bytes 4-7:  uint32 little-endian count of *dropped* nonzeros (capacity
    overflow — see below). The in-band length prefix: a gathered buffer is
    self-describing, no side-channel size exchange.
  * bits from byte 8, LSB-first within each byte. Per shipped nonzero, in
    ascending flat-coordinate order, a Rice code of the zero-run gap
    (gap_0 = pos_0; gap_k = pos_k - pos_{k-1} - 1) with the static parameter
    b = ``rice_b(p)`` (Eq. 12's b*): ``gap >> b`` one-bits, a terminating
    zero bit, b remainder bits LSB-first, then 1 sign bit (1 = negative).

Capacity is STATIC (python, plan-time): a six-sigma percentile bound on the
nonzero count at the configured p plus the worst-case unary spill given that
count (sum of gaps <= n - 1, so sum(gap >> b) <= n / 2^b). Messages whose
realized nnz still overflows are truncated at capacity — the dropped count
rides the header, loudly testable — while configurations where the capacity
cannot beat the flat 2-bit wire fail at BUILD time (``golomb_rows`` raises,
directing to the pack2 wire). Static capacity is what keeps the exchange a
fixed-shape all-gather (jit-able, ledger == traced bytes exactly); the
padding tax is billed honestly by ``dist.collectives.GolombWire``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import common as kcommon

#: in-band header: two uint32 LE counters (shipped nonzeros, dropped nonzeros)
HEADER_BYTES = 8

#: bytes per payload row — same 128-B row the pack2 wire ships, so a golomb
#: bucket row is directly comparable to (and competes with) a pack2 row
ROW_BYTES = kcommon.LANES // 4


def rice_b(p: float) -> int:
    """The static Rice/Golomb parameter: Eq. 12's b* at the plan-time nonzero
    fraction p (``core.encoding.golomb_bstar``)."""
    # deferred: a module-level import would cycle (core package init ->
    # algorithm -> engine -> this module); rice_b only runs at plan time
    from repro.core.encoding import golomb_bstar
    return golomb_bstar(p)


def golomb_capacity_nnz(n: int, p: float) -> int:
    """Plan-time bound on the nonzeros one n-coordinate message may ship:
    mean + six sigma of Binomial(n, p), plus a small-n floor. Six sigma keeps
    the truncation probability negligible (~1e-9 per message) while staying
    within a few percent of n*p for large leaves."""
    mean = n * p
    sdev = math.sqrt(n * p * (1.0 - p))
    return min(n, int(math.ceil(mean + 6.0 * sdev + 8.0)))


def golomb_capacity_bits(n: int, p: float) -> int:
    """Worst-case encoded bits for a message with <= capacity_nnz nonzeros:
    every code pays 2 + b bits (stop + remainder + sign) and the unary parts
    sum to at most n / 2^b (the gaps sum to < n)."""
    b = rice_b(p)
    cap = golomb_capacity_nnz(n, p)
    return cap * (2 + b) + int(math.ceil(n / float(1 << b)))


def golomb_rows(n: int, p: float) -> int:
    """Payload rows of one n-coordinate message at plan-time fraction p — the
    single capacity rule shared by the encoder output shape, the bucket plan
    slot sizing and the wire byte ledger. Raises (loud build-time fallback)
    when the capacity cannot beat the flat 2-bit wire: at that density the
    entropy coding is pure overhead and the caller should use the pack2 wire
    (compressor 'sparsign' instead of 'sparsign_golomb')."""
    cap_bytes = HEADER_BYTES + (golomb_capacity_bits(n, p) + 7) // 8
    rows = -(-cap_bytes // ROW_BYTES)
    pack2_bytes = kcommon.canonical_rows(n) * ROW_BYTES
    if rows * ROW_BYTES >= pack2_bytes:
        raise ValueError(
            f"golomb wire capacity ({rows * ROW_BYTES} B) does not beat the "
            f"flat 2-bit wire ({pack2_bytes} B) for n={n} at nonzero fraction "
            f"p={p} — entropy coding loses above ~35% density. Use the pack2 "
            f"wire (e.g. compressor 'sparsign') for this regime.")
    return rows


def golomb_nbytes(n: int, p: float) -> int:
    """One worker's payload bytes for an n-coordinate leaf (capacity padding
    included) — the golomb twin of ``collectives.packed_nbytes``."""
    return golomb_rows(n, p) * ROW_BYTES


# ---------------------------------------------------------------------------
# Encoder — vectorized emission, shared verbatim by this reference and the
# Pallas kernel bodies (kernels/golomb/kernel.py), so kernel == ref bitwise
# is true by construction.
# ---------------------------------------------------------------------------

def _le32(x) -> jnp.ndarray:
    """uint32 scalar -> 4 little-endian uint8 header bytes."""
    x = jnp.asarray(x, jnp.uint32)
    return jnp.stack([(x >> (8 * i)).astype(jnp.uint8) for i in range(4)])


def emit_stream(t_flat: jnp.ndarray, *, b: int, rows: int) -> jnp.ndarray:
    """Ternary flat stream -> (rows, ROW_BYTES) uint8 wire payload.

    Fully vectorized (no data-dependent shapes, jit/kernel-safe): code start
    offsets are an exclusive prefix sum of per-nonzero code lengths, unary
    runs are written with a +1/-1 delta buffer and a prefix sum, remainder
    and sign bits with static-b scatter-adds. Codes that do not fit the
    static capacity are truncated as a suffix (offsets are monotone, so
    ``fits`` is a prefix of the nonzeros) and counted in the header's dropped
    field. Trailing zero-padding of a canonical view emits no codes, so
    padded and unpadded inputs encode identically.
    """
    n_bits = (rows * ROW_BYTES - HEADER_BYTES) * 8
    t_flat = t_flat.reshape(-1)
    nz = t_flat != 0
    ar = jnp.arange(t_flat.shape[0], dtype=jnp.int32)
    # previous nonzero position (exclusive running max; -1 before the first)
    marked = jnp.where(nz, ar, -1)
    prev = jnp.concatenate(
        [jnp.full((1,), -1, jnp.int32), jax.lax.cummax(marked, axis=0)[:-1]])
    gap = jnp.where(nz, ar - prev - 1, 0)
    q = gap >> b
    code_len = q + 2 + b                      # unary + stop + remainder + sign
    clen = jnp.where(nz, code_len, 0)
    end = jnp.cumsum(clen)
    off = end - clen                          # exclusive cumsum: bit offsets
    fits = nz & (end <= n_bits)
    nnz_shipped = jnp.sum(fits.astype(jnp.uint32))
    nnz_dropped = jnp.sum(nz.astype(jnp.uint32)) - nnz_shipped
    # unary runs: +1 at off, -1 at off+q, prefix-sum > 0 (runs are disjoint);
    # dropped codes scatter to the sentinel slot n_bits, trimmed below
    delta = jnp.zeros((n_bits + 1,), jnp.int32)
    delta = delta.at[jnp.where(fits, off, n_bits)].add(1, mode="drop")
    delta = delta.at[jnp.where(fits, off + q, n_bits)].add(-1, mode="drop")
    bitbuf = (jnp.cumsum(delta)[:n_bits] > 0).astype(jnp.uint8)
    base = off + q + 1                        # first bit after the unary stop
    for j in range(b):
        pos = jnp.where(fits, base + j, n_bits)
        bitbuf = bitbuf.at[pos].add(((gap >> j) & 1).astype(jnp.uint8),
                                    mode="drop")
    sign_pos = jnp.where(fits, base + b, n_bits)
    bitbuf = bitbuf.at[sign_pos].add((t_flat < 0).astype(jnp.uint8),
                                     mode="drop")
    # pack LSB-first into bytes, prepend the header
    byts = (bitbuf.reshape(-1, 8).astype(jnp.uint32)
            << jnp.arange(8, dtype=jnp.uint32)[None, :]).sum(axis=1)
    stream = jnp.concatenate(
        [_le32(nnz_shipped), _le32(nnz_dropped), byts.astype(jnp.uint8)])
    return stream.reshape(rows, ROW_BYTES)


def golomb_encode_ref(t: jnp.ndarray, *, p: float) -> jnp.ndarray:
    """Ternary message (any shape, true coordinates) -> (golomb_rows(n, p),
    ROW_BYTES) uint8 wire payload. The reference encoder the fused kernel is
    pinned against, and the engine's jnp-backend two-pass path."""
    n = int(t.size)
    return emit_stream(t.reshape(-1), b=rice_b(p), rows=golomb_rows(n, p))


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def decode_stream(stream: jnp.ndarray, n: int, *, b: int) -> jnp.ndarray:
    """One worker's payload -> int32 ternary votes, flat (n,).

    Sequential bit reader (lax.while_loop over the header's shipped-code
    count): unary quotient, b remainder bits, sign bit per code. Reads of a
    malformed stream clamp at the buffer edge and scatter with mode='drop' —
    an all-zero buffer (a masked-out worker) has a zero header and decodes to
    zero votes.
    """
    flat = stream.reshape(-1)
    payload_bits = (int(flat.shape[0]) - HEADER_BYTES) * 8
    h = flat[:4].astype(jnp.int32)
    nnz = h[0] | (h[1] << 8) | (h[2] << 16) | (h[3] << 24)
    body = flat[HEADER_BYTES:]
    bits = ((body[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None, :]) & 1
            ).astype(jnp.int32).reshape(-1)

    def one_code(carry):
        k, ptr, prev, out = carry
        q_end = jax.lax.while_loop(
            lambda i: (i < payload_bits) & (bits[i] == 1),
            lambda i: i + 1, ptr)
        q = q_end - ptr
        rem = jnp.int32(0)
        for j in range(b):
            rem = rem | (bits[q_end + 1 + j] << j)
        gap = (q << b) | rem
        pos = prev + 1 + gap
        sign = bits[q_end + 1 + b]
        out = out.at[pos].add(jnp.int32(1) - 2 * sign, mode="drop")
        return k + 1, q_end + 2 + b, pos, out

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(-1), jnp.zeros((n,), jnp.int32))
    _, _, _, out = jax.lax.while_loop(lambda c: c[0] < nnz, one_code, init)
    return out


def golomb_decode_ref(stream: jnp.ndarray, n: int, shape, *, p: float) -> jnp.ndarray:
    """One worker's payload -> its int8 ternary message in ``shape`` (the
    roundtrip inverse of ``golomb_encode_ref`` for messages within capacity)."""
    return decode_stream(stream, n, b=rice_b(p)).astype(jnp.int8).reshape(shape)


def decode_sum_workers(gathered: jnp.ndarray, n: int, *, b: int) -> jnp.ndarray:
    """(M, rows, ROW_BYTES) gathered payloads -> int32 vote sum, flat (n,).

    Workers accumulate strictly in worker-index (gather) order — deliberate,
    mirroring ``unpack8_sum_ref``; integer adds make the order moot for the
    result but the association is part of the wire contract. Shared by the
    reference and the Pallas decode kernel body."""
    total = jnp.zeros((n,), jnp.int32)
    for w in range(int(gathered.shape[0])):
        total = total + decode_stream(gathered[w], n, b=b)
    return total


def ungolomb_sum_ref(gathered: jnp.ndarray, n: int, shape, *, p: float) -> jnp.ndarray:
    """Reference decode-sum: gathered worker payloads -> int32 vote sum in
    ``shape`` — the oracle the fused ``ungolomb_sum_op`` is pinned against."""
    return decode_sum_workers(gathered, n, b=rice_b(p)).reshape(shape)


def decode_wsum_workers(gathered: jnp.ndarray, weights: jnp.ndarray, n: int,
                        *, b: int) -> jnp.ndarray:
    """(M, rows, ROW_BYTES) gathered payloads + (M,) f32 per-worker weights
    -> f32 weighted vote sum, flat (n,).

    The elastic-participation twin of ``decode_sum_workers``: strict
    worker-order float accumulation (the association the kernel reproduces).
    A masked-out worker's all-zero buffer decodes to zero votes and its zero
    weight makes the contribution exactly zero either way."""
    total = jnp.zeros((n,), jnp.float32)
    for w in range(int(gathered.shape[0])):
        total = total + (decode_stream(gathered[w], n, b=b).astype(jnp.float32)
                         * weights[w])
    return total


def ungolomb_wsum_ref(gathered: jnp.ndarray, weights: jnp.ndarray, n: int,
                      shape, *, p: float) -> jnp.ndarray:
    """Reference weighted decode-sum: gathered payloads + per-worker weights
    -> f32 ``sum_m w_m * votes_m`` in ``shape`` (the oracle the fused
    ``ungolomb_wsum_op`` is pinned against)."""
    return decode_wsum_workers(gathered, weights, n, b=rice_b(p)).reshape(shape)
