"""Pallas TPU kernels: the 8-bit QSGD (``pack8``) uplink wire.

``qsgd8_pack8_2d`` is the fused quantize->wire pass: read g (2 or 4 B/coord)
once, regenerate the stochastic-rounding uniforms in-register from the counter
hash (identical stream to ``repro.core.prng``), and write the int8 sign*level
payload (1 B/coord) — neither the f32 uniforms nor an int32 level tensor ever
exist in HBM (the jaxpr pins in tests/benchmarks assert zero int32 HBM
elements). The level clip at 127 is part of the quantizer (see ref.py).

``unpack8_sum_2d`` is the decode side of the ``allgather_packed`` pack8 wire:
the gathered (M, rows, LANES) int8 payloads are decoded with their per-worker
f32 scales (SMEM) and accumulated in VMEM, sequentially in worker order so the
float sum associates exactly like the decoded-psum wire — only the f32 sum
(4 B/coord) is written back; the (M, rows, LANES) f32 decoded tensor of the
unfused chain never materializes.

Tiling matches the ternary kernels: canonical (rows, 512) blocks, rows padded
to the int8 sublane tile, grid over row blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import RNG_GOLDEN, mix32
from repro.kernels.pack8.ref import QSGD8_LEVELS


def _qsgd8_kernel(scalars_ref, g_ref, out_ref, *, block_rows: int, lanes: int):
    # scalars: [seed, counter_base, param_bits] packed as uint32 in SMEM.
    seed = scalars_ref[0, 0]
    counter_base = scalars_ref[0, 1]
    param = jax.lax.bitcast_convert_type(scalars_ref[0, 2], jnp.float32)

    r0 = pl.program_id(0) * block_rows
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 1)
    idx = (jnp.uint32(r0) + rows) * jnp.uint32(lanes) + cols + counter_base

    # counter-hash RNG (kernels/common.mix32 — mirrors repro.core.prng exactly)
    bits = mix32((idx * RNG_GOLDEN) ^ mix32(seed + RNG_GOLDEN))
    u = (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))

    g = g_ref[...].astype(jnp.float32)
    r = jnp.abs(g) / jnp.maximum(param, 1e-20)
    l = jnp.floor(r)
    level = jnp.minimum(l + (u < (r - l)).astype(jnp.float32),
                        jnp.float32(QSGD8_LEVELS))
    # canonical-view zero padding maps to level 0 (r=0 -> floor 0, frac 0), so
    # no explicit valid-mask is needed — same property the sparsign kernel uses
    out_ref[...] = (jnp.sign(g) * level).astype(jnp.int8)


def _unpack8_sum_kernel(scales_ref, p_ref, out_ref, dec_ref, *, m_chunk: int):
    # p_ref block: (m_chunk, block_rows, lanes) int8 — one worker-chunk's
    # levels for this row block; scales_ref: (1, M) f32 in SMEM. Decode +
    # accumulate in VMEM, strictly in worker order: the grid's worker-chunk
    # axis is innermost (sequential on TPU), so revisiting the same out block
    # accumulates chunk 0, 1, ... in order, and the unrolled loop keeps order
    # within a chunk — float adds must associate exactly like the psum wire.
    # Chunking bounds VMEM at any worker count (an (M, block, lanes) block
    # would grow linearly in M).
    #
    # The per-worker products round-trip through the dec_ref VMEM scratch
    # before the add chain: a compiler may otherwise contract each mul into
    # its add with a single rounding, and the result would drift off the
    # decoded-psum wire, whose products are materialized (hence rounded) at
    # the collective boundary. The store forces the same rounding point.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        # +0.0 seed: x + 0.0 == x bitwise here (int levels * positive scales
        # never produce -0.0), matching the psum stream's no-seed sum
        out_ref[...] = jnp.zeros_like(out_ref)

    for k in range(m_chunk):
        dec_ref[k] = p_ref[k].astype(jnp.float32) * scales_ref[0, j * m_chunk + k]
    acc = out_ref[...]
    for k in range(m_chunk):
        acc = acc + dec_ref[k]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def qsgd8_pack8_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *,
                   block_rows: int, interpret: bool) -> jnp.ndarray:
    """g2d: (rows, LANES) f32/bf16; scalars: (1,3) uint32 [seed, base, param-bits].

    Returns the (rows, LANES) int8 signed-level wire payload of qsgd8(g2d)."""
    rows, lanes = g2d.shape
    return pl.pallas_call(
        functools.partial(_qsgd8_kernel, block_rows=block_rows, lanes=lanes),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int8),
        interpret=interpret,
    )(scalars, g2d)


@functools.partial(jax.jit, static_argnames=("block_rows", "m_chunk", "interpret"))
def unpack8_sum_2d(p3d: jnp.ndarray, scales: jnp.ndarray, *,
                   block_rows: int, m_chunk: int, interpret: bool) -> jnp.ndarray:
    """(M, rows, LANES) int8 worker levels + (1, M) f32 scales -> (rows, LANES)
    f32 decoded sum sum_m scales[m] * levels[m] (worker-order association).
    ``m_chunk`` must divide M; the worker-chunk grid axis is innermost so the
    accumulation over chunks is sequential in worker order."""
    m, rows, lanes = p3d.shape
    assert m % m_chunk == 0, (m, m_chunk)
    return pl.pallas_call(
        functools.partial(_unpack8_sum_kernel, m_chunk=m_chunk),
        grid=(rows // block_rows, m // m_chunk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m_chunk, block_rows, lanes), lambda i, j: (j, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_chunk, block_rows, lanes), jnp.float32)],
        interpret=interpret,
    )(scales, p3d)
