"""Pure-jnp oracle for the 8-bit QSGD (``pack8``) uplink wire.

Wire format: the canonical (rows, LANES) int8 view of the *signed stochastic
level* stream — 1 B/coord plus one f32 decode scale per (worker, leaf). Unlike
the 2-bit ternary wire there is no sub-byte interleaving: the int8 payload IS
the wire byte stream, so "packing" is exactly the canonical-view padding.

Level rule (FedCom-style 8-bit QSGD, s = 127 = 1 sign bit + 7 level bits)::

    r     = |g| / param                  # param = max(||g||_2, eps) / 127
    level = min(floor(r) + Bern(r - floor(r)), 127)

The clip at 127 keeps sign*level inside int8 losslessly: r can exceed s by a
float ulp when one coordinate carries the whole norm, and an unclipped level
of 128 would wrap to -128 on the wire (a sign flip, not just noise). The clip
is part of the quantizer's definition here — kernel, oracle and the public
``qsgd8`` compressor all share it bitwise.

Decode side (``unpack8_sum_ref``): the gathered per-worker payloads are
decoded with their per-worker scales and accumulated *sequentially in worker
order* — float addition is non-associative, and worker order is exactly how
the decoded-psum wire reduces, so the pack8 wire stays bitwise-equal to the
fp32 psum oracle stream.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import prng
from repro.kernels import common

#: level count of the 8-bit wire: 1 sign bit + 7 level bits = 2**7 - 1
QSGD8_LEVELS = 127


def qsgd8_levels_ref(g: jnp.ndarray, param, seed, counter_base=0) -> jnp.ndarray:
    """int8 signed stochastic levels of ``g`` (any shape, f32/bf16).

    ``param`` is the decode scale max(||g||_2, eps)/127, resolved by the caller
    from the *whole* tensor (so the chunked jnp path and the kernel agree).
    """
    gf = g.astype(jnp.float32)
    idx = (jnp.arange(g.size, dtype=jnp.uint32).reshape(g.shape)
           + jnp.asarray(counter_base, jnp.uint32))
    r = jnp.abs(gf) / jnp.maximum(jnp.asarray(param, jnp.float32), 1e-20)
    l = jnp.floor(r)
    u = prng.uniform01(seed, idx)
    level = jnp.minimum(l + (u < (r - l)).astype(jnp.float32),
                        jnp.float32(QSGD8_LEVELS))
    return (jnp.sign(gf) * level).astype(jnp.int8)


def qsgd8_pack8_ref(g: jnp.ndarray, param, seed, counter_base=0) -> jnp.ndarray:
    """(any shape) -> (rows, LANES) int8 canonical wire view: the two-pass
    composition (quantize, then pad to the canonical view) the fused kernel
    must reproduce byte-for-byte."""
    t = qsgd8_levels_ref(g, param, seed, counter_base)
    view, _ = common.to_2d(t.reshape(-1))
    return view


def unpack8_sum_ref(gathered: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(M, rows, LANES) int8 worker levels + (M,) f32 scales -> (rows, LANES)
    f32 decoded sum: sum_m scales[m] * levels[m].

    The python loop is deliberate: left-to-right adds in worker order, the
    exact association of the decoded-psum wire (and of the fused kernel's
    unrolled accumulator). A jnp.sum here would re-associate and break the
    cross-wire bitwise pin. Run it EAGERLY (it is the test oracle): inside a
    jit fusion the compiler may contract the products into the adds, which is
    exactly why the kernel rounds them through a VMEM scratch and why the
    wire's jnp backend exchanges decoded floats over psum instead.
    """
    m = gathered.shape[0]
    acc = jnp.zeros(gathered.shape[1:], jnp.float32)
    for i in range(m):
        acc = acc + gathered[i].astype(jnp.float32) * scales[i]
    return acc
