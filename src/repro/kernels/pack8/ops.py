"""jit'd public wrappers for the pack8 (8-bit QSGD) wire kernels: arbitrary
shapes/dtypes, pad -> canonical 2D -> kernel -> int8 wire payload (or back).

``qsgd8_op``/``qsgd8_pack8_op`` share the registry's uniform signature
``(g, param, seed, counter_base, *, interpret=None)`` — they are what the
qsgd8 ``CompressorSpec`` installs as ``pallas_op``/``fused_pack_op``. The
payload of the fused op is the wire-native canonical (rows, LANES) int8 view;
``qsgd8_op`` unpads back to the leaf shape for the non-wire (decoded) path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.pack8.kernel import qsgd8_pack8_2d, unpack8_sum_2d


def _scalars(param, seed, counter_base) -> jnp.ndarray:
    param_bits = jax.lax.bitcast_convert_type(
        jnp.asarray(param, jnp.float32), jnp.uint32)
    return jnp.stack([
        jnp.asarray(seed, jnp.uint32),
        jnp.asarray(counter_base, jnp.uint32),
        param_bits,
    ]).reshape(1, 3)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def qsgd8_pack8_op(
    g: jnp.ndarray,
    param,
    seed,
    counter_base=0,
    *,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """Fused quantize -> 8-bit wire: (any shape, f32/bf16) -> (rows, LANES)
    int8 signed levels, one HBM pass, bitwise equal to
    ``to_2d(qsgd8_levels_ref(g, ...))`` (zero padding quantizes to level 0)."""
    if interpret is None:
        interpret = common.default_interpret()
    view, _ = common.to_2d(g.reshape(-1))
    br = block_rows or common.block_rows_for(view.shape[0])
    return qsgd8_pack8_2d(view, _scalars(param, seed, counter_base),
                          block_rows=br, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def qsgd8_op(
    g: jnp.ndarray,
    param,
    seed,
    counter_base=0,
    *,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """int8 signed qsgd8 levels in the leaf shape (the decoded-wire path)."""
    out2d = qsgd8_pack8_op(g, param, seed, counter_base,
                           interpret=interpret, block_rows=block_rows)
    return common.from_2d(out2d, g.size, g.shape)


@functools.partial(jax.jit, static_argnames=("n", "shape", "interpret"))
def unpack8_sum_op(gathered: jnp.ndarray, scales: jnp.ndarray, n: int, shape, *,
                   interpret: bool | None = None) -> jnp.ndarray:
    """(M, rows, LANES) gathered int8 levels + (M,) f32 scales -> f32 decoded
    sum in ``shape``: sum_m scales[m] * levels[m], accumulated in VMEM in
    worker order (the decode side of the pack8 all-gather wire). The grid
    tiles rows AND worker chunks, so the in-flight (m_chunk, block, LANES)
    int8 block plus its f32 decode scratch stay within a ~2.5 MiB VMEM budget
    at any worker count (block rows cannot shrink below the sublane tile, so
    chunking the worker axis is what bounds large M).
    """
    if interpret is None:
        interpret = common.default_interpret()
    m, rows, lanes = gathered.shape
    br = common.block_rows_for(rows)
    # 5 B per (worker, coord) in flight: int8 input block + f32 decode scratch
    want_chunk = max(1, (1 << 19) // max(1, br * lanes))
    m_chunk = min(m, want_chunk)
    while m % m_chunk:        # largest divisor of M <= the VMEM-budget chunk
        m_chunk -= 1
    total2d = unpack8_sum_2d(gathered, scales.astype(jnp.float32).reshape(1, m),
                             block_rows=br, m_chunk=m_chunk, interpret=interpret)
    return common.from_2d(total2d, n, shape)
