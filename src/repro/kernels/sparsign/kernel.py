"""Pallas TPU kernel: fused magnitude-aware stochastic ternarization (Def. 1).

One HBM pass: read g (2 or 4 B/coord), write int8 (1 B/coord). The Bernoulli
draws are regenerated in-register from the counter hash — no random-bits input —
so the pass moves 3-5 B/coord vs ~13-17 for the unfused jnp chain
(|g| -> p -> rng bits -> compare -> select), a ~3x cut on the memory-bound
compression step.

Tiling: canonical (rows, 512) view, block (block_rows, 512) in VMEM; grid over
row blocks. f32 block of 256x512 = 512 KiB in + 128 KiB out — comfortably inside
the ~16 MiB v5e VMEM with headroom for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import RNG_GOLDEN, mix32


def _kernel(scalars_ref, g_ref, out_ref, *, block_rows: int, lanes: int):
    # scalars: [seed, counter_base, budget_bits] packed as uint32 in SMEM.
    seed = scalars_ref[0, 0]
    counter_base = scalars_ref[0, 1]
    budget = jax.lax.bitcast_convert_type(scalars_ref[0, 2], jnp.float32)

    r0 = pl.program_id(0) * block_rows
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 1)
    idx = (jnp.uint32(r0) + rows) * jnp.uint32(lanes) + cols + counter_base

    # counter-hash RNG (kernels/common.mix32 — mirrors repro.core.prng exactly)
    c = idx * RNG_GOLDEN
    bits = mix32(c ^ mix32(seed + RNG_GOLDEN))
    u = (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))

    g = g_ref[...].astype(jnp.float32)
    p = jnp.clip(jnp.abs(g) * budget, 0.0, 1.0)
    out_ref[...] = jnp.where(u < p, jnp.sign(g), 0.0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sparsign_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *, block_rows: int, interpret: bool):
    """g2d: (rows, LANES) float32/bf16; scalars: (1,3) uint32 [seed, base, budget-bits]."""
    rows, lanes = g2d.shape
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows, lanes=lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int8),
        interpret=interpret,
    )(scalars, g2d)
