"""jit'd public wrapper for the sparsign kernel: arbitrary shapes/dtypes,
pad -> canonical 2D -> kernel -> unpad."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.sparsign.kernel import sparsign_2d


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def sparsign_op(
    g: jnp.ndarray,
    budget,
    seed,
    counter_base=0,
    *,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """int8 ternary sparsign of ``g`` (any shape, f32/bf16) via the Pallas kernel."""
    if interpret is None:
        interpret = common.default_interpret()
    view, n = common.to_2d(g.reshape(-1))
    br = block_rows or common.block_rows_for(view.shape[0])
    budget_bits = jax.lax.bitcast_convert_type(jnp.asarray(budget, jnp.float32), jnp.uint32)
    scalars = jnp.stack(
        [jnp.asarray(seed, jnp.uint32), jnp.asarray(counter_base, jnp.uint32), budget_bits]
    ).reshape(1, 3)
    out2d = sparsign_2d(view, scalars, block_rows=br, interpret=interpret)
    return common.from_2d(out2d, n, g.shape)
