"""Pure-jnp oracle for the sparsign kernel.

Must match the Pallas kernel bit-for-bit: same counter-hash RNG, same float32
threshold comparison, same clipping.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import prng


def sparsign_ref(g: jnp.ndarray, budget, seed, counter_base=0) -> jnp.ndarray:
    """int8 ternary sparsign over an arbitrary-shape tensor."""
    gf = g.astype(jnp.float32)
    p = jnp.clip(jnp.abs(gf) * jnp.float32(budget), 0.0, 1.0)
    idx = jnp.arange(g.size, dtype=jnp.uint32).reshape(g.shape) + jnp.asarray(counter_base, jnp.uint32)
    u = prng.uniform01(seed, idx)
    return jnp.where(u < p, jnp.sign(gf), 0.0).astype(jnp.int8)
