"""Oracle for the fused server EF step (Alg. 2 server side, Eq. 8).

Given the vote mean d, residual e and the *precomputed* scale s = ||d+e||_1 / n
(one jnp reduction pass), the fused pass computes

    out  = s * sign(d + e)        # C(acc), scaled-sign alpha-approx compressor
    e'   = (d + e) - out

in a single read of (d, e) and single write of (out, e').
"""

from __future__ import annotations

import jax.numpy as jnp


def ef_scale(delta_mean: jnp.ndarray, residual: jnp.ndarray) -> jnp.ndarray:
    acc = delta_mean.astype(jnp.float32) + residual.astype(jnp.float32)
    return jnp.sum(jnp.abs(acc)) / jnp.float32(acc.size)


def ef_server_ref(delta_mean: jnp.ndarray, residual: jnp.ndarray, scale) -> tuple[jnp.ndarray, jnp.ndarray]:
    acc = delta_mean.astype(jnp.float32) + residual.astype(jnp.float32)
    out = jnp.asarray(scale, jnp.float32) * jnp.sign(acc)
    return out, acc - out
