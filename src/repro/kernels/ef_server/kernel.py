"""Pallas TPU kernel: fused server error-feedback step (Eq. 8).

Unfused, the server step is 4 memory passes over param-sized fp32 arrays
(add, sign, scale-mul, subtract); fused it is one read pair + one write pair.
With the ~1.6 B params of a jamba model shard this is the second-largest
memory-bound op of a round after the gradient itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _kernel(scale_ref, d_ref, e_ref, out_ref, newe_ref):
    scale = scale_ref[0, 0]
    acc = d_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    out = scale * jnp.sign(acc)
    out_ref[...] = out
    newe_ref[...] = acc - out


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ef_server_2d(d2d, e2d, scale, *, block_rows: int, interpret: bool):
    rows, lanes = d2d.shape
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
            jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        ),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1, 1), d2d, e2d)
