"""Public fused EF-server op (arbitrary shapes)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.ef_server.kernel import ef_server_2d
from repro.kernels.ef_server.ref import ef_scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_server_op(delta_mean: jnp.ndarray, residual: jnp.ndarray, scale=None,
                 *, interpret: bool | None = None):
    """Fused Eq. 8: returns (g_tilde, new_residual), both float32, shape of input.

    ``scale`` defaults to ||delta+residual||_1 / n computed here; callers whose
    leaves are sharded (streamed mode) pass the cross-shard-reduced scale in.
    """
    if interpret is None:
        interpret = common.default_interpret()
    if scale is None:
        scale = ef_scale(delta_mean, residual)
    d2, n = common.to_2d(delta_mean.astype(jnp.float32).reshape(-1))
    e2, _ = common.to_2d(residual.astype(jnp.float32).reshape(-1))
    br = common.block_rows_for(d2.shape[0])
    out2, newe2 = ef_server_2d(d2, e2, scale, block_rows=br, interpret=interpret)
    return (
        common.from_2d(out2, n, delta_mean.shape),
        common.from_2d(newe2, n, delta_mean.shape),
    )
