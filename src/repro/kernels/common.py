"""Shared plumbing for the compression kernels.

All kernels operate on a canonical 2D layout: the caller's tensor is flattened
row-major and viewed as (rows, LANES) with LANES a multiple of 128 (TPU lane
width) and rows padded to the sublane tile of the widest dtype in play
(int8 tiles are (32, 128), f32 tiles are (8, 128) — we pad rows to 32-multiples
so one BlockSpec serves mixed-dtype kernels).

The logical coordinate of element (r, c) is ``r * LANES + c`` — identical to its
index in the caller's flat tensor — so the counter-based RNG stream is invariant
to this packing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

LANES = 512            # lane-dim width of the canonical view (4 * 128)
SUBLANE_PAD = 32       # row padding multiple (int8 sublane tile)
DEFAULT_BLOCK_ROWS = 256


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode everywhere except real TPUs."""
    return jax.default_backend() != "tpu"


def to_2d(flat: jnp.ndarray, lanes: int = LANES, row_pad: int = SUBLANE_PAD):
    """Pad a flat array to a (rows, lanes) canonical view.

    Returns (view, original_size). Padding is zeros (harmless for every kernel
    here: sign(0)=0, votes 0, pack of 0 is 0).
    """
    assert flat.ndim == 1
    n = flat.shape[0]
    rows = -(-n // lanes)
    rows = -(-rows // row_pad) * row_pad
    padded = jnp.zeros((rows * lanes,), dtype=flat.dtype).at[:n].set(flat)
    return padded.reshape(rows, lanes), n


def from_2d(view: jnp.ndarray, n: int, shape, dtype=None):
    out = view.reshape(-1)[:n].reshape(shape)
    return out.astype(dtype) if dtype is not None else out


def block_rows_for(rows: int, want: int = DEFAULT_BLOCK_ROWS) -> int:
    """Largest divisor of ``rows`` that is <= want and a multiple of SUBLANE_PAD."""
    want = min(want, rows)
    want = max(SUBLANE_PAD, (want // SUBLANE_PAD) * SUBLANE_PAD)
    while rows % want:
        want -= SUBLANE_PAD
    return max(want, SUBLANE_PAD)


def smem_scalar(x, dtype) -> jnp.ndarray:
    """Scalars ride in SMEM as (1, 1) arrays."""
    return jnp.asarray(x, dtype=dtype).reshape(1, 1)


@functools.lru_cache(maxsize=None)
def vmem_bytes(block_rows: int, lanes: int, *dtypes) -> int:
    per = {jnp.float32.dtype: 4, jnp.bfloat16.dtype: 2, jnp.int8.dtype: 1,
           jnp.uint8.dtype: 1, jnp.int32.dtype: 4, jnp.uint32.dtype: 4}
    return sum(block_rows * lanes * per[jnp.dtype(d)] for d in dtypes)
