"""Shared plumbing for the compression kernels.

All kernels operate on a canonical 2D layout: the caller's tensor is flattened
row-major and viewed as (rows, LANES) with LANES a multiple of 128 (TPU lane
width) and rows padded to the sublane tile of the widest dtype in play
(int8 tiles are (32, 128), f32 tiles are (8, 128) — we pad rows to 32-multiples
so one BlockSpec serves mixed-dtype kernels).

The logical coordinate of element (r, c) is ``r * LANES + c`` — identical to its
index in the caller's flat tensor — so the counter-based RNG stream is invariant
to this packing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

LANES = 512            # lane-dim width of the canonical view (4 * 128)
SUBLANE_PAD = 32       # row padding multiple (int8 sublane tile)
DEFAULT_BLOCK_ROWS = 256

# murmur3 finalizer constants as numpy scalars (NOT jnp arrays) so they inline
# as literals inside Pallas kernel bodies. One copy shared by every kernel
# that regenerates the counter stream; must mirror repro.core.prng exactly —
# tests pin kernel == prng-based oracle bitwise.
RNG_C1 = np.uint32(0x85EBCA6B)
RNG_C2 = np.uint32(0xC2B2AE35)
RNG_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x):
    """murmur3 fmix32 over uint32 values, kernel-inlinable (literal constants).
    The in-kernel twin of ``repro.core.prng.mix32``."""
    x = x ^ (x >> 16)
    x = x * RNG_C1
    x = x ^ (x >> 13)
    x = x * RNG_C2
    x = x ^ (x >> 16)
    return x


def encode2bit(x):
    """ternary int8 {-1,0,1} -> 2-bit code uint8 {2,0,1} (the pack2bit wire
    codebook); shared by the pack and fused compress+pack kernels."""
    return jnp.where(x < 0, jnp.uint8(2), x.astype(jnp.uint8))


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode everywhere except real TPUs."""
    return jax.default_backend() != "tpu"


def canonical_rows(n: int, lanes: int = LANES, row_pad: int = SUBLANE_PAD) -> int:
    """Row count of the canonical (rows, lanes) view of an n-element stream:
    ceil to full lanes, rows padded to the sublane tile. The single source of
    the padding rule — ``to_2d`` builds the buffers with it and the wire
    ledgers (``dist.collectives.packed_nbytes``/``packed8_nbytes``) size the
    real payloads from it, so accounting can never drift from the buffers."""
    rows = -(-n // lanes)
    return -(-rows // row_pad) * row_pad


def to_2d(flat: jnp.ndarray, lanes: int = LANES, row_pad: int = SUBLANE_PAD):
    """Pad a flat array to a (rows, lanes) canonical view.

    Returns (view, original_size). Padding is zeros (harmless for every kernel
    here: sign(0)=0, votes 0, pack of 0 is 0).
    """
    assert flat.ndim == 1
    n = flat.shape[0]
    rows = canonical_rows(n, lanes, row_pad)
    padded = jnp.zeros((rows * lanes,), dtype=flat.dtype).at[:n].set(flat)
    return padded.reshape(rows, lanes), n


def from_2d(view: jnp.ndarray, n: int, shape, dtype=None):
    out = view.reshape(-1)[:n].reshape(shape)
    return out.astype(dtype) if dtype is not None else out


def block_rows_for(rows: int, want: int = DEFAULT_BLOCK_ROWS) -> int:
    """Largest divisor of ``rows`` that is <= want and a multiple of SUBLANE_PAD."""
    want = min(want, rows)
    want = max(SUBLANE_PAD, (want // SUBLANE_PAD) * SUBLANE_PAD)
    while rows % want:
        want -= SUBLANE_PAD
    return max(want, SUBLANE_PAD)


def smem_scalar(x, dtype) -> jnp.ndarray:
    """Scalars ride in SMEM as (1, 1) arrays."""
    return jnp.asarray(x, dtype=dtype).reshape(1, 1)


def hbm_elems(fn, *args, dtype=jnp.int8) -> int:
    """Element count of ``dtype`` arrays materialized *between* ops when
    tracing ``fn(*args)`` — i.e. HBM-level traffic of that dtype. The walker
    lives in ``repro.analysis.jaxpr_audit`` (recursive over every sub-jaxpr,
    including custom_jvp/custom_vjp/closed_call bodies, but never descending
    into a pallas_call's kernel body, whose values live in VMEM registers);
    this shim keeps the kernels' historical entry point. Used by the wire
    tests/bench to pin that the fused uplinks have no int8 ternary (2-bit
    wire) or int32 level (pack8 wire) intermediate while the unfused chains
    necessarily do."""
    from repro.analysis import jaxpr_audit  # lazy: analysis imports kernels

    return jaxpr_audit.hbm_elems(fn, *args, dtype=dtype)


def int8_hbm_elems(fn, *args) -> int:
    """HBM-level int8 element count of ``fn(*args)`` (see ``hbm_elems``)."""
    return hbm_elems(fn, *args, dtype=jnp.int8)


def int32_hbm_elems(fn, *args) -> int:
    """HBM-level int32 element count of ``fn(*args)`` (see ``hbm_elems``)."""
    return hbm_elems(fn, *args, dtype=jnp.int32)


@functools.lru_cache(maxsize=None)
def vmem_bytes(block_rows: int, lanes: int, *dtypes) -> int:
    per = {jnp.float32.dtype: 4, jnp.bfloat16.dtype: 2, jnp.int8.dtype: 1,
           jnp.uint8.dtype: 1, jnp.int32.dtype: 4, jnp.uint32.dtype: 4}
    return sum(block_rows * lanes * per[jnp.dtype(d)] for d in dtypes)
