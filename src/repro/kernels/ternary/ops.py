"""jit'd public wrappers for the generic ternary kernel template: arbitrary
shapes/dtypes, pad -> canonical 2D -> kernel -> int8 tensor or packed wire.

``ternary_compress_op``/``ternary_pack2bit_op`` take the rule name as a static
argument; the named partials at the bottom are what the CompressorSpec
registry installs as ``pallas_op``/``fused_pack_op`` — every entry shares the
uniform signature ``(g, param, seed, counter_base, *, interpret=None)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import prng
from repro.kernels import common
from repro.kernels.ternary.kernel import (N_SCALARS, ternary_compress_2d,
                                          ternary_pack2bit_2d)


def _scalars(param, seed, counter_base, n_valid) -> jnp.ndarray:
    """(1, N_SCALARS) uint32 SMEM payload; seed folds happen host-side so the
    kernel's u(salt) is a pure table read (see kernel.py layout)."""
    param_bits = jax.lax.bitcast_convert_type(
        jnp.asarray(param, jnp.float32), jnp.uint32)
    s = jnp.stack([
        jnp.asarray(seed, jnp.uint32),
        prng.fold_seed(seed, 1),
        prng.fold_seed(seed, 2),
        jnp.asarray(counter_base, jnp.uint32),
        param_bits,
        jnp.asarray(n_valid, jnp.uint32),
    ])
    assert s.shape == (N_SCALARS,)
    return s.reshape(1, N_SCALARS)


@functools.partial(jax.jit, static_argnames=("rule", "interpret", "block_rows"))
def ternary_compress_op(
    g: jnp.ndarray,
    param,
    seed,
    counter_base=0,
    *,
    rule: str,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """int8 ternary RULES[rule](g) (any shape, f32/bf16) via the Pallas template."""
    if interpret is None:
        interpret = common.default_interpret()
    view, n = common.to_2d(g.reshape(-1))
    br = block_rows or common.block_rows_for(view.shape[0])
    out2d = ternary_compress_2d(view, _scalars(param, seed, counter_base, n),
                                rule=rule, block_rows=br, interpret=interpret)
    return common.from_2d(out2d, n, g.shape)


@functools.partial(jax.jit, static_argnames=("rule", "interpret", "block_rows"))
def ternary_pack2bit_op(
    g: jnp.ndarray,
    param,
    seed,
    counter_base=0,
    *,
    rule: str,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """2-bit packed wire of RULES[rule](g), fused — one HBM pass, bitwise equal
    to ``pack2bit_op(ternary_compress_op(g, ...))`` (padding masked in-kernel,
    so rules that don't map 0 -> 0, e.g. noisy_sign, still pad to zero codes)."""
    if interpret is None:
        interpret = common.default_interpret()
    view, n = common.to_2d(g.reshape(-1))
    br = block_rows or common.block_rows_for(view.shape[0])
    return ternary_pack2bit_2d(view, _scalars(param, seed, counter_base, n),
                               rule=rule, block_rows=br, interpret=interpret)


# ---------------------------------------------------------------------------
# Registry instantiations (CompressorSpec.pallas_op / fused_pack_op)
# ---------------------------------------------------------------------------

sign_op = functools.partial(ternary_compress_op, rule="sign")
sign_pack2bit_op = functools.partial(ternary_pack2bit_op, rule="sign")
noisy_sign_op = functools.partial(ternary_compress_op, rule="noisy_sign")
noisy_sign_pack2bit_op = functools.partial(ternary_pack2bit_op, rule="noisy_sign")
stochastic_ternary_op = functools.partial(ternary_compress_op, rule="stochastic_ternary")
stochastic_ternary_pack2bit_op = functools.partial(ternary_pack2bit_op, rule="stochastic_ternary")
