# Generic ternary-compressor kernel template: one kernel body, many
# compressors. The probability/symbol rule is a specialization argument
# (see rules.py); ops.py exposes the per-compressor instantiations the
# CompressorSpec registry points at.
