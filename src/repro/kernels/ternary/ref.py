"""Pure-jnp oracles for the generic ternary kernel template.

Must match the Pallas kernels bit-for-bit: same rules (rules.py), same
counter-hash RNG (repro.core.prng == kernels.common.mix32), same float32
threshold comparisons. These are also the *normalized* reference compressors
the CompressorSpec registry points at — the public compressor functions in
repro.core.compressors are thin scale-wrapping shims over them.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import prng
from repro.kernels import common
from repro.kernels.pack2bit.ref import pack2bit_ref
from repro.kernels.ternary.rules import RULES


def ternary_compress_ref(g: jnp.ndarray, param, seed, counter_base=0, *,
                         rule: str) -> jnp.ndarray:
    """int8 ternary RULES[rule] symbols over an arbitrary-shape tensor."""
    fn = RULES[rule]
    gf = g.astype(jnp.float32)
    idx = (jnp.arange(g.size, dtype=jnp.uint32).reshape(g.shape)
           + jnp.asarray(counter_base, jnp.uint32))

    def u(salt: int):
        s = seed if salt == 0 else prng.fold_seed(seed, salt)
        return prng.uniform01(s, idx)

    return fn(gf, u, jnp.asarray(param, jnp.float32)).astype(jnp.int8)


def ternary_pack2bit_ref(g: jnp.ndarray, param, seed, counter_base=0, *,
                         rule: str) -> jnp.ndarray:
    """(any shape) -> (rows, LANES//4) uint8 packed canonical wire: the
    two-pass composition the fused kernel must reproduce byte-for-byte."""
    t = ternary_compress_ref(g, param, seed, counter_base, rule=rule)
    view, _ = common.to_2d(t.reshape(-1))
    return pack2bit_ref(view)
