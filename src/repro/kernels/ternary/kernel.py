"""Pallas TPU kernel template: generic fused ternary compression.

One kernel body serves the whole ternary family — the probability/symbol rule
(rules.py) is a compile-time specialization, exactly like sparsign's dedicated
kernel: read g (2 or 4 B/coord) in one HBM pass, regenerate the counter-hash
Bernoulli/noise draws in-register, write either the int8 ternary tensor
(1 B/coord) or, in the fused ``*_pack2bit`` variant, the 2-bit packed wire
directly (0.25 B/coord — the int8 ternary tensor never exists in HBM).

Unlike sparsign (whose rule maps 0 -> 0), some rules emit nonzero symbols at
zero input (noisy_sign signs pure noise), so the canonical-view zero padding
must be masked explicitly: positions >= n are forced to 0 so the packed wire
stays bitwise-equal to ``pack2bit(ref(g))`` and the byte-level nnz count stays
exact.

Tiling matches the sparsign kernels: canonical (rows, 512) f32/bf16 input
blocks, (rows, 512) int8 or (rows, 128) uint8 output blocks, grid over rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import RNG_GOLDEN, encode2bit, mix32
from repro.kernels.ternary.rules import RULES

# scalars layout, (1, 6) uint32 in SMEM:
#   [seed, fold(seed,1), fold(seed,2), counter_base, param_bits, n_valid]
# the three seeds feed u(0)/u(1)/u(2); rules draw lazily, unused streams cost
# nothing (the hash is only materialized when the rule calls u).
N_SCALARS = 6


def _symbols(scalars_ref, g_ref, *, rule, block_rows: int, lanes: int):
    counter_base = scalars_ref[0, 3]
    param = jax.lax.bitcast_convert_type(scalars_ref[0, 4], jnp.float32)
    n_valid = scalars_ref[0, 5]

    r0 = pl.program_id(0) * block_rows
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, lanes), 1)
    pos = (jnp.uint32(r0) + rows) * jnp.uint32(lanes) + cols
    idx = pos + counter_base

    def u(salt: int):
        # counter-hash RNG (kernels/common.mix32 — mirrors repro.core.prng);
        # salt picks the host-folded seed: 0 = unfolded, k = fold_seed(seed, k)
        bits = mix32((idx * RNG_GOLDEN) ^ mix32(scalars_ref[0, salt] + RNG_GOLDEN))
        return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))

    g = g_ref[...].astype(jnp.float32)
    # mask the canonical-view padding: rules need not map 0 -> 0
    return jnp.where(pos < n_valid, rule(g, u, param), 0.0)


def _compress_kernel(scalars_ref, g_ref, out_ref, *, rule, block_rows, lanes):
    t = _symbols(scalars_ref, g_ref, rule=rule, block_rows=block_rows, lanes=lanes)
    out_ref[...] = t.astype(jnp.int8)


def _pack2bit_kernel(scalars_ref, g_ref, out_ref, *, rule, block_rows, lanes):
    t = _symbols(scalars_ref, g_ref, rule=rule, block_rows=block_rows,
                 lanes=lanes).astype(jnp.int8)
    # pack2bit's block-interleaved encoding, still in VMEM (see pack2bit/ref.py)
    quarter = lanes // 4
    c0 = encode2bit(t[:, 0 * quarter:1 * quarter])
    c1 = encode2bit(t[:, 1 * quarter:2 * quarter])
    c2 = encode2bit(t[:, 2 * quarter:3 * quarter])
    c3 = encode2bit(t[:, 3 * quarter:4 * quarter])
    out_ref[...] = c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)


@functools.partial(jax.jit, static_argnames=("rule", "block_rows", "interpret"))
def ternary_compress_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *,
                        rule: str, block_rows: int, interpret: bool):
    """g2d: (rows, LANES) f32/bf16; scalars: (1, N_SCALARS) uint32.
    Returns the (rows, LANES) int8 ternary symbols of RULES[rule]."""
    rows, lanes = g2d.shape
    return pl.pallas_call(
        functools.partial(_compress_kernel, rule=RULES[rule],
                          block_rows=block_rows, lanes=lanes),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int8),
        interpret=interpret,
    )(scalars, g2d)


@functools.partial(jax.jit, static_argnames=("rule", "block_rows", "interpret"))
def ternary_pack2bit_2d(g2d: jnp.ndarray, scalars: jnp.ndarray, *,
                        rule: str, block_rows: int, interpret: bool):
    """Fused compress -> 2-bit packed wire: (rows, LANES) -> (rows, LANES//4)
    uint8, one HBM pass, no int8 ternary intermediate."""
    rows, lanes = g2d.shape
    q = lanes // 4
    return pl.pallas_call(
        functools.partial(_pack2bit_kernel, rule=RULES[rule],
                          block_rows=block_rows, lanes=lanes),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, q), jnp.uint8),
        interpret=interpret,
    )(scalars, g2d)
