"""Ternarization rules — the single source of truth shared by the jnp
reference compressors (repro.core.compressors), the pure-jnp kernel oracles
(ref.py) and the Pallas kernel bodies (kernel.py).

A rule maps one float32 block to ternary {-1, 0, +1} symbols::

    rule(g, u, param) -> float32 in {-1.0, 0.0, +1.0}

where ``g`` is the float32 gradient block, ``param`` a float32 scalar whose
meaning is rule-specific (sparsign: the budget B; noisy_sign: the noise sigma;
stochastic_ternary: the normalizing magnitude s_t), and ``u(salt)`` returns the
coordinate-indexed uniform[0,1) stream for this block with the caller's seed
folded by ``salt`` (salt 0 = the unfolded seed). Callers supply ``u``: the jnp
oracle from ``repro.core.prng``, the Pallas kernel from the in-register
counter hash (``repro.kernels.common.mix32``) — bitwise-identical streams by
the engine's backend contract.

Rules must stay pure elementwise jnp (plus ``u``) so the same function object
inlines inside a Pallas kernel body.
"""

from __future__ import annotations

import jax.numpy as jnp


def sparsign_rule(g, u, param):
    """Def. 1: sign(g_i) w.p. min(|g_i| * B, 1) else 0; param = B."""
    p = jnp.clip(jnp.abs(g) * param, 0.0, 1.0)
    return jnp.where(u(0) < p, jnp.sign(g), 0.0)


def sign_rule(g, u, param):
    """signSGD (Bernstein et al. 2018): deterministic sign; sign(0) = 0.
    param unused; no uniforms drawn."""
    return jnp.sign(g)


def noisy_sign_rule(g, u, param):
    """Noisy signSGD (Chen et al. 2020a): sign(g + n), n ~ N(0, sigma^2);
    param = sigma. Gaussian noise from two folded uniform streams (Box-Muller),
    matching repro.core.compressors.noisy_sign draw-for-draw."""
    u1 = jnp.maximum(u(1), jnp.float32(1e-12))  # guard u1=0 for the log
    u2 = u(2)
    n = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    return jnp.sign(g + param * n)


def stochastic_ternary_rule(g, u, param):
    """TernGrad / 1-bit QSGD family: sign(g_i) w.p. |g_i|/s_t else 0;
    param = s_t (the local or magnitude-shared normalizer)."""
    p = jnp.clip(jnp.abs(g) / jnp.maximum(param, 1e-12), 0.0, 1.0)
    return jnp.where(u(0) < p, jnp.sign(g), 0.0)


#: rule name -> rule fn; the kernel template and the oracles key on this table
RULES = {
    "sparsign": sparsign_rule,
    "sign": sign_rule,
    "noisy_sign": noisy_sign_rule,
    "stochastic_ternary": stochastic_ternary_rule,
}
