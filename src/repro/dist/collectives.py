"""Worker-axis collectives for the vote exchange (Algorithm 1 step 3), and the
``VoteWire`` abstraction every hot-path consumer speaks.

The paper's M workers are the devices along the mesh worker axes ('pod',
'data'). Each worker holds a ternary message per gradient leaf; the server sum
is a collective over those axes, computed redundantly on every worker so the
downlink is free. Three wire-equivalent variants:

- ``vote_psum``:             one integer psum — the production default.
- ``vote_psum_hier``:        two-level psum (int8 within a pod, widened
                             across pods) matching the hierarchical wire
                             model in benchmarks/bench_collectives.py.
- ``vote_allgather_packed``: all-gather of 2-bit-packed votes (the
                             kernels/pack2bit wire format) + fused local
                             decode-sum; costs M*d/4 bytes on the wire, honest
                             about the "no integer reduction on the fabric"
                             regime.

All three return the same per-coordinate vote total; the equivalence is
pinned by tests/mdev/check_collectives.py on a forced 8-device host mesh and
by tests/mdev/check_wires.py at the train-step level.

Sparse ternary messages can also ride the sub-2-bit entropy-coded gather
(``GolombWire``, wire format ``golomb``): Golomb/RLE-coded zero runs + sign
bits at a static plan-time capacity (kernels/golomb), ~(2+b)*p bits/coord at
plan nonzero fraction p vs pack2's flat 2 — same integer vote totals, a
fraction of the bytes at paper-regime sparsity.

Non-ternary 8-bit payloads (qsgd8's sign*level stream, wire format ``pack8``)
get their own gather-wire twin, ``vote_allgather_packed8``/``Pack8Wire``:
1 B/coord plus each worker's 4-B decode scale, dequantized into the mean
server's float sum during the fused decode — the honest FedCom-baseline wire
(vs 4 B/coord decoded psum). There is no psum variant: a fabric reduction
cannot sum levels quantized against different norms.

``make_vote_wire(impl, axes, mesh, wire_format=)`` builds the wire object at
step-build time. A wire knows its *native message format* (``native_format``:
``int8`` leaf-shaped ternary votes, ``pack2`` 2-bit canonical view, or
``pack8`` int8 level canonical view — what ``engine.compress_leaf(wire=...)``
emits), how to mask/count/exchange messages in that format, and its
per-round per-device wire-byte ledger (``wire_bytes``), computed from the real
buffer sizes (including canonical-view padding), not an idealized model.

Scale-carrying compressors ship f32 decode scales next to the payload: one
shared scalar for the ``scaled_votes`` mode (``worker_shared_linf`` is the
magnitude-sharing all-reduce(max) that produces it), per-worker scalars on
the pack8 wire; ``VoteWire.scalar_bytes`` is the ledger entry either way.

Ring-pipelined gather (``ring_chunk_rows``): the gather wires' default
exchange is one monolithic ``all_gather`` that materializes the full
``(M, rows, width)`` tensor in HBM before decoding. Setting
``ring_chunk_rows`` replaces it with an M-1-hop ``ring_permute`` pipeline:
the payload is cut into fixed-shape row chunks, each chunk circulates the
worker ring with every arriving slice decode-summed immediately through the
same fused kernels, so peak payload HBM is ~2 chunks (in-flight + decoding)
instead of M x payload. Total fabric bytes are unchanged — every byte still
visits every worker — only the residency changes; ``gather_hbm_bytes`` is
the ledger entry. Integer wires (pack2, golomb) accumulate int32 and are
bitwise-equal to the monolithic gather at any arrival order; the pack8
wire's f32 sums associate in ring-arrival order (self, prev, prev-1, ...)
instead of worker-index order — deterministic, allclose vs the oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist import compat

VOTE_IMPLS = ("psum", "hier", "allgather_packed")


@dataclasses.dataclass(frozen=True)
class ParticipationSpec:
    """Elastic-participation contract a ``VoteWire`` carries: per-worker vote
    weights (FedCom-style data-volume weighting), a quorum expressed as a
    FRACTION of realized participation, and a per-round report-dropout rate
    (chaos: crashes / stragglers past the round deadline).

    With a spec attached, the wire's weighted exchange returns
    ``sum_m w_m * votes_m`` together with the realized participation total
    ``W = sum_{reporting} w_m``, and the server deadband becomes
    ``|sum w_m sign_m| >= q_frac * W`` instead of a fixed integer M-quorum —
    the vote normalizes to whoever actually reported. ``weights=None`` means
    uniform 1.0; ``q_frac=None`` re-derives the fraction from the legacy
    integer quorum (``resolve_q_frac``). Validation is loud and build-time."""

    weights: Optional[Tuple[float, ...]] = None
    q_frac: Optional[float] = None
    dropout: float = 0.0

    def __post_init__(self):
        if self.weights is not None:
            w = tuple(float(x) for x in self.weights)
            if not w or any(not (x > 0.0) or not (x < float("inf")) for x in w):
                raise ValueError(
                    f"participation weights must be positive finite floats "
                    f"(a zero/negative weight is a permanently-dead worker — "
                    f"shrink the mesh instead), got {self.weights!r}")
            object.__setattr__(self, "weights", w)
        if self.q_frac is not None:
            q = float(self.q_frac)
            if not (0.0 < q <= 1.0):
                raise ValueError(
                    f"quorum fraction must be in (0, 1]: it is the share of "
                    f"realized participation the vote magnitude must clear, "
                    f"got {self.q_frac!r}")
        d = float(self.dropout)
        if not (0.0 <= d < 1.0):
            raise ValueError(
                f"report dropout must be in [0, 1) (1.0 would drop every "
                f"report every round), got {self.dropout!r}")

    @property
    def is_uniform(self) -> bool:
        return self.weights is None

    def weights_array(self, n_workers: int) -> jnp.ndarray:
        """(M,) f32 per-worker weights (uniform 1.0 when unset), validated
        against the wire's worker count."""
        if self.weights is None:
            return jnp.ones((n_workers,), jnp.float32)
        if len(self.weights) != n_workers:
            raise ValueError(
                f"participation weights cover {len(self.weights)} workers "
                f"but the wire has {n_workers}")
        return jnp.asarray(self.weights, jnp.float32)

    def weight_of(self, widx, n_workers: int) -> jnp.ndarray:
        """This worker's static weight as a traced f32 scalar (flat worker
        index — the same row-major order as ``worker_index``)."""
        if self.weights is None:
            return jnp.float32(1.0)
        return self.weights_array(n_workers)[widx]

    def resolve_q_frac(self, quorum: int, n_workers: int) -> float:
        """The wire's quorum fraction: the explicit ``q_frac``, else the
        legacy integer M-quorum re-derived as ``quorum / M`` — at full
        uniform participation (W = M) the weighted deadband
        ``|v| >= q_frac * W`` is then exactly the legacy ``|v| >= quorum``."""
        if self.q_frac is not None:
            return float(self.q_frac)
        q = int(quorum)
        if not (1 <= q <= n_workers):
            raise ValueError(
                f"cannot derive a quorum fraction: integer quorum {quorum!r} "
                f"is outside [1, M={n_workers}]")
        return q / float(n_workers)


def axis_size(name) -> int:
    """Static size of a named mesh axis (valid inside shard_map)."""
    return compat.axis_size(name)


def worker_count(axes: Sequence[str]) -> int:
    """M = product of the worker-axis sizes (static)."""
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def worker_index(axes: Sequence[str]) -> jnp.ndarray:
    """This worker's flat index in [0, M): row-major over ``axes`` order."""
    idx = None
    for a in axes:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * compat.axis_size(a) + i
    return idx


def _sum_dtype(n_workers: int):
    """Smallest int dtype holding ternary-vote sums in [-M, M] — the psum
    payload dtype IS the wire format, so don't widen beyond need."""
    if n_workers <= 127:
        return jnp.int8
    if n_workers <= 32767:
        return jnp.int16
    return jnp.int32


def packed_nbytes(n_coords: int) -> int:
    """Actual bytes of the 2-bit packed wire for an n-coordinate leaf: the
    canonical (rows, LANES) view is padded to the sublane tile, and the padded
    rows ship. This is the *real* per-worker payload (vs the idealized d/4)."""
    from repro.kernels import common as kcommon
    return kcommon.canonical_rows(n_coords) * (kcommon.LANES // 4)


def packed8_nbytes(n_coords: int) -> int:
    """Actual bytes of the pack8 wire for an n-coordinate leaf: the canonical
    (rows, LANES) int8 view, padded rows included — 1 B/coord at aligned
    sizes (vs the idealized d)."""
    from repro.kernels import common as kcommon
    return kcommon.canonical_rows(n_coords) * kcommon.LANES


def golomb_payload_nbytes(n_coords: int, p: float) -> int:
    """Actual bytes of the entropy-coded golomb wire for an n-coordinate leaf
    at plan-time nonzero fraction p: the static capacity rows (header +
    six-sigma coded-bit bound, ``kernels.golomb.ref.golomb_rows``) — capacity
    padding billed honestly, exactly what the fixed-shape gather ships."""
    from repro.kernels.golomb import ref as golomb_ref
    return golomb_ref.golomb_nbytes(n_coords, p)


def vote_psum(votes: jnp.ndarray, axes: Sequence[str], n_workers: int) -> jnp.ndarray:
    """Integer psum of ternary votes over the worker axes."""
    return jax.lax.psum(votes.astype(_sum_dtype(int(n_workers))), tuple(axes))


def scalar_psum(x: jnp.ndarray, axes) -> jnp.ndarray:
    """Sanctioned all-reduce for O(1) protocol/metric scalars (loss, nnz,
    participation counts, scaled-sign shard L1 partials). Raw ``lax.psum``
    outside this module is a repolint error — array payloads must ride a
    ``VoteWire`` (or ``decoded_exchange``) so the byte ledger sees them; a
    scalar reduction is protocol traffic the ledger deliberately does not
    bill, and routing it here keeps that distinction auditable."""
    return jax.lax.psum(x, axes if isinstance(axes, str) else tuple(axes))


def fsdp_all_gather(leaf: jnp.ndarray, axis_name: str, axis: int, *,
                    tiled: bool = True) -> jnp.ndarray:
    """Sanctioned all-gather for FSDP parameter unsharding (streamed mode's
    per-superblock param regather). Not uplink traffic — it moves parameters,
    not gradient messages — so it is billed by the FSDP gather model in
    benchmarks/bench_collectives.py, not the VoteWire ledger; keeping the raw
    collective here (and only here) lets the repolint distinguish the two."""
    return jax.lax.all_gather(leaf, axis_name, axis=axis, tiled=tiled)


def worker_shared_linf(g: jnp.ndarray, axes: Sequence[str], mask=None) -> jnp.ndarray:
    """max_m ||g_m||_inf over the worker axes — TernGrad's magnitude-sharing
    protocol (one f32 scalar all-reduce(max), ~4 B on the fabric) and the
    ``linf_share`` budget policy's shared statistic. Must run inside the
    worker-axes shard_map. ``mask`` (scalar bool) excludes non-participating
    workers from the max, matching the round's sampled set S."""
    local = jnp.max(jnp.abs(g.astype(jnp.float32)))
    if mask is not None:
        local = jnp.where(mask, local, 0.0)
    return jax.lax.pmax(local, tuple(axes))


def worker_shared_linf_many(gs: Sequence[jnp.ndarray], axes: Sequence[str],
                            mask=None) -> jnp.ndarray:
    """Vectorized ``worker_shared_linf``: ONE (L,) f32 pmax for L leaves
    instead of L scalar pmaxes — the bucketed path's magnitude-sharing
    protocol. pmax is element-wise, so entry i is bitwise the per-leaf
    ``worker_shared_linf(gs[i], ...)``."""
    local = jnp.stack([jnp.max(jnp.abs(g.astype(jnp.float32))) for g in gs])
    if mask is not None:
        local = jnp.where(mask, local, 0.0)
    return jax.lax.pmax(local, tuple(axes))


def vote_psum_hier(votes: jnp.ndarray, inner_axis: str, outer_axis: str,
                   inner_size: int, outer_size: int) -> jnp.ndarray:
    """Two-level vote sum: int8-narrow within the fast inner domain ('data',
    intra-pod ICI), widened only for the slow outer hop ('pod', DCN). Equal to
    the flat psum; the wire ledger differs (1 B/coord inner + 2 B/coord outer
    vs 1-4 B/coord flat, cf. bench_collectives.wire_model)."""
    inner = jax.lax.psum(votes.astype(_sum_dtype(int(inner_size))), inner_axis)
    total = int(inner_size) * int(outer_size)
    return jax.lax.psum(inner.astype(_sum_dtype(total)), outer_axis)


def vote_allgather_packed(votes: jnp.ndarray, axes: Sequence[str],
                          n_workers: int, *, backend: Optional[str] = None) -> jnp.ndarray:
    """All-gather of 2-bit-packed votes + fused local decode-sum.

    Wire bytes = M * ceil(d/4) per device (vs the psum's reduced payload) —
    the trade the paper's Table reports for fabrics without int reductions.
    Packing uses the pack2bit kernel's canonical block-interleaved format; the
    decode side is the fused unpack+accumulate kernel (``unpack2bit_sum_op``),
    so the (M, rows, LANES) int8 ternary tensor never materializes —
    ``backend="jnp"`` selects the vmapped oracle instead.
    """
    from repro.kernels.pack2bit.ops import pack2bit_op

    interpret = (backend == "interpret") if backend is not None else None
    packed = pack2bit_op(votes.astype(jnp.int8), interpret=interpret)
    total = _packed_decode_sum(
        jax.lax.all_gather(packed, tuple(axes), axis=0, tiled=False),
        votes.size, votes.shape, backend=backend)
    return total.astype(_sum_dtype(int(n_workers)))


def _packed_decode_sum(gathered: jnp.ndarray, size: int, shape,
                       *, backend: Optional[str]) -> jnp.ndarray:
    """(M, rows, q) gathered packed votes -> int32 vote sum in ``shape``,
    dispatched like the engine: jnp -> vmapped oracle, else fused kernel."""
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import unpack2bit_sum_op
    from repro.kernels.pack2bit.ref import unpack2bit_sum_ref

    if backend == "jnp":
        return kcommon.from_2d(unpack2bit_sum_ref(gathered), size, shape)
    interpret = (backend == "interpret") if backend is not None else None
    return unpack2bit_sum_op(gathered, size, shape, interpret=interpret)


def _golomb_decode_sum(gathered: jnp.ndarray, size: int, shape, *, p: float,
                       backend: Optional[str]) -> jnp.ndarray:
    """(M, rows, 128) gathered entropy-coded payloads -> int32 vote sum in
    ``shape``, dispatched like the engine: jnp -> the reference decoder
    (bitwise the kernel — shared helpers), else the fused decode-sum kernel."""
    from repro.kernels.golomb.ops import ungolomb_sum_op
    from repro.kernels.golomb.ref import ungolomb_sum_ref

    if backend == "jnp":
        return ungolomb_sum_ref(gathered, size, shape, p=p)
    interpret = (backend == "interpret") if backend is not None else None
    return ungolomb_sum_op(gathered, size, shape, p=p, interpret=interpret)


def _packed_decode_wsum(gathered: jnp.ndarray, weights: jnp.ndarray,
                        size: int, shape,
                        *, backend: Optional[str]) -> jnp.ndarray:
    """Weighted twin of ``_packed_decode_sum``: (M, rows, q) gathered packed
    votes + (M,) f32 per-worker weights -> f32 ``sum_m w_m * votes_m`` in
    ``shape``. A masked-out worker's all-zero payload decodes to zero votes
    AND its weight is zero, so it contributes exact zeros twice over."""
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import unpack2bit_wsum_op
    from repro.kernels.pack2bit.ref import unpack2bit_wsum_ref

    if backend == "jnp":
        return kcommon.from_2d(unpack2bit_wsum_ref(gathered, weights), size, shape)
    interpret = (backend == "interpret") if backend is not None else None
    return unpack2bit_wsum_op(gathered, weights, size, shape, interpret=interpret)


def _golomb_decode_wsum(gathered: jnp.ndarray, weights: jnp.ndarray,
                        size: int, shape, *, p: float,
                        backend: Optional[str]) -> jnp.ndarray:
    """Weighted twin of ``_golomb_decode_sum``: f32 ``sum_m w_m * votes_m``
    with per-worker weights riding the gather as the side channel."""
    from repro.kernels.golomb.ops import ungolomb_wsum_op
    from repro.kernels.golomb.ref import ungolomb_wsum_ref

    if backend == "jnp":
        return ungolomb_wsum_ref(gathered, weights, size, shape, p=p)
    interpret = (backend == "interpret") if backend is not None else None
    return ungolomb_wsum_op(gathered, weights, size, shape, p=p,
                            interpret=interpret)


def _unpack8_op():
    """Lazy accessor for the fused pack8 decode-sum op (kernels import at
    call time, like every other kernel dispatch in this module)."""
    from repro.kernels.pack8.ops import unpack8_sum_op
    return unpack8_sum_op


def decoded_message(values: jnp.ndarray, scale, mask, *, is_ternary: bool):
    """One worker's ``decoded``-mode message: decode locally (values * scale),
    zero non-participants. Returns ``(decoded fp32 message, masked nnz)`` —
    ternary messages count |symbols|, float payloads count nonzero decoded
    coordinates. Shared by the per-leaf psum (``decoded_exchange``) and the
    bucketed path (which assembles many decoded messages into one psum), so
    the bitwise pin between them depends on ONE decode definition."""
    dec = values.astype(jnp.float32) * scale
    dec = jnp.where(mask, dec, 0.0)
    if is_ternary:
        nnz = jnp.sum(jnp.abs(
            jnp.where(mask, values, jnp.zeros((), values.dtype))).astype(jnp.float32))
    else:
        nnz = jnp.sum((dec != 0.0).astype(jnp.float32))
    return dec, nnz


def decoded_exchange(values: jnp.ndarray, scale, mask, axes: Sequence[str],
                     *, is_ternary: bool):
    """The ``decoded`` wire mode, shared verbatim by both train modes: decode
    one worker's message locally (values * scale), zero non-participants, and
    fp32-psum over the worker axes. Returns ``(float sum, this worker's
    masked nnz)``. One definition keeps the cross-mode bitwise pin
    (check_wires.py) from depending on two hand-synchronized copies."""
    dec, nnz = decoded_message(values, scale, mask, is_ternary=is_ternary)
    return jax.lax.psum(dec, tuple(axes)), nnz


def decoded_exchange_bucket(payload: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """Bucketed ``decoded``-mode exchange: ONE fp32 psum of a whole bucket of
    pre-decoded, pre-masked messages (``decoded_message`` per leaf, assembled
    by ``dist.bucketing``). psum is element-wise per coordinate, so each
    leaf's slice of the result is bitwise the per-leaf ``decoded_exchange``
    sum; the caller splits with ``bucketing.split_bucket``."""
    return jax.lax.psum(payload, tuple(axes))


def decoded_wire_bytes(n_coords: int, n_workers: int) -> float:
    """Per-device byte ledger of the decoded fp32 psum (the float wire the
    ``decoded`` mode rides, outside any VoteWire): one ring all-reduce of
    4 B/coord."""
    return 2.0 * (n_workers - 1) / n_workers * 4.0 * n_coords


def allreduce_scalar_bytes(n_workers: int) -> float:
    """Ring all-reduce of one f32 scalar — the magnitude-sharing pmax
    (``worker_shared_linf``) and any shared-scale protocol scalar."""
    return 2.0 * (n_workers - 1) / n_workers * 4.0


def uplink_ledger(mode: str, wire: "VoteWire", n_coords: int, *,
                  share_linf: bool = False) -> float:
    """Per-device uplink bytes to exchange one n-coordinate leaf under a wire
    mode (``engine.wire_mode``: votes | scaled_votes | pack8 | decoded) — THE
    ledger definition, shared by both train steps and pinned against the
    traced collective census by ``repro.analysis`` (jaxpr + HLO passes).

    Terms: the mode's array payload (the wire's own ``wire_bytes``, or the
    decoded fp32 psum which bypasses the wire object), plus the pack8 wire's
    per-worker decode-scale gather, plus — when the compressor's scale
    protocol shares a magnitude (``engine.needs_shared_linf``) — one f32
    scalar all-reduce for the pmax'd L-inf. The shared-linf term is billed at
    the all-reduce model regardless of which wire carries the payload (the
    pmax rides the fabric, not the gather)."""
    if mode == "decoded":
        total = decoded_wire_bytes(n_coords, wire.n_workers)
    else:
        total = wire.wire_bytes(n_coords)
    if mode == "pack8":
        # per-worker decode scales ride the gather — once per ring chunk
        # (the chunked ring re-ships the scale alongside every chunk); under
        # elastic participation the worker's weight rides the same slot
        # (scalar_bytes widens to 8 B — the weight premultiplies the decode
        # scale AND ships raw for the participation total)
        total += wire.scalar_bytes() * wire.ring_chunks(n_coords)
    # elastic weight side-channel on the ternary gather wires: one f32 weight
    # per worker rides every gather (re-shipped per ring chunk, like pack8's
    # scales); the psum wires instead bill the participation payload inside
    # wire_bytes (a second per-coordinate f32 all-reduce). The decoded mode
    # bypasses the wire object entirely (weights premultiply the decode scale
    # before the f32 psum), so no side channel is traced or billed there.
    if mode != "decoded":
        total += wire.weight_bytes() * wire.ring_chunks(n_coords)
    if share_linf:
        total += allreduce_scalar_bytes(wire.n_workers)
    return total


def uplink_ledger_bucket(mode: str, wire: "VoteWire", n_coords: int,
                         n_slots: int, *, rows: Optional[int] = None,
                         ring_chunks: int = 1) -> Tuple[float, float]:
    """Per-device uplink bytes for ONE bucketed exchange carrying ``n_slots``
    leaves in ``n_coords`` padded coordinates — the bucketed variant of
    ``uplink_ledger``, split census-style into (payload, scalar) bytes.

    The payload term is the wire's bucket byte model: for the fixed-rate
    formats it is ``wire_bytes`` evaluated at the bucket's padded coordinate
    count (``n_coords`` is a whole number of canonical rows, so the packed
    ledgers are exact — padding is billed once per bucket); the
    variable-length golomb wire instead bills its payload ROWS directly
    (``rows``, the bucket's row count — slot rows are plan-time capacity,
    not coordinate rows, so a coordinate-count model would be fiction).
    The pack8 wire additionally gathers one f32 decode scale per SLOT in a
    single (n_slots,) vector all-gather next to the payload; with >= 2 slots
    that vector is array payload under the census's classification, with one
    slot it is scalar protocol traffic — the split mirrors the census's
    ``in_elems >= 2`` rule so the exact pin holds either way. The shared-linf
    term is per exchange *group*, not per bucket — ``bucketing.plan_ledger``
    bills it. ``ring_chunks`` (``wire.bucket_ring_chunks``) multiplies the
    pack8 scale-vector term: the chunked ring re-ships the whole vector
    alongside every chunk."""
    if mode == "decoded":
        payload = decoded_wire_bytes(n_coords, wire.n_workers)
    else:
        payload = wire.bucket_payload_bytes(n_coords, rows=rows)
    scalar = 0.0
    if mode == "pack8":
        # elastic participation appends ONE weight entry to the per-slot
        # scale vector (the side channel becomes (n_slots + 1,)) — the
        # census's >= 2-element payload classification follows the widened
        # vector, so the split must too
        n_side = n_slots + (1 if wire.participation is not None else 0)
        scales = float((wire.n_workers - 1) * 4 * n_side) * int(ring_chunks)
        if n_side >= 2:
            payload += scales
        else:
            scalar += scales
    elif mode != "decoded":
        # ternary gather wires under elastic participation gather a (1,) f32
        # weight per worker next to the bucket (re-shipped per ring chunk);
        # one element -> scalar protocol traffic under the census split. The
        # decoded mode's bucket psum bypasses the wire (no side channel).
        scalar += wire.weight_bytes() * int(ring_chunks)
    return payload, scalar


def vote_allgather_packed8(payload: jnp.ndarray, scale, axes: Sequence[str],
                           size: int, shape, *,
                           backend: Optional[str] = None) -> jnp.ndarray:
    """All-gather of int8 sign*level payloads + per-worker f32 scales, fused
    dequantize-sum — the pack8 (8-bit QSGD) wire exchange.

    Wire bytes = M * (ceil'd d + 4) per device; returns the float32 decoded
    sum ``sum_m scale_m * levels_m`` of shape ``shape`` — exactly what the
    mean server consumes. Workers are accumulated strictly in worker-index
    order (the gather order), which is also how the decoded-psum wire
    associates its float adds, so the two wires agree bitwise.

    ``backend='jnp'`` skips the gather entirely: each worker dequantizes its
    own payload and the sum IS a float psum — the reference program whose
    association the kernel path reproduces. Same values, fp32 fabric bytes;
    the kernel backends run the honest 1 B/coord gather.
    """
    from repro.kernels import common as kcommon
    from repro.kernels.pack8.ops import unpack8_sum_op

    scale = jnp.asarray(scale, jnp.float32)
    if backend == "jnp":
        dec = kcommon.from_2d(payload, size, shape).astype(jnp.float32) * scale
        return jax.lax.psum(dec, tuple(axes))
    gathered = jax.lax.all_gather(payload, tuple(axes), axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, tuple(axes), axis=0, tiled=False)
    interpret = (backend == "interpret") if backend is not None else None
    return unpack8_sum_op(gathered, scales, size, shape, interpret=interpret)


# ---------------------------------------------------------------------------
# Ring-pipelined gather: ppermute chunks with streaming decode-sum
# ---------------------------------------------------------------------------

#: Default ring chunk size (canonical payload rows per chunk) when a caller
#: asks for ring mode without a size: 256 rows is a 32 KiB pack2 / 128 KiB
#: pack8 chunk — big enough to amortize a ppermute launch on the host
#: backends, small enough that two in-flight chunks stay far under one
#: monolithic gather. TPU latency tuning of this knob is deferred to the
#: hardware pass (see ROADMAP); this is the documented CPU-container default.
DEFAULT_RING_CHUNK_ROWS = 256


def ring_perm(m: int) -> list:
    """The M-cycle permutation (i -> i+1 mod M): after one application every
    worker holds its predecessor's buffer, so M-1 hops visit every peer.
    ``m == 1`` degenerates to the identity [(0, 0)] — trace-legal, and the
    hop loop's condition is already false there."""
    return [(i, (i + 1) % m) for i in range(m)]


def ring_permute(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """Sanctioned one-hop ring shift over the (flattened) worker axes: the
    ONLY ppermute call site in the repo (raw ``lax.ppermute`` outside this
    module is a repolint error). Row-major flat product indexing over
    ``axes`` — the same worker order as ``worker_index`` and the gather
    wires' axis-0 stacking, so ring arrival order is a pure rotation of the
    monolithic gather's worker order."""
    axes = tuple(axes)
    if len(axes) == 1:
        return jax.lax.ppermute(x, axes[0],
                                ring_perm(compat.axis_size(axes[0])))
    if compat.HAS_TUPLE_PPERMUTE:
        return jax.lax.ppermute(x, axes, ring_perm(worker_count(axes)))
    return _ring_permute_nested(x, axes)


def _ring_permute_nested(x: jnp.ndarray, axes: Tuple[str, ...]) -> jnp.ndarray:
    """Old-jax fallback: compose single-axis ppermutes into the flat-product
    ring shift. One hop of the flat ring advances the innermost axis; the
    worker that wraps (innermost index 0 after the shift) must also take the
    carry into the outer axes — everyone shifts the inner axis, the outer
    shift is computed unconditionally (collectives can't branch per-device)
    and selected only on the wrapping workers."""
    s = compat.axis_size(axes[-1])
    y = jax.lax.ppermute(x, axes[-1], ring_perm(s))
    if len(axes) == 1:
        return y
    z = _ring_permute_nested(y, axes[:-1])
    return jnp.where(jax.lax.axis_index(axes[-1]) == 0, z, y)


def _ring_chunk_spans(total_rows: int, chunk_rows: Optional[int]) -> tuple:
    """Static (row_start, rows) chunk framing of a payload: greedy
    ``chunk_rows``-row spans with a short tail. ``None`` = one whole-payload
    chunk (a chunked ring degenerates to an unchunked one, which is how the
    ledger treats a monolithic gather's chunk count too)."""
    if chunk_rows is None or total_rows <= chunk_rows:
        return ((0, total_rows),)
    spans = []
    r = 0
    while r < total_rows:
        spans.append((r, min(int(chunk_rows), total_rows - r)))
        r += spans[-1][1]
    return tuple(spans)


def _slot_groups(slots, chunk_rows: Optional[int]) -> tuple:
    """Golomb chunk framing: greedy groups of CONSECUTIVE WHOLE slots whose
    rows fit in ``chunk_rows``. The coded stream is not row-addressable mid-
    slot (each slot is one self-describing capacity stream), so golomb
    chunks on slot boundaries; a slot bigger than ``chunk_rows`` rides the
    ring alone as an oversized chunk."""
    slots = tuple(slots)
    if chunk_rows is None:
        return (slots,)
    groups, cur, cur_rows = [], [], 0
    for s in slots:
        if cur and cur_rows + s.rows > chunk_rows:
            groups.append(tuple(cur))
            cur, cur_rows = [], 0
        cur.append(s)
        cur_rows += s.rows
    if cur:
        groups.append(tuple(cur))
    return tuple(groups)


def _chunk_segments(slots, r0: int, nr: int) -> tuple:
    """Which slot row-ranges a [r0, r0+nr) chunk carries: static
    (slot_index, slot, seg_row_start, seg_rows) tuples, in row order. Used
    by the pack8 bucket ring — its slots are sublane-aligned, so every
    segment boundary is a valid kernel tile boundary when the chunk size
    is a sublane multiple."""
    segs = []
    for i, s in enumerate(slots):
        a = max(r0, s.row_start)
        b = min(r0 + nr, s.row_start + s.rows)
        if b > a:
            segs.append((i, s, a, b - a))
    return tuple(segs)


def _ring_accumulate(payload: jnp.ndarray, side: tuple, decode_fn,
                     axes: Tuple[str, ...], m: int):
    """One chunk's M-1-hop ring exchange with streaming decode-sum.

    Decode our own chunk first, then ``lax.while_loop`` the ring: each hop
    shifts the payload (and any side-channel arrays, e.g. pack8 decode
    scales) one worker forward and adds ``decode_fn``'s decode of the
    arriving slice into the accumulator — the gathered ``(M, ...)`` tensor
    never exists; peak HBM is the in-flight chunk plus the accumulator.
    ``decode_fn(chunk, *side)`` may return an array or a tuple of arrays
    (per-slot sums); accumulation is tree-mapped. The hop loop is a
    ``while_loop`` (never a scan) on purpose: the census walker descends
    its body with trips=1, so the single traced ppermute per chunk bills as
    one (M-1)-hop ring launch regardless of the build-time mesh size — at
    M=1 the loop body never runs and the decode of our own chunk is the
    whole sum."""
    acc = decode_fn(payload, *side)

    def cond(carry):
        return carry[0] < m

    def body(carry):
        k, b, sd, a = carry
        b = ring_permute(b, axes)
        sd = tuple(ring_permute(s, axes) for s in sd)
        a = jax.tree_util.tree_map(jnp.add, a, decode_fn(b, *sd))
        return (k + 1, b, sd, a)

    _, _, _, acc = jax.lax.while_loop(
        cond, body, (jnp.int32(1), payload, tuple(side), acc))
    return acc


# ---------------------------------------------------------------------------
# The wire abstraction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VoteWire:
    """One vote-exchange wire: message format + collective + byte ledger.

    Static (python-level) object closed over by the jitted train step; built
    once per step via ``make_vote_wire``. ``exchange`` must run inside the
    worker-axes shard_map. All wires return the same vote totals bitwise —
    only the message format and the bytes on the fabric differ.

    With a ``participation`` spec attached (``make_vote_wire(...,
    participation=...)``), the elastic exchange family
    (``exchange_weighted`` / ``exchange_bucket_weighted``) is live: the wire
    carries each worker's effective weight (static per-worker weight x
    dynamic report mask) next to the payload — as a second per-coordinate
    f32 all-reduce on the psum wires, as a billed (1,)-per-worker gather
    side channel on the ternary gather wires, folded into the existing
    decode-scale channel (widened to carry the raw weight too) on pack8 —
    and returns ``(sum_m w_m * votes_m, W = sum_reporting w_m)`` for the
    participation-normalized server deadband.
    """

    axes: Tuple[str, ...]
    n_workers: int
    participation: Optional[ParticipationSpec] = None

    name = "psum"
    #: native uplink message format ("int8": leaf-shaped int8 ternary votes,
    #: "pack2": 2-bit packed uint8 canonical view, "pack8": int8 sign*level
    #: canonical view); ``engine.compress_leaf(wire=...)`` emits it and
    #: validates it against the CompressorSpec's declared wire_format
    native_format = "int8"

    @property
    def wants_packed(self) -> bool:
        """Does this wire speak a packed canonical view (vs leaf-shaped votes)?"""
        return self.native_format != "int8"

    def mask_message(self, values: jnp.ndarray, mask) -> jnp.ndarray:
        """Zero a non-participating worker's message, in wire-native format
        (an all-zero packed byte decodes to four zero votes)."""
        return jnp.where(mask, values, jnp.zeros((), values.dtype))

    def message_nnz(self, values: jnp.ndarray) -> jnp.ndarray:
        """Number of nonzero votes in one wire-native message (f32 scalar)."""
        return jnp.sum(jnp.abs(values).astype(jnp.float32))

    def exchange(self, values: jnp.ndarray, size: int, shape, *,
                 scale=None) -> jnp.ndarray:
        """Wire-native message -> integer vote sum of shape ``shape``.

        ``scale`` is only meaningful on the pack8 wire (each worker's decode
        scale rides the gather); the integer vote wires reject it loudly —
        a shared scale stays OUTSIDE the exchange (``scaled_votes`` decode)."""
        if scale is not None:
            raise ValueError(
                f"the {self.name!r} vote wire exchanges raw integer votes; "
                f"a decode scale inside the exchange is a pack8-wire concept")
        return vote_psum(values, self.axes, self.n_workers)

    def _require_participation(self):
        if self.participation is None:
            raise ValueError(
                f"the {self.name!r} wire was built without a "
                f"ParticipationSpec; the weighted exchange family is the "
                f"elastic-participation path — pass participation= to "
                f"make_vote_wire")

    def exchange_weighted(self, values: jnp.ndarray, size: int, shape, *,
                          weight, scale=None):
        """Elastic exchange: ``(sum_m w_m * votes_m, per-coordinate
        participation total)``. ``weight`` is THIS worker's effective f32
        weight (static weight x report mask — exactly 0.0 when not
        reporting; ``values`` must already be masked to zeros). The psum
        wires all-reduce two f32 arrays — the weighted vote and the realized
        participation count per coordinate — both billed as payload."""
        self._require_participation()
        if scale is not None:
            raise ValueError(
                f"the {self.name!r} vote wire exchanges raw integer votes; "
                f"a decode scale inside the exchange is a pack8-wire concept")
        w = jnp.asarray(weight, jnp.float32)
        wv = jax.lax.psum(values.astype(jnp.float32) * w, tuple(self.axes))
        wtot = jax.lax.psum(jnp.broadcast_to(w, shape).astype(jnp.float32),
                            tuple(self.axes))
        return wv, wtot

    def exchange_bucket(self, payload: jnp.ndarray, bucket, *, scale=None):
        """One bucket of wire-native messages -> per-leaf aggregates, ONE
        collective. ``payload`` is the assembled (rows, width) buffer
        (``dist.bucketing.assemble_bucket``), ``bucket`` its static
        ``bucketing.Bucket`` layout; returns a list of per-leaf sums in the
        leaves' shapes, aligned with ``bucket.slots``. The exchange is
        element-wise per coordinate, so every slice is bitwise the per-leaf
        ``exchange`` of the same message — the cross-granularity pin
        (tests/mdev) rides on that. ``scale`` is pack8-only, as in
        ``exchange``."""
        if scale is not None:
            raise ValueError(
                f"the {self.name!r} vote wire exchanges raw integer votes; "
                f"a decode scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        return bucketing.split_bucket(
            vote_psum(payload, self.axes, self.n_workers), bucket)

    def exchange_bucket_weighted(self, payload: jnp.ndarray, bucket, *,
                                 weight, scale=None):
        """Bucketed elastic exchange: per-leaf ``(weighted vote sums,
        participation total)`` for one assembled bucket — ``(parts, wtot)``
        where ``parts`` aligns with ``bucket.slots`` and ``wtot`` is the
        realized participation (per-coordinate f32 arrays per slot on the
        psum wires, one scalar on the gather wires — per-worker weights are
        per-message, so every coordinate shares it)."""
        self._require_participation()
        if scale is not None:
            raise ValueError(
                f"the {self.name!r} vote wire exchanges raw integer votes; "
                f"a decode scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        w = jnp.asarray(weight, jnp.float32)
        wv = jax.lax.psum(payload.astype(jnp.float32) * w, tuple(self.axes))
        wtot = jax.lax.psum(
            jnp.broadcast_to(w, payload.shape).astype(jnp.float32),
            tuple(self.axes))
        return (bucketing.split_bucket(wv, bucket),
                bucketing.split_bucket(wtot, bucket))

    def wire_bytes(self, n_coords: int) -> float:
        """Per-device wire bytes to exchange one n-coordinate leaf's votes
        (ring-collective first principles, real payload sizes). Under elastic
        participation the psum wires exchange TWO f32 arrays (weighted vote +
        per-coordinate participation count) instead of one narrow integer
        payload — billed honestly."""
        m = self.n_workers
        if self.participation is not None:
            return 2.0 * decoded_wire_bytes(n_coords, m)
        payload = n_coords * jnp.dtype(_sum_dtype(m)).itemsize
        return 2.0 * (m - 1) / m * payload

    def scalar_bytes(self) -> float:
        """Ledger for the f32 decode scale(s) riding alongside a leaf's
        payload: one ring all-reduce of 4 bytes (the magnitude-shared scale of
        ``worker_shared_linf``). The pack8 wire overrides this with its
        per-worker scale gather."""
        m = self.n_workers
        return 2.0 * (m - 1) / m * 4.0

    def weight_bytes(self) -> float:
        """Elastic weight side-channel ledger: bytes to ship this worker's
        f32 effective weight alongside ONE payload exchange (multiplied by
        the ring chunk count upstream — the chunked ring re-ships it). Zero
        for the psum wires (their participation payload bills inside
        ``wire_bytes``) and for pack8 (the weight widens ``scalar_bytes``);
        the ternary gather wires override with the (M-1)-peer gather."""
        return 0.0

    def bucket_payload_bytes(self, n_coords: int,
                             rows: Optional[int] = None) -> float:
        """Payload ledger for ONE bucket of this wire: the fixed-rate wires
        bill by padded coordinate count (rows carry LANES coordinates each,
        so ``wire_bytes(n_coords)`` is exact); the variable-length golomb
        wire overrides this to bill its capacity rows directly."""
        return self.wire_bytes(n_coords)

    def ring_chunks(self, n_coords: int) -> int:
        """Number of ring chunks (= payload collective launches) to exchange
        one n-coordinate leaf. 1 for the psum wires and for unchunked
        gathers; the gather wires override with their chunk framing."""
        return 1

    def bucket_ring_chunks(self, bucket) -> int:
        """Ring chunk count for ONE bucket exchange (cf. ``ring_chunks``)."""
        return 1

    def gather_hbm_bytes(self, n_coords: int) -> float:
        """Peak HBM footprint of the gathered payload while exchanging one
        n-coordinate leaf: M x payload for a monolithic gather, ~2 chunks
        (in-flight + decoding) for the ring, 0 for the psum wires (a fabric
        reduction never materializes a gathered tensor). A residency model,
        not wire traffic — total fabric bytes (``wire_bytes``) are identical
        either way."""
        return 0.0

    def bucket_gather_hbm_bytes(self, bucket) -> float:
        """Peak gathered-payload HBM for ONE bucket exchange (cf.
        ``gather_hbm_bytes``)."""
        return 0.0


@dataclasses.dataclass(frozen=True)
class HierVoteWire(VoteWire):
    """Two-level psum: narrow within axes[1] (intra-pod), widened across
    axes[0] (DCN hop). Requires exactly two worker axes."""

    inner_size: int = 1
    outer_size: int = 1

    name = "hier"

    def exchange(self, values, size, shape, *, scale=None):
        if scale is not None:
            raise ValueError(
                "the 'hier' vote wire exchanges raw integer votes; a decode "
                "scale inside the exchange is a pack8-wire concept")
        return vote_psum_hier(values, self.axes[1], self.axes[0],
                              self.inner_size, self.outer_size)

    def exchange_bucket(self, payload, bucket, *, scale=None):
        if scale is not None:
            raise ValueError(
                "the 'hier' vote wire exchanges raw integer votes; a decode "
                "scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        return bucketing.split_bucket(
            vote_psum_hier(payload, self.axes[1], self.axes[0],
                           self.inner_size, self.outer_size), bucket)

    def _hier_f32_psum(self, x: jnp.ndarray) -> jnp.ndarray:
        # elastic sums are f32, so there is no narrow/widen dtype split —
        # but the exchange stays two-level to keep the hierarchical wire
        # shape (intra-pod reduce, then the DCN hop)
        return jax.lax.psum(jax.lax.psum(x, self.axes[1]), self.axes[0])

    def exchange_weighted(self, values, size, shape, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the 'hier' vote wire exchanges raw integer votes; a decode "
                "scale inside the exchange is a pack8-wire concept")
        w = jnp.asarray(weight, jnp.float32)
        wv = self._hier_f32_psum(values.astype(jnp.float32) * w)
        wtot = self._hier_f32_psum(
            jnp.broadcast_to(w, shape).astype(jnp.float32))
        return wv, wtot

    def exchange_bucket_weighted(self, payload, bucket, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the 'hier' vote wire exchanges raw integer votes; a decode "
                "scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        w = jnp.asarray(weight, jnp.float32)
        wv = self._hier_f32_psum(payload.astype(jnp.float32) * w)
        wtot = self._hier_f32_psum(
            jnp.broadcast_to(w, payload.shape).astype(jnp.float32))
        return (bucketing.split_bucket(wv, bucket),
                bucketing.split_bucket(wtot, bucket))

    def wire_bytes(self, n_coords):
        # both ring terms share one (symmetric) formula — make_vote_wire
        # validates the axis sizes >= 1 at build time, so neither denominator
        # needs a zero guard
        ni, no = self.inner_size, self.outer_size
        if self.participation is not None:
            # two f32 arrays (weighted vote + participation count), both
            # levels at 4 B/coord — no narrow inner dtype to exploit
            inner = 2.0 * (ni - 1) / ni * 4.0 * n_coords
            outer = 2.0 * (no - 1) / no * 4.0 * n_coords
            return 2.0 * (inner + outer)
        inner = 2.0 * (ni - 1) / ni * n_coords * jnp.dtype(_sum_dtype(ni)).itemsize
        outer = 2.0 * (no - 1) / no * n_coords * jnp.dtype(_sum_dtype(ni * no)).itemsize
        return inner + outer


@dataclasses.dataclass(frozen=True)
class PackedVoteWire(VoteWire):
    """All-gather of the 2-bit packed wire + fused decode-sum. The message IS
    the packed canonical view — produced in one pass by the fused
    sparsign_pack2bit kernel on the kernel backends. With ``ring_chunk_rows``
    set, the gather becomes the chunked ppermute ring (module docstring):
    int32 accumulation, bitwise the monolithic gather."""

    backend: Optional[str] = None
    ring_chunk_rows: Optional[int] = None

    name = "allgather_packed"
    native_format = "pack2"

    def message_nnz(self, values):
        # count nonzero 2-bit codes straight off the bytes: codes are {0,1,2},
        # so (b | b>>1) has bit 0 of each code set iff the code is nonzero
        nz = (values | (values >> 1)) & jnp.uint8(0x55)
        cnt = ((nz & 1) + ((nz >> 2) & 1) + ((nz >> 4) & 1) + ((nz >> 6) & 1))
        return jnp.sum(cnt.astype(jnp.float32))

    def _ring_decode_flat(self, payload: jnp.ndarray) -> jnp.ndarray:
        """Ring-exchange a (rows, LANES//4) packed payload in row chunks,
        returning the flat (rows*LANES,) int32 vote sum. Every span is a
        sublane multiple (canonical rows are sublane-padded and the chunk
        size is validated as one), so each chunk decodes through the
        unmodified fused kernel as a self-contained pack2 stream."""
        from repro.kernels import common as kcommon
        parts = []
        for r0, nr in _ring_chunk_spans(payload.shape[0], self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)

            def decode(b, _nr=nr):
                return _packed_decode_sum(b[None], _nr * kcommon.LANES,
                                          (_nr * kcommon.LANES,),
                                          backend=self.backend)

            parts.append(_ring_accumulate(chunk, (), decode, self.axes,
                                          self.n_workers))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def exchange(self, values, size, shape, *, scale=None):
        if scale is not None:
            raise ValueError(
                "the 2-bit packed vote wire exchanges raw ternary votes; a "
                "decode scale inside the exchange is a pack8-wire concept")
        if self.ring_chunk_rows is not None:
            flat = self._ring_decode_flat(values)
            total = jax.lax.slice(flat, (0,), (size,)).reshape(shape)
            return total.astype(_sum_dtype(self.n_workers))
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        total = _packed_decode_sum(gathered, size, shape, backend=self.backend)
        return total.astype(_sum_dtype(self.n_workers))

    def exchange_bucket(self, payload, bucket, *, scale=None):
        """ONE all-gather of the whole packed bucket + one fused decode-sum
        over it, then split on the decoded stream. pack2 packs each canonical
        row independently, so the bucket (a row-concatenation of per-leaf
        payloads) is itself a valid pack2 stream and the whole-bucket decode
        is bitwise the per-leaf decode at every coordinate — which is also
        what lets the ring path chunk the bucket on ANY sublane-aligned row
        boundary, slots included."""
        if scale is not None:
            raise ValueError(
                "the 2-bit packed vote wire exchanges raw ternary votes; a "
                "decode scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        n = bucket.n_coords
        if self.ring_chunk_rows is not None:
            flat = self._ring_decode_flat(payload)
            return bucketing.split_bucket(
                flat.astype(_sum_dtype(self.n_workers)), bucket)
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        total = _packed_decode_sum(gathered, n, (n,), backend=self.backend)
        return bucketing.split_bucket(
            total.astype(_sum_dtype(self.n_workers)), bucket)

    def _ring_wdecode_flat(self, payload: jnp.ndarray, w1: jnp.ndarray):
        """Weighted ring exchange of a (rows, LANES//4) packed payload: the
        (1,) effective weight rides every chunk's ring as the side channel
        (re-shipped per chunk — the ledger's ``weight_bytes x ring_chunks``),
        each arriving slice weighted-decode-summed at M=1. Returns the flat
        f32 weighted vote sum and the realized participation total (the
        weights accumulate around the same ring)."""
        from repro.kernels import common as kcommon
        parts, wtot = [], None
        for r0, nr in _ring_chunk_spans(payload.shape[0], self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)

            def decode(b, wv, _nr=nr):
                s = _packed_decode_wsum(b[None], wv, _nr * kcommon.LANES,
                                        (_nr * kcommon.LANES,),
                                        backend=self.backend)
                return (s, jnp.sum(wv))

            acc, wt = _ring_accumulate(chunk, (w1,), decode, self.axes,
                                       self.n_workers)
            parts.append(acc)
            wtot = wt if wtot is None else wtot
        return (parts[0] if len(parts) == 1 else jnp.concatenate(parts)), wtot

    def exchange_weighted(self, values, size, shape, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the 2-bit packed vote wire exchanges raw ternary votes; a "
                "decode scale inside the exchange is a pack8-wire concept")
        w1 = jnp.asarray(weight, jnp.float32).reshape((1,))
        if self.ring_chunk_rows is not None:
            flat, wtot = self._ring_wdecode_flat(values, w1)
            return jax.lax.slice(flat, (0,), (size,)).reshape(shape), wtot
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        wvec = jax.lax.all_gather(w1, self.axes, axis=0,
                                  tiled=False).reshape(-1)
        wv = _packed_decode_wsum(gathered, wvec, size, shape,
                                 backend=self.backend)
        return wv, jnp.sum(wvec)

    def exchange_bucket_weighted(self, payload, bucket, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the 2-bit packed vote wire exchanges raw ternary votes; a "
                "decode scale inside the exchange is a pack8-wire concept")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        w1 = jnp.asarray(weight, jnp.float32).reshape((1,))
        n = bucket.n_coords
        if self.ring_chunk_rows is not None:
            flat, wtot = self._ring_wdecode_flat(payload, w1)
            return bucketing.split_bucket(flat, bucket), wtot
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        wvec = jax.lax.all_gather(w1, self.axes, axis=0,
                                  tiled=False).reshape(-1)
        total = _packed_decode_wsum(gathered, wvec, n, (n,),
                                    backend=self.backend)
        return bucketing.split_bucket(total, bucket), jnp.sum(wvec)

    def weight_bytes(self):
        # the (1,) f32 effective weight gathered from M-1 peers next to the
        # packed payload — the elastic side channel
        if self.participation is None:
            return 0.0
        return float((self.n_workers - 1) * 4.0)

    def wire_bytes(self, n_coords):
        # ring all-gather: each device transmits its (padded) packed payload
        # to M-1 peers — no reduction on the fabric. The chunked ppermute
        # ring ships the same bytes (every chunk visits every worker), so
        # one formula serves both exchanges.
        return float((self.n_workers - 1) * packed_nbytes(n_coords))

    def ring_chunks(self, n_coords):
        from repro.kernels import common as kcommon
        return len(_ring_chunk_spans(kcommon.canonical_rows(n_coords),
                                     self.ring_chunk_rows))

    def bucket_ring_chunks(self, bucket):
        return len(_ring_chunk_spans(bucket.rows, self.ring_chunk_rows))

    def _gather_hbm(self, rows: int) -> float:
        from repro.kernels import common as kcommon
        row_bytes = kcommon.LANES // 4
        if self.ring_chunk_rows is None:
            return float(self.n_workers * rows * row_bytes)
        max_nr = max(nr for _, nr in _ring_chunk_spans(rows, self.ring_chunk_rows))
        return float(2 * max_nr * row_bytes)

    def gather_hbm_bytes(self, n_coords):
        from repro.kernels import common as kcommon
        return self._gather_hbm(kcommon.canonical_rows(n_coords))

    def bucket_gather_hbm_bytes(self, bucket):
        return self._gather_hbm(bucket.rows)


@dataclasses.dataclass(frozen=True)
class Pack8Wire(VoteWire):
    """All-gather of int8 sign*level payloads (the pack8 wire format) + fused
    dequantize-sum — the non-ternary 8-bit twin of ``PackedVoteWire``. The
    message IS the canonical (rows, LANES) int8 view of the signed levels,
    produced in one pass by the fused qsgd8_pack8 kernel on the kernel
    backends; each worker's f32 decode scale rides the gather next to it and
    the exchange returns the float32 decoded sum the mean server consumes.

    With ``ring_chunk_rows`` set, the kernel backends ring the payload in
    sublane-tile chunks with the decode scales riding the same ring as an
    f32 side channel; f32 sums then associate in ring-arrival order — a
    different (deterministic) association than the worker-order oracle,
    allclose but not bitwise. The jnp backend keeps its psum-oracle program
    regardless (there is no gather to ring); the byte/HBM ledgers model the
    honest gather wire either way, exactly as ``wire_bytes`` already does."""

    backend: Optional[str] = None
    ring_chunk_rows: Optional[int] = None

    name = "allgather_packed8"
    native_format = "pack8"

    def message_nnz(self, values):
        # nonzero LEVELS, not their magnitudes: |level| would overweight
        # large coordinates in the nnz_frac metric
        return jnp.sum((values != 0).astype(jnp.float32))

    def _interpret(self):
        return (self.backend == "interpret") if self.backend is not None else None

    def exchange(self, values, size, shape, *, scale=None):
        if scale is None:
            raise ValueError(
                "the pack8 wire dequantizes during the exchange and needs "
                "this worker's decode scale (CompressedGrad.scale)")
        if self.ring_chunk_rows is not None and self.backend != "jnp":
            return self._ring_exchange(values, scale, size, shape)
        return vote_allgather_packed8(values, scale, self.axes, size, shape,
                                      backend=self.backend)

    def _ring_exchange(self, payload, scale, size, shape):
        """Chunked ring exchange of one leaf: the (1,) decode scale rides
        every chunk's ring next to the payload (re-shipped per chunk — the
        ledger's ``ring_chunks`` factor), each arriving slice dequantize-
        summed through the fused kernel at M=1."""
        from repro.kernels import common as kcommon
        from repro.kernels.pack8.ops import unpack8_sum_op
        sc = jnp.asarray(scale, jnp.float32).reshape((1,))
        parts = []
        for r0, nr in _ring_chunk_spans(payload.shape[0], self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)

            def decode(b, s, _nr=nr):
                return unpack8_sum_op(b[None], s, _nr * kcommon.LANES,
                                      (_nr * kcommon.LANES,),
                                      interpret=self._interpret())

            parts.append(_ring_accumulate(chunk, (sc,), decode, self.axes,
                                          self.n_workers))
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return jax.lax.slice(flat, (0,), (size,)).reshape(shape)

    def exchange_bucket(self, payload, bucket, *, scale=None):
        """ONE payload all-gather + ONE (n_slots,) scale-vector all-gather for
        the whole bucket. Slots are sublane-aligned (``bucketing``'s pack8
        ``align_rows``), so each leaf's gathered row slice IS its per-leaf
        canonical view and decodes through the unmodified fused
        ``unpack8_sum`` kernel with that slot's per-worker scales — worker
        accumulation order and rounding points are bitwise the per-leaf wire.
        ``scale`` is the (n_slots,) f32 vector of the slots' decode scales."""
        if scale is None:
            raise ValueError(
                "the pack8 wire dequantizes during the exchange and needs "
                "the bucket's per-slot decode scales (one f32 per leaf)")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        from repro.kernels.pack8.ops import unpack8_sum_op
        scale = jnp.asarray(scale, jnp.float32).reshape(-1)
        assert scale.shape[0] == len(bucket.slots), (scale.shape, bucket)
        if self.backend == "jnp":
            # the psum oracle program, as in vote_allgather_packed8: decode
            # our own payload (per-row slot scales), ONE fp32 psum, split
            row_scales = jnp.concatenate(
                [jnp.broadcast_to(scale[i], (s.rows,))
                 for i, s in enumerate(bucket.slots)]
                + ([jnp.zeros((bucket.rows - sum(s.rows for s in bucket.slots),),
                              jnp.float32)] if bucket.rows > sum(
                                  s.rows for s in bucket.slots) else []))
            dec = payload.astype(jnp.float32) * row_scales[:, None]
            return bucketing.split_bucket(jax.lax.psum(dec, self.axes), bucket)
        if self.ring_chunk_rows is not None:
            return self._ring_exchange_bucket(payload, scale, bucket)
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        scales = jax.lax.all_gather(scale, self.axes, axis=0, tiled=False)
        interpret = self._interpret()
        out = []
        for i, s in enumerate(bucket.slots):
            rows = jax.lax.slice_in_dim(gathered, s.row_start,
                                        s.row_start + s.rows, axis=1)
            out.append(unpack8_sum_op(rows, scales[:, i], s.size, s.shape,
                                      interpret=interpret))
        return out

    def _ring_exchange_bucket(self, payload, scale, bucket):
        """Chunked ring exchange of one bucket: payload chunks on sublane
        row tiles, the whole (n_slots,) scale vector riding every chunk's
        ring. Slots are sublane-aligned (``bucketing``'s pack8
        ``align_rows``), so every chunk/slot intersection is a tile-aligned
        segment decoding through the unmodified fused kernel; per-slot
        segments re-concatenate in row order."""
        from repro.kernels import common as kcommon
        from repro.kernels.pack8.ops import unpack8_sum_op
        outs = [[] for _ in bucket.slots]
        for r0, nr in _ring_chunk_spans(bucket.rows, self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)
            segs = _chunk_segments(bucket.slots, r0, nr)

            def decode(b, sc, _segs=segs, _r0=r0):
                res = []
                for i, _s, a, srows in _segs:
                    rows = jax.lax.slice_in_dim(b, a - _r0, a - _r0 + srows,
                                                axis=0)
                    res.append(unpack8_sum_op(
                        rows[None], sc[i:i + 1], srows * kcommon.LANES,
                        (srows * kcommon.LANES,), interpret=self._interpret()))
                return tuple(res)

            part = _ring_accumulate(chunk, (scale,), decode, self.axes,
                                    self.n_workers)
            for (i, _s, _a, _srows), arr in zip(segs, part):
                outs[i].append(arr)
        result = []
        for s, parts in zip(bucket.slots, outs):
            flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            result.append(jax.lax.slice(flat, (0,), (s.size,)).reshape(s.shape))
        return result

    def exchange_weighted(self, values, size, shape, *, weight, scale=None):
        """Elastic pack8 exchange: the effective weight PREMULTIPLIES the
        decode scale (a dropped worker's scale*0 zeroes its dequantized
        contribution — the fused kernel is unchanged) and also ships raw in
        the widened (2,) side channel ``[scale * w, w]`` so the server can
        normalize by the realized participation total."""
        self._require_participation()
        if scale is None:
            raise ValueError(
                "the pack8 wire dequantizes during the exchange and needs "
                "this worker's decode scale (CompressedGrad.scale)")
        w = jnp.asarray(weight, jnp.float32)
        sc = jnp.asarray(scale, jnp.float32).reshape(())
        if self.backend == "jnp":
            # the psum oracle program, weighted: decode with scale * w
            from repro.kernels import common as kcommon
            dec = kcommon.from_2d(values, size, shape).astype(jnp.float32) \
                * (sc * w)
            return (jax.lax.psum(dec, tuple(self.axes)),
                    scalar_psum(w, self.axes))
        side = jnp.stack([sc * w, w])
        if self.ring_chunk_rows is not None:
            return self._ring_exchange_weighted(values, side, size, shape)
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        sides = jax.lax.all_gather(side, self.axes, axis=0, tiled=False)
        wv = _unpack8_op()(gathered, sides[:, 0], size, shape,
                                  interpret=self._interpret())
        return wv, jnp.sum(sides[:, 1])

    def _ring_exchange_weighted(self, payload, side, size, shape):
        """Weighted chunked ring: the (2,) ``[scale * w, w]`` side channel
        rides every chunk (re-shipped per chunk — ``scalar_bytes`` widens to
        8 B under participation and ``uplink_ledger`` multiplies by
        ``ring_chunks``); the raw weights accumulate around the ring into
        the participation total."""
        from repro.kernels import common as kcommon
        op = _unpack8_op()
        parts, wtot = [], None
        for r0, nr in _ring_chunk_spans(payload.shape[0], self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)

            def decode(b, s, _nr=nr):
                val = op(b[None], s[0:1], _nr * kcommon.LANES,
                         (_nr * kcommon.LANES,), interpret=self._interpret())
                return (val, s[1])

            acc, wt = _ring_accumulate(chunk, (side,), decode, self.axes,
                                       self.n_workers)
            parts.append(acc)
            wtot = wt if wtot is None else wtot
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return jax.lax.slice(flat, (0,), (size,)).reshape(shape), wtot

    def exchange_bucket_weighted(self, payload, bucket, *, weight, scale=None):
        """Bucketed elastic pack8 exchange: the per-slot scale vector is
        premultiplied by the effective weight and widened by one raw-weight
        entry — ONE (n_slots + 1,) side-channel gather for the whole
        bucket."""
        self._require_participation()
        if scale is None:
            raise ValueError(
                "the pack8 wire dequantizes during the exchange and needs "
                "the bucket's per-slot decode scales (one f32 per leaf)")
        from repro.dist import bucketing  # lazy: bucketing imports this module
        w = jnp.asarray(weight, jnp.float32)
        scale = jnp.asarray(scale, jnp.float32).reshape(-1)
        assert scale.shape[0] == len(bucket.slots), (scale.shape, bucket)
        if self.backend == "jnp":
            row_scales = jnp.concatenate(
                [jnp.broadcast_to(scale[i] * w, (s.rows,))
                 for i, s in enumerate(bucket.slots)]
                + ([jnp.zeros((bucket.rows - sum(s.rows for s in bucket.slots),),
                              jnp.float32)] if bucket.rows > sum(
                                  s.rows for s in bucket.slots) else []))
            dec = payload.astype(jnp.float32) * row_scales[:, None]
            return (bucketing.split_bucket(jax.lax.psum(dec, self.axes),
                                           bucket),
                    scalar_psum(w, self.axes))
        side = jnp.concatenate([scale * w, w.reshape((1,))])
        if self.ring_chunk_rows is not None:
            return self._ring_exchange_bucket_weighted(payload, side, bucket)
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        sides = jax.lax.all_gather(side, self.axes, axis=0, tiled=False)
        op = _unpack8_op()
        out = []
        for i, s in enumerate(bucket.slots):
            rows = jax.lax.slice_in_dim(gathered, s.row_start,
                                        s.row_start + s.rows, axis=1)
            out.append(op(rows, sides[:, i], s.size, s.shape,
                          interpret=self._interpret()))
        return out, jnp.sum(sides[:, -1])

    def _ring_exchange_bucket_weighted(self, payload, side, bucket):
        """Weighted bucket ring: the whole (n_slots + 1,) side vector rides
        every chunk; per-slot segments decode with the premultiplied scales
        and the raw-weight tail entry accumulates into the participation
        total."""
        from repro.kernels import common as kcommon
        op = _unpack8_op()
        outs = [[] for _ in bucket.slots]
        wtot = None
        for r0, nr in _ring_chunk_spans(bucket.rows, self.ring_chunk_rows):
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + nr, axis=0)
            segs = _chunk_segments(bucket.slots, r0, nr)

            def decode(b, sc, _segs=segs, _r0=r0):
                res = []
                for i, _s, a, srows in _segs:
                    rows = jax.lax.slice_in_dim(b, a - _r0, a - _r0 + srows,
                                                axis=0)
                    res.append(op(
                        rows[None], sc[i:i + 1], srows * kcommon.LANES,
                        (srows * kcommon.LANES,), interpret=self._interpret()))
                return tuple(res) + (sc[-1],)

            part = _ring_accumulate(chunk, (side,), decode, self.axes,
                                    self.n_workers)
            wtot = part[-1] if wtot is None else wtot
            for (i, _s, _a, _srows), arr in zip(segs, part[:-1]):
                outs[i].append(arr)
        result = []
        for s, parts in zip(bucket.slots, outs):
            flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            result.append(jax.lax.slice(flat, (0,), (s.size,)).reshape(s.shape))
        return result, wtot

    def wire_bytes(self, n_coords):
        # ring all-gather of the (padded) int8 payload to M-1 peers
        return float((self.n_workers - 1) * packed8_nbytes(n_coords))

    def scalar_bytes(self):
        # per-WORKER decode scales ride the same ring all-gather: M-1
        # incoming 4-B scalars per device (vs the all-reduced shared scalar
        # of the scaled_votes mode). The chunked ring re-ships them once
        # per chunk — ``uplink_ledger`` multiplies by ``ring_chunks``.
        # Elastic participation widens the slot to 8 B: the weighted decode
        # scale plus the raw weight (the participation side channel).
        per = 8.0 if self.participation is not None else 4.0
        return float((self.n_workers - 1) * per)

    def ring_chunks(self, n_coords):
        from repro.kernels import common as kcommon
        return len(_ring_chunk_spans(kcommon.canonical_rows(n_coords),
                                     self.ring_chunk_rows))

    def bucket_ring_chunks(self, bucket):
        return len(_ring_chunk_spans(bucket.rows, self.ring_chunk_rows))

    def _gather_hbm(self, rows: int) -> float:
        from repro.kernels import common as kcommon
        if self.ring_chunk_rows is None:
            return float(self.n_workers * rows * kcommon.LANES)
        max_nr = max(nr for _, nr in _ring_chunk_spans(rows, self.ring_chunk_rows))
        return float(2 * max_nr * kcommon.LANES)

    def gather_hbm_bytes(self, n_coords):
        from repro.kernels import common as kcommon
        return self._gather_hbm(kcommon.canonical_rows(n_coords))

    def bucket_gather_hbm_bytes(self, bucket):
        return self._gather_hbm(bucket.rows)


@dataclasses.dataclass(frozen=True)
class GolombWire(VoteWire):
    """All-gather of Golomb/RLE entropy-coded ternary payloads + fused
    decode-sum — the sub-2-bit variable-length wire (``kernels/golomb``).

    The message is a fixed-capacity uint8 byte stream sized at step-build
    time from the plan nonzero fraction ``p`` (``ref.golomb_rows``): coded
    zero-run gaps + sign bits behind an in-band header carrying the shipped/
    dropped nonzero counts (the length prefix — a gathered buffer is
    self-describing). Static capacity keeps the exchange a fixed-shape
    all-gather, so the byte ledger (capacity padding included) equals the
    traced collective exactly; messages denser than plan truncate at
    capacity with the dropped count in the header, and configurations where
    the capacity loses to pack2 already failed loudly at build time.

    With ``ring_chunk_rows`` set, the gather becomes the ppermute ring. The
    coded stream is not row-addressable mid-stream, so golomb chunks on
    STREAM boundaries: a per-leaf exchange rings its whole capacity stream
    as one chunk; a bucket rings groups of consecutive whole slots
    (``_slot_groups`` — each slot is its own self-describing stream).
    int32 accumulation, bitwise the monolithic gather."""

    backend: Optional[str] = None
    p: float = 0.05
    ring_chunk_rows: Optional[int] = None

    name = "allgather_golomb"
    native_format = "golomb"

    def message_nnz(self, values):
        # the in-band header IS the count: bytes 0-3, uint32 little-endian
        # (shipped nonzeros — what the server's vote sum will see)
        h = values.reshape(-1)[:4].astype(jnp.float32)
        return h[0] + h[1] * 256.0 + h[2] * 65536.0 + h[3] * 16777216.0

    def message_dropped(self, values):
        """Nonzeros truncated at capacity (header bytes 4-7) — the overflow
        telemetry a caller can surface when realized nnz outruns plan p."""
        h = values.reshape(-1)[4:8].astype(jnp.float32)
        return h[0] + h[1] * 256.0 + h[2] * 65536.0 + h[3] * 16777216.0

    def exchange(self, values, size, shape, *, scale=None):
        if scale is not None:
            raise ValueError(
                "the golomb vote wire exchanges entropy-coded ternary votes; "
                "a decode scale inside the exchange is a pack8-wire concept")
        if self.ring_chunk_rows is not None:
            # one leaf = one self-describing capacity stream = one chunk
            def decode(b):
                return _golomb_decode_sum(b[None], size, shape, p=self.p,
                                          backend=self.backend)

            total = _ring_accumulate(values, (), decode, self.axes,
                                     self.n_workers)
            return total.astype(_sum_dtype(self.n_workers))
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        total = _golomb_decode_sum(gathered, size, shape, p=self.p,
                                   backend=self.backend)
        return total.astype(_sum_dtype(self.n_workers))

    def exchange_bucket(self, payload, bucket, *, scale=None):
        """ONE all-gather of the whole coded bucket, then per-slot fused
        decode-sums on the gathered row slices. Slots are whole capacity
        streams (their own headers), so each slice decodes exactly as the
        per-leaf wire message — there is no whole-bucket decode to split:
        the coded stream, unlike pack2 rows, is not coordinate-addressable.
        The ring path chunks on whole-slot groups for the same reason."""
        if scale is not None:
            raise ValueError(
                "the golomb vote wire exchanges entropy-coded ternary votes; "
                "a decode scale inside the exchange is a pack8-wire concept")
        if self.ring_chunk_rows is not None:
            return self._ring_exchange_bucket(payload, bucket)
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        out = []
        for s in bucket.slots:
            rows = jax.lax.slice_in_dim(gathered, s.row_start,
                                        s.row_start + s.rows, axis=1)
            total = _golomb_decode_sum(rows, s.size, s.shape, p=self.p,
                                       backend=self.backend)
            out.append(total.astype(_sum_dtype(self.n_workers)))
        return out

    def _ring_exchange_bucket(self, payload, bucket):
        """Ring the bucket in whole-slot groups: each group's contiguous row
        span is one chunk whose decode is a tuple of per-slot fused
        decode-sums (slots carry their own headers, so a group chunk is a
        concatenation of self-contained streams)."""
        slot_pos = {s: i for i, s in enumerate(bucket.slots)}
        out = [None] * len(bucket.slots)
        for g in _slot_groups(bucket.slots, self.ring_chunk_rows):
            r0 = g[0].row_start
            g_rows = sum(s.rows for s in g)
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + g_rows, axis=0)

            def decode(b, _g=g, _r0=r0):
                res = []
                for s in _g:
                    rows = jax.lax.slice_in_dim(
                        b, s.row_start - _r0,
                        s.row_start - _r0 + s.rows, axis=0)
                    res.append(_golomb_decode_sum(rows[None], s.size, s.shape,
                                                  p=self.p,
                                                  backend=self.backend))
                return tuple(res)

            part = _ring_accumulate(chunk, (), decode, self.axes,
                                    self.n_workers)
            for s, arr in zip(g, part):
                out[slot_pos[s]] = arr.astype(_sum_dtype(self.n_workers))
        return out

    def exchange_weighted(self, values, size, shape, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the golomb vote wire exchanges entropy-coded ternary votes; "
                "a decode scale inside the exchange is a pack8-wire concept")
        w1 = jnp.asarray(weight, jnp.float32).reshape((1,))
        if self.ring_chunk_rows is not None:
            # one leaf = one self-describing capacity stream = one chunk;
            # the (1,) weight rides the same ring as the side channel
            def decode(b, wv):
                s = _golomb_decode_wsum(b[None], wv, size, shape, p=self.p,
                                        backend=self.backend)
                return (s, jnp.sum(wv))

            return _ring_accumulate(values, (w1,), decode, self.axes,
                                    self.n_workers)
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        wvec = jax.lax.all_gather(w1, self.axes, axis=0,
                                  tiled=False).reshape(-1)
        wv = _golomb_decode_wsum(gathered, wvec, size, shape, p=self.p,
                                 backend=self.backend)
        return wv, jnp.sum(wvec)

    def exchange_bucket_weighted(self, payload, bucket, *, weight, scale=None):
        self._require_participation()
        if scale is not None:
            raise ValueError(
                "the golomb vote wire exchanges entropy-coded ternary votes; "
                "a decode scale inside the exchange is a pack8-wire concept")
        w1 = jnp.asarray(weight, jnp.float32).reshape((1,))
        if self.ring_chunk_rows is not None:
            return self._ring_exchange_bucket_weighted(payload, w1, bucket)
        gathered = jax.lax.all_gather(payload, self.axes, axis=0, tiled=False)
        wvec = jax.lax.all_gather(w1, self.axes, axis=0,
                                  tiled=False).reshape(-1)
        out = []
        for s in bucket.slots:
            rows = jax.lax.slice_in_dim(gathered, s.row_start,
                                        s.row_start + s.rows, axis=1)
            out.append(_golomb_decode_wsum(rows, wvec, s.size, s.shape,
                                           p=self.p, backend=self.backend))
        return out, jnp.sum(wvec)

    def _ring_exchange_bucket_weighted(self, payload, w1, bucket):
        """Weighted slot-group ring: the (1,) weight rides every group
        chunk; raw weights accumulate around the ring into the realized
        participation total."""
        slot_pos = {s: i for i, s in enumerate(bucket.slots)}
        out = [None] * len(bucket.slots)
        wtot = None
        for g in _slot_groups(bucket.slots, self.ring_chunk_rows):
            r0 = g[0].row_start
            g_rows = sum(s.rows for s in g)
            chunk = jax.lax.slice_in_dim(payload, r0, r0 + g_rows, axis=0)

            def decode(b, wv, _g=g, _r0=r0):
                res = []
                for s in _g:
                    rows = jax.lax.slice_in_dim(
                        b, s.row_start - _r0,
                        s.row_start - _r0 + s.rows, axis=0)
                    res.append(_golomb_decode_wsum(rows[None], wv, s.size,
                                                   s.shape, p=self.p,
                                                   backend=self.backend))
                return tuple(res) + (jnp.sum(wv),)

            part = _ring_accumulate(chunk, (w1,), decode, self.axes,
                                    self.n_workers)
            wtot = part[-1] if wtot is None else wtot
            for s, arr in zip(g, part[:-1]):
                out[slot_pos[s]] = arr
        return out, wtot

    def weight_bytes(self):
        # the (1,) f32 effective weight gathered from M-1 peers next to the
        # coded payload — the elastic side channel
        if self.participation is None:
            return 0.0
        return float((self.n_workers - 1) * 4.0)

    def wire_bytes(self, n_coords):
        # ring all-gather of the capacity-padded coded payload to M-1 peers
        return float((self.n_workers - 1)
                     * golomb_payload_nbytes(n_coords, self.p))

    def bucket_payload_bytes(self, n_coords, rows=None):
        # bucket rows are capacity rows (plan-time, per slot), NOT coordinate
        # rows — bill exactly the (rows, 128) uint8 buffer the gather ships
        assert rows is not None, \
            "golomb bucket ledger needs the bucket's payload row count"
        from repro.kernels.golomb.ref import ROW_BYTES
        return float((self.n_workers - 1) * rows * ROW_BYTES)

    def payload_rows(self, n_coords: int) -> int:
        """Static capacity rows of one n-coordinate leaf at the wire's plan
        fraction — the bucket plan's ``rows_fn`` for this wire."""
        from repro.kernels.golomb.ref import golomb_rows
        return golomb_rows(n_coords, self.p)

    def bucket_ring_chunks(self, bucket):
        return len(_slot_groups(bucket.slots, self.ring_chunk_rows))

    def gather_hbm_bytes(self, n_coords):
        from repro.kernels.golomb.ref import ROW_BYTES, golomb_rows
        rows = golomb_rows(n_coords, self.p)
        if self.ring_chunk_rows is None:
            return float(self.n_workers * rows * ROW_BYTES)
        # a per-leaf stream is one chunk regardless of size (not row-
        # addressable), so the ring holds ~2 whole streams — still an M/2
        # residency win over the monolithic gather
        return float(2 * rows * ROW_BYTES)

    def bucket_gather_hbm_bytes(self, bucket):
        from repro.kernels.golomb.ref import ROW_BYTES
        if self.ring_chunk_rows is None:
            return float(self.n_workers * bucket.rows * ROW_BYTES)
        max_rows = max(sum(s.rows for s in g)
                       for g in _slot_groups(bucket.slots, self.ring_chunk_rows))
        return float(2 * max_rows * ROW_BYTES)


def make_vote_wire(impl: str, axes: Sequence[str], mesh=None, *,
                   backend: Optional[str] = None,
                   wire_format: str = "pack2",
                   golomb_p: Optional[float] = None,
                   ring_chunk_rows: Optional[int] = None,
                   participation: Optional[ParticipationSpec] = None) -> VoteWire:
    """Build the wire for ``impl`` over the worker ``axes`` at step-build time.

    Axis sizes come from ``mesh.shape`` when a mesh is given (the builders'
    path — errors surface before tracing), else from the ambient axis env
    (valid inside shard_map). ``backend`` steers the packed wires' decode-sum
    dispatch exactly like the engine's kernel backends. ``wire_format`` is the
    negotiated payload format (``engine.wire_payload_format``): ``pack2``
    selects the ternary wires, ``golomb`` the entropy-coded ternary gather
    (``allgather_packed`` impl only — a fabric psum cannot sum byte streams;
    ``golomb_p`` is its plan-time nonzero fraction, required), ``pack8`` the
    8-bit level gather (``allgather_packed`` only — levels quantized against
    per-worker norms cannot be reduced on the fabric). ``ring_chunk_rows``
    (gather wires only; a positive sublane multiple, e.g.
    ``DEFAULT_RING_CHUNK_ROWS``) switches the gather to the chunked
    ppermute ring — see the module docstring and ``engine.
    resolve_ring_chunk_rows`` for the negotiated path. ``participation``
    (a ``ParticipationSpec``) arms the elastic weighted-exchange family —
    per-worker weights are validated against the realized worker count here,
    at build time.
    """
    axes = tuple(axes)
    if participation is not None and not isinstance(participation,
                                                    ParticipationSpec):
        raise TypeError(
            f"participation must be a ParticipationSpec, got "
            f"{type(participation).__name__}")
    if impl not in VOTE_IMPLS:
        raise ValueError(f"unknown vote_impl {impl!r}; known: {VOTE_IMPLS}")
    if impl == "hier" and len(axes) != 2:
        raise ValueError(
            f"vote_impl='hier' needs exactly two worker axes (outer, inner) "
            f"— e.g. ('pod', 'data') — got {axes!r}. Use vote_impl='psum' "
            f"for a flat worker domain; silently substituting the flat wire "
            f"here would misreport the hierarchical byte ledger.")
    if wire_format not in ("pack2", "golomb", "pack8"):
        raise ValueError(
            f"unknown wire payload format {wire_format!r}; the vote wires "
            f"speak 'pack2'/'golomb' (ternary) or 'pack8' (8-bit levels) — "
            f"the float format rides the decoded psum, not a VoteWire")
    if wire_format == "pack8" and impl != "allgather_packed":
        raise ValueError(
            f"the pack8 wire needs vote_impl='allgather_packed' (per-worker "
            f"decode scales ride the gather; a fabric psum cannot sum levels "
            f"quantized against different norms), got {impl!r} — "
            f"engine.wire_mode falls back to the decoded wire there")
    if wire_format == "golomb":
        if impl != "allgather_packed":
            raise ValueError(
                f"the golomb wire needs vote_impl='allgather_packed' (a "
                f"fabric psum cannot reduce variable-length byte streams), "
                f"got {impl!r} — engine.wire_payload_format falls back to "
                f"int8 psum votes there")
        if golomb_p is None:
            raise ValueError(
                "the golomb wire needs golomb_p (the plan-time nonzero "
                "fraction that sizes its static capacity) — see "
                "engine.resolve_golomb_p")
        if not 0.0 < float(golomb_p) < 1.0:
            raise ValueError(
                f"golomb plan fraction must be in (0,1), got {golomb_p}")
    if ring_chunk_rows is not None:
        if impl != "allgather_packed":
            raise ValueError(
                f"ring_chunk_rows is a gather-wire concept (it chunks the "
                f"gathered payload) — vote_impl={impl!r} reduces on the "
                f"fabric and never materializes a gathered tensor; use "
                f"vote_impl='allgather_packed', or drop the ring knob")
        from repro.kernels import common as kcommon
        r = int(ring_chunk_rows)
        if r <= 0 or r % kcommon.SUBLANE_PAD != 0:
            raise ValueError(
                f"ring_chunk_rows must be a positive multiple of the "
                f"sublane tile ({kcommon.SUBLANE_PAD}) so every chunk stays "
                f"a valid kernel grid, got {ring_chunk_rows!r}")
        ring_chunk_rows = r
    sizes = tuple(int(mesh.shape[a]) for a in axes) if mesh is not None \
        else tuple(compat.axis_size(a) for a in axes)
    # one build-time validation point: every per-size /n in the byte ledgers
    # (and the worker count itself) is safe downstream of this check
    if not axes or any(s < 1 for s in sizes):
        raise ValueError(
            f"vote wire needs >= 1 worker: axes {axes!r} have sizes {sizes!r}")
    n = 1
    for s in sizes:
        n *= s
    if participation is not None:
        # weights must cover the realized fleet — fail before tracing
        participation.weights_array(n)
    if wire_format == "pack8":
        return Pack8Wire(axes=axes, n_workers=n, backend=backend,
                         ring_chunk_rows=ring_chunk_rows,
                         participation=participation)
    if wire_format == "golomb":
        return GolombWire(axes=axes, n_workers=n, backend=backend,
                          p=float(golomb_p), ring_chunk_rows=ring_chunk_rows,
                          participation=participation)
    if impl == "hier":
        return HierVoteWire(axes=axes, n_workers=n,
                            inner_size=sizes[1], outer_size=sizes[0],
                            participation=participation)
    if impl == "allgather_packed":
        return PackedVoteWire(axes=axes, n_workers=n, backend=backend,
                              ring_chunk_rows=ring_chunk_rows,
                              participation=participation)
    return VoteWire(axes=axes, n_workers=n, participation=participation)
