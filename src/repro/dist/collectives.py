"""Worker-axis collectives for the vote exchange (Algorithm 1 step 3).

The paper's M workers are the devices along the mesh worker axes ('pod',
'data'). Each worker holds an int8 ternary message per gradient leaf; the
server sum is a collective over those axes, computed redundantly on every
worker so the downlink is free. Three wire-equivalent variants:

- ``vote_psum``:             one integer psum — the production default.
- ``vote_psum_hier``:        two-level psum (int8 within a pod, widened
                             across pods) matching the hierarchical wire
                             model in benchmarks/bench_collectives.py.
- ``vote_allgather_packed``: all-gather of 2-bit-packed votes (the
                             kernels/pack2bit wire format) + local decode-sum;
                             costs M*d/4 bytes on the wire, honest about the
                             "no integer reduction on the fabric" regime.

All three return the same per-coordinate vote total; the equivalence is
pinned by tests/mdev/check_collectives.py on a forced 8-device host mesh.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.dist import compat


def axis_size(name) -> int:
    """Static size of a named mesh axis (valid inside shard_map)."""
    return compat.axis_size(name)


def worker_count(axes: Sequence[str]) -> int:
    """M = product of the worker-axis sizes (static)."""
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def worker_index(axes: Sequence[str]) -> jnp.ndarray:
    """This worker's flat index in [0, M): row-major over ``axes`` order."""
    idx = None
    for a in axes:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * compat.axis_size(a) + i
    return idx


def _sum_dtype(n_workers: int):
    """Smallest int dtype holding ternary-vote sums in [-M, M] — the psum
    payload dtype IS the wire format, so don't widen beyond need."""
    if n_workers <= 127:
        return jnp.int8
    if n_workers <= 32767:
        return jnp.int16
    return jnp.int32


def vote_psum(votes: jnp.ndarray, axes: Sequence[str], n_workers: int) -> jnp.ndarray:
    """Integer psum of ternary votes over the worker axes."""
    return jax.lax.psum(votes.astype(_sum_dtype(int(n_workers))), tuple(axes))


def vote_psum_hier(votes: jnp.ndarray, inner_axis: str, outer_axis: str,
                   inner_size: int, outer_size: int) -> jnp.ndarray:
    """Two-level vote sum: int8-narrow within the fast inner domain ('data',
    intra-pod ICI), widened only for the slow outer hop ('pod', DCN). Equal to
    the flat psum; the wire ledger differs (1 B/coord inner + 2 B/coord outer
    vs 1-4 B/coord flat, cf. bench_collectives.wire_model)."""
    inner = jax.lax.psum(votes.astype(_sum_dtype(int(inner_size))), inner_axis)
    total = int(inner_size) * int(outer_size)
    return jax.lax.psum(inner.astype(_sum_dtype(total)), outer_axis)


def vote_allgather_packed(votes: jnp.ndarray, axes: Sequence[str],
                          n_workers: int) -> jnp.ndarray:
    """All-gather of 2-bit-packed votes + local decode-sum.

    Wire bytes = M * ceil(d/4) per device (vs the psum's reduced payload) —
    the trade the paper's Table reports for fabrics without int reductions.
    Packing uses the pack2bit kernel's canonical block-interleaved format;
    decode is the pure-jnp oracle vmapped over workers (gathered bytes are
    small by construction, and the unpack is bandwidth-trivial).
    """
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import pack2bit_op
    from repro.kernels.pack2bit.ref import unpack2bit_ref

    packed = pack2bit_op(votes.astype(jnp.int8))          # (rows, LANES//4) u8
    gathered = jax.lax.all_gather(packed, tuple(axes), axis=0, tiled=False)
    ternary = jax.vmap(unpack2bit_ref)(gathered)          # (M, rows, LANES) i8
    total = jnp.sum(ternary.astype(jnp.int32), axis=0)
    total = kcommon.from_2d(total, votes.size, votes.shape)
    return total.astype(_sum_dtype(int(n_workers)))
