"""Worker-axis collectives for the vote exchange (Algorithm 1 step 3), and the
``VoteWire`` abstraction every hot-path consumer speaks.

The paper's M workers are the devices along the mesh worker axes ('pod',
'data'). Each worker holds a ternary message per gradient leaf; the server sum
is a collective over those axes, computed redundantly on every worker so the
downlink is free. Three wire-equivalent variants:

- ``vote_psum``:             one integer psum — the production default.
- ``vote_psum_hier``:        two-level psum (int8 within a pod, widened
                             across pods) matching the hierarchical wire
                             model in benchmarks/bench_collectives.py.
- ``vote_allgather_packed``: all-gather of 2-bit-packed votes (the
                             kernels/pack2bit wire format) + fused local
                             decode-sum; costs M*d/4 bytes on the wire, honest
                             about the "no integer reduction on the fabric"
                             regime.

All three return the same per-coordinate vote total; the equivalence is
pinned by tests/mdev/check_collectives.py on a forced 8-device host mesh and
by tests/mdev/check_wires.py at the train-step level.

``make_vote_wire(impl, axes, mesh)`` builds the wire object at step-build
time. A wire knows its *native message format* (``wants_packed``: int8 ternary
tensor vs 2-bit packed canonical view — what ``engine.compress_leaf(wire=...)``
emits), how to mask/count/exchange messages in that format, and its
per-round per-device wire-byte ledger (``wire_bytes``), computed from the real
buffer sizes (including canonical-view padding), not an idealized model.

Scale-carrying ternary compressors (the ``scaled_votes`` wire mode) ship one
shared f32 decode scale per leaf next to the payload: ``worker_shared_linf``
is the magnitude-sharing all-reduce(max) that produces it, and
``VoteWire.scalar_bytes`` its ledger entry.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist import compat

VOTE_IMPLS = ("psum", "hier", "allgather_packed")


def axis_size(name) -> int:
    """Static size of a named mesh axis (valid inside shard_map)."""
    return compat.axis_size(name)


def worker_count(axes: Sequence[str]) -> int:
    """M = product of the worker-axis sizes (static)."""
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def worker_index(axes: Sequence[str]) -> jnp.ndarray:
    """This worker's flat index in [0, M): row-major over ``axes`` order."""
    idx = None
    for a in axes:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * compat.axis_size(a) + i
    return idx


def _sum_dtype(n_workers: int):
    """Smallest int dtype holding ternary-vote sums in [-M, M] — the psum
    payload dtype IS the wire format, so don't widen beyond need."""
    if n_workers <= 127:
        return jnp.int8
    if n_workers <= 32767:
        return jnp.int16
    return jnp.int32


def packed_nbytes(n_coords: int) -> int:
    """Actual bytes of the 2-bit packed wire for an n-coordinate leaf: the
    canonical (rows, LANES) view is padded to the sublane tile, and the padded
    rows ship. This is the *real* per-worker payload (vs the idealized d/4)."""
    from repro.kernels import common as kcommon
    rows = -(-n_coords // kcommon.LANES)
    rows = -(-rows // kcommon.SUBLANE_PAD) * kcommon.SUBLANE_PAD
    return rows * (kcommon.LANES // 4)


def vote_psum(votes: jnp.ndarray, axes: Sequence[str], n_workers: int) -> jnp.ndarray:
    """Integer psum of ternary votes over the worker axes."""
    return jax.lax.psum(votes.astype(_sum_dtype(int(n_workers))), tuple(axes))


def worker_shared_linf(g: jnp.ndarray, axes: Sequence[str], mask=None) -> jnp.ndarray:
    """max_m ||g_m||_inf over the worker axes — TernGrad's magnitude-sharing
    protocol (one f32 scalar all-reduce(max), ~4 B on the fabric) and the
    ``linf_share`` budget policy's shared statistic. Must run inside the
    worker-axes shard_map. ``mask`` (scalar bool) excludes non-participating
    workers from the max, matching the round's sampled set S."""
    local = jnp.max(jnp.abs(g.astype(jnp.float32)))
    if mask is not None:
        local = jnp.where(mask, local, 0.0)
    return jax.lax.pmax(local, tuple(axes))


def vote_psum_hier(votes: jnp.ndarray, inner_axis: str, outer_axis: str,
                   inner_size: int, outer_size: int) -> jnp.ndarray:
    """Two-level vote sum: int8-narrow within the fast inner domain ('data',
    intra-pod ICI), widened only for the slow outer hop ('pod', DCN). Equal to
    the flat psum; the wire ledger differs (1 B/coord inner + 2 B/coord outer
    vs 1-4 B/coord flat, cf. bench_collectives.wire_model)."""
    inner = jax.lax.psum(votes.astype(_sum_dtype(int(inner_size))), inner_axis)
    total = int(inner_size) * int(outer_size)
    return jax.lax.psum(inner.astype(_sum_dtype(total)), outer_axis)


def vote_allgather_packed(votes: jnp.ndarray, axes: Sequence[str],
                          n_workers: int, *, backend: Optional[str] = None) -> jnp.ndarray:
    """All-gather of 2-bit-packed votes + fused local decode-sum.

    Wire bytes = M * ceil(d/4) per device (vs the psum's reduced payload) —
    the trade the paper's Table reports for fabrics without int reductions.
    Packing uses the pack2bit kernel's canonical block-interleaved format; the
    decode side is the fused unpack+accumulate kernel (``unpack2bit_sum_op``),
    so the (M, rows, LANES) int8 ternary tensor never materializes —
    ``backend="jnp"`` selects the vmapped oracle instead.
    """
    from repro.kernels.pack2bit.ops import pack2bit_op

    interpret = (backend == "interpret") if backend is not None else None
    packed = pack2bit_op(votes.astype(jnp.int8), interpret=interpret)
    total = _packed_decode_sum(
        jax.lax.all_gather(packed, tuple(axes), axis=0, tiled=False),
        votes.size, votes.shape, backend=backend)
    return total.astype(_sum_dtype(int(n_workers)))


def _packed_decode_sum(gathered: jnp.ndarray, size: int, shape,
                       *, backend: Optional[str]) -> jnp.ndarray:
    """(M, rows, q) gathered packed votes -> int32 vote sum in ``shape``,
    dispatched like the engine: jnp -> vmapped oracle, else fused kernel."""
    from repro.kernels import common as kcommon
    from repro.kernels.pack2bit.ops import unpack2bit_sum_op
    from repro.kernels.pack2bit.ref import unpack2bit_sum_ref

    if backend == "jnp":
        return kcommon.from_2d(unpack2bit_sum_ref(gathered), size, shape)
    interpret = (backend == "interpret") if backend is not None else None
    return unpack2bit_sum_op(gathered, size, shape, interpret=interpret)


# ---------------------------------------------------------------------------
# The wire abstraction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VoteWire:
    """One vote-exchange wire: message format + collective + byte ledger.

    Static (python-level) object closed over by the jitted train step; built
    once per step via ``make_vote_wire``. ``exchange`` must run inside the
    worker-axes shard_map. All wires return the same vote totals bitwise —
    only the message format and the bytes on the fabric differ.
    """

    axes: Tuple[str, ...]
    n_workers: int

    name = "psum"
    #: native uplink message format: False -> int8 ternary tensor (leaf shape),
    #: True -> 2-bit packed uint8 canonical view (rows, LANES//4)
    wants_packed = False

    def mask_message(self, values: jnp.ndarray, mask) -> jnp.ndarray:
        """Zero a non-participating worker's message, in wire-native format
        (an all-zero packed byte decodes to four zero votes)."""
        return jnp.where(mask, values, jnp.zeros((), values.dtype))

    def message_nnz(self, values: jnp.ndarray) -> jnp.ndarray:
        """Number of nonzero votes in one wire-native message (f32 scalar)."""
        return jnp.sum(jnp.abs(values).astype(jnp.float32))

    def exchange(self, values: jnp.ndarray, size: int, shape) -> jnp.ndarray:
        """Wire-native message -> integer vote sum of shape ``shape``."""
        return vote_psum(values, self.axes, self.n_workers)

    def wire_bytes(self, n_coords: int) -> float:
        """Per-device wire bytes to exchange one n-coordinate leaf's votes
        (ring-collective first principles, real payload sizes)."""
        m = self.n_workers
        payload = n_coords * jnp.dtype(_sum_dtype(m)).itemsize
        return 2.0 * (m - 1) / m * payload

    def scalar_bytes(self) -> float:
        """Ledger for one shared f32 scalar riding alongside a leaf's payload —
        the magnitude-shared scale (``worker_shared_linf``) of scale-carrying
        ternary compressors. One ring all-reduce of 4 bytes."""
        m = self.n_workers
        return 2.0 * (m - 1) / m * 4.0


@dataclasses.dataclass(frozen=True)
class HierVoteWire(VoteWire):
    """Two-level psum: narrow within axes[1] (intra-pod), widened across
    axes[0] (DCN hop). Requires exactly two worker axes."""

    inner_size: int = 1
    outer_size: int = 1

    name = "hier"

    def exchange(self, values, size, shape):
        return vote_psum_hier(values, self.axes[1], self.axes[0],
                              self.inner_size, self.outer_size)

    def wire_bytes(self, n_coords):
        ni, no = self.inner_size, self.outer_size
        inner = 2.0 * (ni - 1) / ni * n_coords * jnp.dtype(_sum_dtype(ni)).itemsize
        outer = 2.0 * (no - 1) / max(no, 1) * n_coords * jnp.dtype(_sum_dtype(ni * no)).itemsize
        return inner + outer


@dataclasses.dataclass(frozen=True)
class PackedVoteWire(VoteWire):
    """All-gather of the 2-bit packed wire + fused decode-sum. The message IS
    the packed canonical view — produced in one pass by the fused
    sparsign_pack2bit kernel on the kernel backends."""

    backend: Optional[str] = None

    name = "allgather_packed"
    wants_packed = True

    def message_nnz(self, values):
        # count nonzero 2-bit codes straight off the bytes: codes are {0,1,2},
        # so (b | b>>1) has bit 0 of each code set iff the code is nonzero
        nz = (values | (values >> 1)) & jnp.uint8(0x55)
        cnt = ((nz & 1) + ((nz >> 2) & 1) + ((nz >> 4) & 1) + ((nz >> 6) & 1))
        return jnp.sum(cnt.astype(jnp.float32))

    def exchange(self, values, size, shape):
        gathered = jax.lax.all_gather(values, self.axes, axis=0, tiled=False)
        total = _packed_decode_sum(gathered, size, shape, backend=self.backend)
        return total.astype(_sum_dtype(self.n_workers))

    def wire_bytes(self, n_coords):
        # ring all-gather: each device transmits its (padded) packed payload
        # to M-1 peers — no reduction on the fabric
        return float((self.n_workers - 1) * packed_nbytes(n_coords))


def make_vote_wire(impl: str, axes: Sequence[str], mesh=None, *,
                   backend: Optional[str] = None) -> VoteWire:
    """Build the wire for ``impl`` over the worker ``axes`` at step-build time.

    Axis sizes come from ``mesh.shape`` when a mesh is given (the builders'
    path — errors surface before tracing), else from the ambient axis env
    (valid inside shard_map). ``backend`` steers the packed wire's decode-sum
    dispatch exactly like the engine's kernel backends.
    """
    axes = tuple(axes)
    if impl not in VOTE_IMPLS:
        raise ValueError(f"unknown vote_impl {impl!r}; known: {VOTE_IMPLS}")
    if impl == "hier" and len(axes) != 2:
        raise ValueError(
            f"vote_impl='hier' needs exactly two worker axes (outer, inner) "
            f"— e.g. ('pod', 'data') — got {axes!r}. Use vote_impl='psum' "
            f"for a flat worker domain; silently substituting the flat wire "
            f"here would misreport the hierarchical byte ledger.")
    sizes = tuple(int(mesh.shape[a]) for a in axes) if mesh is not None \
        else tuple(compat.axis_size(a) for a in axes)
    n = 1
    for s in sizes:
        n *= s
    if impl == "hier":
        return HierVoteWire(axes=axes, n_workers=n,
                            inner_size=sizes[1], outer_size=sizes[0])
    if impl == "allgather_packed":
        return PackedVoteWire(axes=axes, n_workers=n, backend=backend)
    return VoteWire(axes=axes, n_workers=n)
