"""Version bridge to the modern jax sharding API.

The reproduction is written against the current API surface — ``jax.shard_map``
with ``axis_names``/``check_vma``, ``jax.sharding.set_mesh``, meshes carrying
``AxisType``, the two-argument ``AbstractMesh`` constructor and
``jax.lax.axis_size`` — but the pinned container ships jax 0.4.37 which has
none of them. Every helper here feature-detects at call time, so the same
call sites run correct (if not always maximally parallel) on both.

Old-jax (0.4.x) fallbacks, and what they cost:

- ``shard_map``: ``jax.experimental.shard_map`` with ALL mesh axes manual.
  Partial-manual lowering (``auto=...``) is broken in jaxlib 0.4.36 on the
  host platform — ``axis_index`` lowers to a PartitionId op the SPMD
  partitioner rejects, and all-gather trips an ``IsManualSubgroup`` check
  abort — so the non-worker axes are taken manual too. Parameters replicated
  over 'model' then compute redundantly per model-rank: results are bitwise
  identical to the partial-auto program, but there is no TP compute split on
  old jax. New jax re-engages GSPMD over the auto axes automatically.
- ``set_mesh``: the mesh's own context manager (resource env), which is what
  makes bare-PartitionSpec ``with_sharding_constraint`` resolve on 0.4.x.
- ``make_mesh``/``abstract_mesh``: drop ``axis_types`` / use the
  (name, size)-pairs constructor.
- ``axis_size``: ``jax.core.axis_frame(name)``, which on 0.4.37 returns the
  static axis size from the ambient axis env.
"""

from __future__ import annotations

import contextlib

import jax

HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax.sharding, "set_mesh")
HAS_ABSTRACT_MESH_CTX = hasattr(jax.sharding, "get_abstract_mesh")
HAS_LAX_AXIS_SIZE = hasattr(jax.lax, "axis_size")
#: ``jax.lax.ppermute`` accepts a TUPLE of named axes (flat row-major product
#: indexing over the axis group) from the 0.4 line on; very old releases take
#: a single axis name only. The ring-pipelined gather wire's primary path
#: needs the tuple form over ('pod','data') — when this is False,
#: ``dist.collectives._ring_permute_nested`` composes per-axis single-name
#: permutes instead (same result, more hops on the outer axis).
HAS_TUPLE_PPERMUTE = jax.__version_info__ >= (0, 4, 16)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with explicit-Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), devices=devices)


def abstract_mesh(axis_shapes, axis_names):
    """AbstractMesh under both the (sizes, names) and (name,size)-pairs ctors."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_shapes)))


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context: jax.sharding.set_mesh, or the 0.4.x resource env."""
    if HAS_SET_MESH:
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """jax.shard_map, or the jax.experimental fallback (see module docstring).

    ``axis_names`` is the set of manual axes; the rest of the mesh is auto
    (GSPMD) on new jax and — of necessity — manual on 0.4.x.
    """
    if HAS_NEW_SHARD_MAP:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(name) -> int:
    """Static size of a named (manual) mesh axis inside shard_map."""
    if HAS_LAX_AXIS_SIZE:
        return jax.lax.axis_size(name)
    return jax.core.axis_frame(name)


def manual_axis_names() -> frozenset:
    """Names of the manual mesh axes of the current trace (empty outside
    shard_map). Used to gate sharding hints: a constraint naming a manual
    axis is an error, and on 0.4.x every shard_map axis is manual."""
    try:
        return frozenset(jax.core.unsafe_get_axis_names_DO_NOT_USE())
    except Exception:  # noqa: BLE001 — introspection-only; absence means "none"
        return frozenset()
