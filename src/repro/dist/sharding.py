"""Logical-axis -> mesh sharding rules and placement builders.

Model code names *logical* axes ("vocab", "heads", "ff", "expert", "batch",
"seq"); this module owns the mapping onto the production mesh axes
('pod', 'data' = the paper's workers; 'model' = TP/EP/SP) and the sanitizer
that nulls any placement the actual dims cannot honor. Everything downstream
— the train steps' activation hints, the serve builders' param/cache
placement, the dry-run's input specs — derives from these tables, so a rule
change here re-shards the whole system coherently.
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Parameter placement (Megatron TP / EP): every feature-parallel logical axis
# maps onto 'model'. Conflicts on one tensor (e.g. an expert x ff weight) are
# resolved by sanitize_spec's last-wins dedup, matching hint()'s convention.
TP_RULES: Mapping[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "expert": "model",
}

# Training activations: batch over the worker axis (dropped by the train steps
# for axes they take manual), sequence between blocks and features inside them
# over 'model' (Megatron-style SP; hint()'s last-wins keeps the feature axis
# when both appear on one tensor).
ACT_RULES_TRAIN: Mapping[str, Optional[str]] = {
    "batch": "data",
    "seq": "model",
    "heads": "model",
    "ff": "model",
    "expert": "model",
    "vocab": "model",
}

# Serving activations: decode works on [B, 1] tokens — no sequence axis worth
# sharding (the cache depth is placed by cache_shardings_tree instead); batch
# rides the worker axes, which the serve builders override per deployment.
ACT_RULES_SERVE: Mapping[str, Optional[str]] = {
    "batch": "data",
    "seq": None,
    "heads": "model",
    "ff": "model",
    "expert": "model",
    "vocab": "model",
}


# ---------------------------------------------------------------------------
# Spec construction / sanitation
# ---------------------------------------------------------------------------

def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Mapping[str, Optional[str]] = TP_RULES) -> P:
    """Map a tuple of logical axis names to a mesh PartitionSpec.

    Unknown / None axes stay unsharded. The result is *raw*: it may repeat a
    mesh axis or not divide the dims — run it through sanitize_spec against
    the concrete shape before building a sharding.
    """
    return P(*(rules.get(name) if name is not None else None for name in logical))


def _entry_names(entry) -> tuple:
    """Mesh-axis names of one spec entry (scalar, tuple, or list)."""
    return tuple(entry) if isinstance(entry, (list, tuple)) else (entry,)


def sanitize_spec(spec: P, dims: Sequence[int], mesh) -> P:
    """Null out spec entries the dims cannot honor; dedup repeated mesh axes.

    Per dim: the mesh-axis product (tuple entries multiply) must divide a
    positive dim, else the entry is replaced by None — sharding a zero-size
    dim or leaving ragged shards is never worth a partial placement. A mesh
    axis claimed by several dims keeps only its LAST occurrence (feature dims
    trail batch/sequence dims in our layouts — same convention as hint()).
    Works on Mesh and AbstractMesh: only ``mesh.shape`` is consulted.
    """
    sizes = dict(mesh.shape)
    out = []
    for i, dim in enumerate(dims):
        entry = spec[i] if i < len(spec) else None
        if entry is None:
            out.append(None)
            continue
        names = _entry_names(entry)
        if len(set(names)) != len(names):  # axis repeated inside one dim
            out.append(None)
            continue
        size = 1
        for name in names:
            size *= sizes[name]
        out.append(entry if dim > 0 and dim % size == 0 else None)
    last = {}
    for i, entry in enumerate(out):
        if entry is None:
            continue
        for name in _entry_names(entry):
            if name in last:
                out[last[name]] = None
            last[name] = i
    return P(*out)


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


# ---------------------------------------------------------------------------
# Whole-tree placements
# ---------------------------------------------------------------------------

def tp_param_specs(model, mesh):
    """PartitionSpec tree for TP parameter placement (params replicated over
    the worker axes, feature axes over 'model', sanitized per leaf)."""
    shapes = model.param_shapes()
    logical = model.param_logical_axes()
    lg_leaves, treedef = jax.tree_util.tree_flatten(logical, is_leaf=_is_logical)
    sh_leaves = treedef.flatten_up_to(shapes)
    specs = [sanitize_spec(logical_to_spec(lg), s.shape, mesh)
             for lg, s in zip(lg_leaves, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def tp_param_shardings(model, mesh):
    """NamedSharding tree placing params for the simple trainer / TP serving."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), tp_param_specs(model, mesh),
        is_leaf=lambda x: isinstance(x, P))


# Decode-cache leaf layouts, positions counted from the END so the same entry
# serves stacked (leading superblock-repeat axis) and unstacked (tail) leaves.
_CACHE_LAYOUT = {
    "k": {"batch": -4, "seq": -3, "heads": -2},
    "v": {"batch": -4, "seq": -3, "heads": -2},
    "pos": {"batch": -2, "seq": -1},
    "conv": {"batch": -3},            # mamba conv tail: no shardable seq axis
    "state": {"batch": -4, "heads": -3},
}


def cache_shardings_tree(cache_shapes, mesh, *, worker_axes: Sequence[str] = ("data",),
                         shard_seq: bool = False):
    """NamedSharding tree for a decode-cache pytree.

    Default: batch over the worker axes, kv-heads over 'model'. With
    ``shard_seq`` (long-context, batch < workers) the cache *sequence* axis is
    sharded over the worker axes instead and batch stays replicated — GSPMD
    then inserts the distributed-softmax reductions. Every placement is
    sanitized against the leaf's dims, so non-dividing head counts or window
    sizes degrade to replication rather than erroring.
    """
    wa = tuple(worker_axes)
    wa_entry = wa if len(wa) > 1 else wa[0]

    def one(path, sds):
        name = path[-1].key
        layout = _CACHE_LAYOUT[name]
        rank = len(sds.shape)
        spec = [None] * rank
        if shard_seq:
            if "seq" in layout:
                spec[rank + layout["seq"]] = wa_entry
        else:
            spec[rank + layout["batch"]] = wa_entry
        if "heads" in layout:
            spec[rank + layout["heads"]] = "model"
        return NamedSharding(mesh, sanitize_spec(P(*spec), sds.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
