"""Bucketized uplink wire layout: many gradient leaves -> few big collectives.

Both train modes historically exchanged one collective per gradient leaf; at
model-config scale (27B-72B) that is hundreds of small launches per step, each
paying launch overhead and its own canonical-view padding tax. A ``BucketPlan``
is the static (step-build-time) answer: every leaf's wire-native payload is
trimmed to whole canonical rows (LANES coordinates per row) and laid out
contiguously into fixed-capacity *buckets*, so one bucket rides ONE collective
and the sublane-tile padding is paid once per bucket instead of once per leaf.

Row granularity is what keeps the packed formats exchange-legal:

  * ``pack2`` packs each canonical row independently (block-interleaved within
    the row), so any whole-row slice of the payload is itself a valid pack2
    stream — leaves may start at ANY row (``align_rows=1``) and the bucket is
    decoded in one fused pass, then split per leaf on the decoded stream.
  * ``pack8`` payload slices are consumed by the fused ``unpack8_sum`` kernel,
    whose grid needs sublane-aligned row counts — leaves align to
    ``SUBLANE_PAD`` rows (``align_rows=32``), i.e. exactly their canonical
    per-leaf row count, and decode per slot with that worker's gathered scale.
  * ``golomb`` slots are whole self-describing entropy-coded streams (their
    own in-band headers) at plan-time CAPACITY rows — the variable-length
    payload protocol: per-slot encoded lengths become static capacity via
    the wire's ``payload_rows`` (``build_bucket_plan``'s ``rows_fn``), the
    length prefix rides in-band, and each gathered slice decodes exactly as
    the per-leaf wire message (``align_rows=1``).
  * ``int8`` votes and ``f32`` decoded messages are element-wise under
    psum, so rows are just the shared layout unit (``align_rows=1``).

The per-leaf compress (seeds, counter_base, budget/scale resolution) is
UNCHANGED — a slot's payload is bitwise the per-leaf wire message, so bucketed
and per-leaf exchanges agree bitwise and the counter-stream layout the
cross-mode equivalence tests pin survives bucket granularity.

``plan_ledger`` is the bucketed twin of ``collectives.uplink_ledger``; the
``repro.analysis`` CollectiveCensus pins it against the traced step exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist import collectives
from repro.kernels import common as kcommon

#: payload formats a bucket can carry (wire native formats + the decoded f32
#: stream, which rides the fp32 psum outside any VoteWire). ``golomb`` rows
#: are capacity rows of the entropy-coded stream, NOT coordinate rows — each
#: slot is one self-describing coded message (kernels/golomb), so slot sizing
#: comes from the wire's capacity model (``build_bucket_plan``'s ``rows_fn``)
#: rather than ``leaf_rows``.
BUCKET_FORMATS = ("int8", "pack2", "golomb", "pack8", "f32")

#: bytes one canonical payload row occupies in each format's wire buffer
ROW_BYTES = {"int8": kcommon.LANES, "pack2": kcommon.LANES // 4,
             "golomb": kcommon.LANES // 4,
             "pack8": kcommon.LANES, "f32": 4 * kcommon.LANES}

#: numpy/jnp dtype of the payload buffer per format
ROW_DTYPE = {"int8": jnp.int8, "pack2": jnp.uint8, "golomb": jnp.uint8,
             "pack8": jnp.int8, "f32": jnp.float32}

#: row width (elements per row) of the payload buffer per format
ROW_WIDTH = {"int8": kcommon.LANES, "pack2": kcommon.LANES // 4,
             "golomb": kcommon.LANES // 4,
             "pack8": kcommon.LANES, "f32": kcommon.LANES}


def format_align_rows(fmt: str) -> int:
    """Slot row-alignment per payload format: pack8 slices feed the fused
    decode kernel (sublane-tiled grid), everything else is row-independent
    (golomb slots are whole self-describing streams — any row start works)."""
    if fmt not in BUCKET_FORMATS:
        raise ValueError(f"unknown bucket format {fmt!r}; known: {BUCKET_FORMATS}")
    return kcommon.SUBLANE_PAD if fmt == "pack8" else 1


def wire_bucket_format(mode: str, wire) -> str:
    """Payload format a wire mode's bucket carries: the wire's native message
    format, or the decoded fp32 stream for the ``decoded`` mode."""
    return "f32" if mode == "decoded" else wire.native_format


def leaf_rows(n: int, align_rows: int) -> int:
    """Payload rows an n-coordinate leaf occupies at the given alignment:
    ceil to full LANES rows, then up to the alignment multiple. At
    ``align_rows=SUBLANE_PAD`` this IS ``kcommon.canonical_rows(n)`` — the
    slot slice equals the leaf's own canonical view."""
    rows = -(-n // kcommon.LANES)
    return -(-rows // align_rows) * align_rows


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's home inside a bucket. ``index`` is the leaf's position in
    the group list the plan was built from (the canonical flat leaf order —
    what seeds/quorum/EF are indexed by)."""

    index: int
    size: int
    shape: Tuple[int, ...]
    row_start: int
    rows: int


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One wire buffer: ``rows`` canonical payload rows (slot rows plus tail
    padding to the kernel tile for the packed formats)."""

    slots: Tuple[LeafSlot, ...]
    rows: int

    @property
    def n_coords(self) -> int:
        return self.rows * kcommon.LANES


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static leaf->bucket layout for one exchange group (the whole tree in
    simple mode; one superblock layer, or the outer leaves, in streamed
    mode). Built once at step-build time; closed over by the jitted step."""

    fmt: str
    align_rows: int
    buckets: Tuple[Bucket, ...]

    @property
    def n_slots(self) -> int:
        return sum(len(b.slots) for b in self.buckets)

    @property
    def total_rows(self) -> int:
        return sum(b.rows for b in self.buckets)

    def wire_nbytes(self) -> int:
        """Bytes of all payload buffers (one worker's copy), padding included."""
        return self.total_rows * ROW_BYTES[self.fmt]


def _tail_pad(rows: int, fmt: str) -> int:
    # the packed formats decode through sublane-tiled kernel grids; the psum
    # formats ship exactly the slot rows
    if fmt in ("pack2", "pack8"):
        pad = kcommon.SUBLANE_PAD
        return -(-rows // pad) * pad
    return rows


def build_bucket_plan(shapes: Sequence, fmt: str, *,
                      bucket_bytes: Optional[int] = None,
                      rows_fn=None) -> BucketPlan:
    """Greedy in-order packing of ``shapes`` (leaf shapes, canonical flat
    order) into buckets of at most ``bucket_bytes`` payload each
    (``None`` = unbounded: one bucket for the whole group). A leaf larger
    than the cap gets its own bucket — leaves are never split across
    buckets (per-leaf quorum/EF/server math address one slot).

    ``rows_fn`` (n_coords -> payload rows) overrides the coordinate-count
    row rule for variable-length formats: the golomb wire's slot rows are
    plan-time CAPACITY rows (``GolombWire.payload_rows``), not
    ``leaf_rows``. Required for fmt='golomb', meaningless elsewhere."""
    if (fmt == "golomb") != (rows_fn is not None):
        raise ValueError(
            "rows_fn is how the variable-length golomb format sizes its "
            "capacity slots: required for fmt='golomb' (pass the wire's "
            "payload_rows), invalid for the fixed-rate formats")
    align = format_align_rows(fmt)
    row_bytes = ROW_BYTES[fmt]
    cap_rows = None
    if bucket_bytes is not None:
        cap_rows = max(align, (int(bucket_bytes) // row_bytes // align) * align)
    buckets: List[Bucket] = []
    slots: List[LeafSlot] = []
    row = 0

    def flush():
        nonlocal slots, row
        if slots:
            buckets.append(Bucket(slots=tuple(slots), rows=_tail_pad(row, fmt)))
        slots, row = [], 0

    for i, s in enumerate(shapes):
        shape = tuple(s.shape) if hasattr(s, "shape") else tuple(s)
        n = int(math.prod(shape)) if shape else 1
        rows = rows_fn(n) if rows_fn is not None else leaf_rows(n, align)
        if cap_rows is not None and slots and row + rows > cap_rows:
            flush()
        slots.append(LeafSlot(index=i, size=n, shape=shape,
                              row_start=row, rows=rows))
        row += rows
        if cap_rows is not None and row >= cap_rows:
            flush()
    flush()
    return BucketPlan(fmt=fmt, align_rows=align, buckets=tuple(buckets))


# ---------------------------------------------------------------------------
# Payload assembly / splitting (traced)
# ---------------------------------------------------------------------------

def as_rows(values: jnp.ndarray, fmt: str, rows: int) -> jnp.ndarray:
    """One leaf's wire message -> exactly ``rows`` payload rows (its bucket
    slice). Packed messages arrive as canonical 2D views and are trimmed
    (dropped tail rows are sublane zero-padding the per-leaf wire would have
    shipped); leaf-shaped messages are flattened and zero-padded into rows.
    The coordinate at (r, c) keeps flat index r*LANES + c, so the
    counter-stream layout is untouched."""
    width = ROW_WIDTH[fmt]
    if fmt == "golomb":
        # coded messages are emitted at EXACTLY their capacity rows (the
        # same golomb_rows(n, p) rule that sized the slot) — a mismatch
        # means encoder and plan disagree on p or n: refuse loudly
        assert values.ndim == 2 and values.shape == (rows, width), \
            (values.shape, rows, width)
        return values
    if fmt in ("pack2", "pack8"):
        assert values.ndim == 2 and values.shape[1] == width, values.shape
        assert values.shape[0] >= rows, (values.shape, rows)
        return values[:rows]
    flat = values.reshape(-1).astype(ROW_DTYPE[fmt])
    assert flat.shape[0] <= rows * width, (flat.shape, rows)
    padded = jnp.zeros((rows * width,), ROW_DTYPE[fmt]).at[:flat.shape[0]].set(flat)
    return padded.reshape(rows, width)


def assemble_bucket(payloads: Sequence[jnp.ndarray], bucket: Bucket,
                    fmt: str) -> jnp.ndarray:
    """Slot payload rows (aligned with ``bucket.slots``) -> one contiguous
    (bucket.rows, width) wire buffer, tail rows zero."""
    parts = list(payloads)
    assert len(parts) == len(bucket.slots)
    used = sum(s.rows for s in bucket.slots)
    if bucket.rows > used:
        parts.append(jnp.zeros((bucket.rows - used, ROW_WIDTH[fmt]),
                               ROW_DTYPE[fmt]))
    return jnp.concatenate(parts, axis=0)


def split_bucket(agg: jnp.ndarray, bucket: Bucket) -> List[jnp.ndarray]:
    """One bucket's aggregated (decoded/summed) payload -> per-leaf arrays in
    the leaves' shapes, aligned with ``bucket.slots``. ``agg`` is row-shaped
    (rows, LANES) or flat (rows*LANES,); slicing is static under jit."""
    flat = agg.reshape(-1)
    out = []
    for s in bucket.slots:
        start = s.row_start * kcommon.LANES
        out.append(jax.lax.slice(flat, (start,), (start + s.size,)).reshape(s.shape))
    return out


# ---------------------------------------------------------------------------
# Byte ledger — the bucketed twin of collectives.uplink_ledger
# ---------------------------------------------------------------------------

def plan_ledger(mode: str, wire, plan: BucketPlan, *,
                share_linf: bool = False) -> Tuple[float, float]:
    """(payload_bytes, scalar_bytes) one application of ``plan`` bills to the
    per-device uplink — split the way the analysis census splits (array
    payloads >= 2 elements vs scalar protocol traffic). Payload terms come
    from ``collectives.uplink_ledger_bucket`` (one bucket = one exchange);
    the shared-linf term is ONE vector pmax over all the plan's slots
    (vs one scalar pmax per leaf in the per-leaf path)."""
    payload = scalar = 0.0
    for b in plan.buckets:
        p, s = collectives.uplink_ledger_bucket(
            mode, wire, b.n_coords, len(b.slots), rows=b.rows,
            ring_chunks=wire.bucket_ring_chunks(b))
        payload += p
        scalar += s
    if share_linf:
        n = plan.n_slots
        bytes_ = collectives.allreduce_scalar_bytes(wire.n_workers) * n
        if n >= 2:
            payload += bytes_
        else:
            scalar += bytes_
    return payload, scalar


def plan_gather_hbm_bytes(mode: str, wire, plan: BucketPlan) -> float:
    """Peak gathered-payload HBM across the plan's bucket exchanges — the
    bucketed twin of ``wire.gather_hbm_bytes``. Buckets exchange one at a
    time, so the plan's peak is the max bucket, not the sum; the decoded
    mode's psum never materializes a gathered tensor (0.0), matching
    ``collectives.VoteWire.gather_hbm_bytes`` for the psum wires."""
    if mode == "decoded":
        return 0.0
    return max((wire.bucket_gather_hbm_bytes(b) for b in plan.buckets),
               default=0.0)


def streamed_plan_ledger(mode: str, wire, block_plan: BucketPlan,
                         outer_plan: BucketPlan, n_repeats: int, *,
                         share_linf: bool = False) -> Tuple[float, float]:
    """(payload, scalar) per-device uplink bytes for one bucketed streamed
    step. The double-buffered backward scan exchanges the *pending* layer's
    buckets each iteration: it primes with one zero bucket (first iteration)
    and drains the last pending bucket after the scan, so each block bucket
    rides the wire ``n_repeats + 1`` times per step — billed honestly, it is
    the pipeline's fill/drain cost (one extra exchange out of n_repeats+1).
    The shared-linf vector pmax runs at compress time — once per REAL layer
    (``n_repeats``) plus once for the outer group."""
    bp, bs = plan_ledger(mode, wire, block_plan)
    op, osc = plan_ledger(mode, wire, outer_plan, share_linf=share_linf)
    payload = (n_repeats + 1) * bp + op
    scalar = (n_repeats + 1) * bs + osc
    if share_linf:
        n = block_plan.n_slots
        bytes_ = collectives.allreduce_scalar_bytes(wire.n_workers) * n
        if n >= 2:
            payload += n_repeats * bytes_
        else:
            scalar += n_repeats * bytes_
    return payload, scalar
