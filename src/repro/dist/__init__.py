"""repro.dist — the distributed substrate.

Two modules plus a version bridge:

- ``sharding``:    logical-axis -> mesh PartitionSpec rules (TP/EP/SP for
  params, activations, decode caches) and the placement sanitizer that keeps
  every spec divisible on the actual dims.
- ``collectives``: the worker-axis vote exchange — the paper's "M workers send
  ternary messages, the server sums" step, as shard_map collectives in three
  wire-equivalent variants (flat int psum, hierarchical pod/data psum,
  2-bit-packed all-gather).
- ``compat``:      feature-detecting bridge between the current jax sharding
  API this repo targets and the pinned jax 0.4.x in the container.
"""

from repro.dist import collectives, compat, sharding

__all__ = ["collectives", "compat", "sharding"]
