"""Paper Table 3 (+ Tables 4-7 alpha sweep): EF-SPARSIGNSGD with tau local steps
vs the FedCom-style 8-bit-QSGD FedAvg baseline."""

from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import csv_header, csv_row
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import ImageDataConfig, make_image_dataset
from repro.fl.models import mlp_fashion
from repro.fl.simulation import FLConfig, run_fl, stack_partitions


def _ef(tau):
    return CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=1.0),
                             server="scaled_sign_ef", local_steps=tau, local_budget=10.0)


def main(fast: bool = False):
    n_workers = 20
    rounds = 30 if fast else 80
    taus = (1, 5) if fast else (1, 5, 10, 20)
    alphas = (0.1,) if fast else (0.1, 0.5)

    for alpha in alphas:
        x, y, xt, yt = make_image_dataset(ImageDataConfig(
            n_train=3000 if fast else 8000, n_test=800, seed=2))
        parts = dirichlet_partition(y, n_workers=n_workers, alpha=alpha, seed=2)
        xp, yp = stack_partitions(x, y, parts)
        v0, apply_fn = mlp_fashion(jax.random.PRNGKey(2))

        print(f"# Table 3 analog (alpha={alpha}): EF-SPARSIGNSGD-Local(tau), M={n_workers}")
        csv_header(["algorithm", "tau", "final_acc", "uplink_bits_per_round"])
        for tau in taus:
            cfg = FLConfig(n_workers=n_workers, rounds=max(10, rounds // max(1, tau // 2)),
                           batch_size=64, lr=0.05, local_lr=0.02, comp=_ef(tau),
                           seed=2, eval_every=5)
            res = run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)
            csv_row([f"ef_sparsign_local{tau}", tau, f"{res['final_acc']:.4f}",
                     f"{res['uplink_bits_per_round']:.3e}"])
        # FedCom analog: 8-bit QSGD uplink, mean server (FedAvg aggregation)
        from repro.core.encoding import baseline_bits_per_round
        comp = CompressionConfig(compressor="qsgd_1bit_l2", server="mean")
        cfg = FLConfig(n_workers=n_workers, rounds=rounds, batch_size=64,
                       lr=0.05, comp=comp, seed=2, eval_every=5)
        res = run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)
        bits8 = baseline_bits_per_round(res["d"], "qsgd8") * n_workers
        csv_row(["fedcom_8bit_qsgd(1-bit uplink run, 8-bit accounted)", 1,
                 f"{res['final_acc']:.4f}", f"{bits8:.3e}"])


if __name__ == "__main__":
    main()
