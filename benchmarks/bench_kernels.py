"""Compression-kernel benchmark: jnp reference vs Pallas for the three engine
kernels (sparsign, vote_update, ef_server) plus the pack2bit wire packer, at
model-realistic leaf shapes.

On CPU the Pallas side runs in interpret mode — a correctness-path timing, not
the TPU roofline; the structural hbm_bytes_per_coord column carries the TPU
memory-traffic model either way. Full runs write ``BENCH_kernels.json`` at the
repo root (the tracked bench-trajectory baseline); ``--quick`` writes
``BENCH_kernels.quick.json`` (the CI smoke artifact) so it can't clobber the
baseline.

  python -m benchmarks.bench_kernels            # full shapes
  python -m benchmarks.bench_kernels --quick    # CI smoke
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_header, csv_row, timed
from repro.analysis.jaxpr_audit import NoHbmIntermediate
from repro.kernels import common as kcommon
from repro.kernels.ef_server.ops import ef_server_op
from repro.kernels.ef_server.ref import ef_scale, ef_server_ref
from repro.kernels.golomb.ops import golomb_pack_op, sparsign_golomb_op
from repro.kernels.golomb.ref import golomb_encode_ref, golomb_nbytes
from repro.kernels.pack2bit.ops import pack2bit_op
from repro.kernels.pack2bit.ref import pack2bit_ref
from repro.kernels.pack8.ops import qsgd8_op, qsgd8_pack8_op
from repro.kernels.pack8.ref import qsgd8_levels_ref
from repro.kernels.sparsign.ops import sparsign_op
from repro.kernels.sparsign.ref import sparsign_ref
from repro.kernels.sparsign_pack2bit.ops import sparsign_pack2bit_op
from repro.kernels.ternary.ops import ternary_compress_op, ternary_pack2bit_op
from repro.kernels.vote_update.ops import vote_update_op
from repro.kernels.vote_update.ref import vote_update_ref

ROOT = Path(__file__).resolve().parents[1]
OUT_PATH = ROOT / "BENCH_kernels.json"            # tracked full-shape baseline
QUICK_OUT_PATH = ROOT / "BENCH_kernels.quick.json"  # CI smoke; never tracked

# model-realistic leaf shapes (qwen1.5-4b-class: hidden 2560, ffn 6912;
# embed shard = vocab slice of an FSDP-sharded embedding table)
SHAPES_FULL = {
    "attn_proj_2560x2560": (2560, 2560),
    "mlp_up_2560x6912": (2560, 6912),
    "embed_shard_8192x2560": (8192, 2560),
}
SHAPES_QUICK = {
    "leaf_64k": (512, 128),
    "leaf_256k": (512, 512),
}

# TPU HBM traffic per coordinate (structural, independent of where we time)
BYTES_PER_COORD = {
    ("sparsign", "pallas"): 4 + 1,        # read f32, write i8; RNG in-register
    ("sparsign", "jnp"): 4 + 4 + 4 + 1,   # + u32 idx and f32 uniform traffic
    ("vote_update", "pallas"): 4 + 4 + 4, # w + votes -> w' in one pass
    ("vote_update", "jnp"): 4 * 4,        # sign/cast/scale/sub ~4 passes
    ("ef_server", "pallas"): 8 + 8,       # (d,e) in, (out,e') out fused
    ("ef_server", "jnp"): 8 * 3,          # ~4-pass unfused chain over (d,e)
    ("pack2bit", "pallas"): 1 + 0.25,
    # the allgather_packed uplink, fused vs two-pass: fused reads the f32
    # gradient and writes wire bytes in ONE kernel (the int8 ternary tensor
    # never exists in HBM); two-pass pays the compress write + pack read
    ("uplink_fused", "pallas"): 4 + 0.25,
    ("uplink_two_pass", "pallas"): (4 + 1) + (1 + 0.25),
    ("uplink_two_pass", "jnp"): (4 + 4 + 4 + 1) + (1 + 0.25),
    # the generic ternary template's fused uplinks (CompressorSpec registry):
    # same single-pass structure for every ternary compressor — noisy_sign
    # draws two RNG streams (both in-register, zero extra HBM traffic),
    # terngrad's s_t arrives as a pre-reduced scalar in SMEM
    ("uplink_fused_noisy_sign", "pallas"): 4 + 0.25,
    ("uplink_fused_terngrad", "pallas"): 4 + 0.25,
    ("uplink_two_pass_noisy_sign", "pallas"): (4 + 1) + (1 + 0.25),
    ("uplink_two_pass_terngrad", "pallas"): (4 + 1) + (1 + 0.25),
    # the entropy-coded (golomb) uplink at plan p=0.05: fused reads the f32
    # gradient and writes the coded byte stream in ONE pass (~0.05 B/coord of
    # capacity rows on the wire — sub-2-bit); two-pass pays the int8 ternary
    # write + re-read before coding
    ("uplink_fused_golomb", "pallas"): 4 + 0.05,
    ("uplink_two_pass_golomb", "pallas"): (4 + 1) + (1 + 0.05),
    ("uplink_two_pass_golomb", "jnp"): (4 + 4 + 4 + 1) + (1 + 0.05),
    # the 8-bit QSGD (pack8) uplink: fused reads the f32 gradient and writes
    # the int8 sign*level wire payload in ONE pass (1 B/coord on the wire);
    # the decoded-psum chain it replaces quantizes, re-reads the levels and
    # writes the 4 B/coord fp32 psum payload
    ("uplink_fused_qsgd8", "pallas"): 4 + 1,
    ("uplink_decoded_psum_qsgd8", "pallas"): (4 + 1) + (1 + 4),
    ("uplink_decoded_psum_qsgd8", "jnp"): (4 + 4 + 4 + 1) + (1 + 4),
}


def _bench_shape(name: str, shape, records: list, pallas_label: str):
    n = int(np.prod(shape))
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(*shape), jnp.float32)
    w = jnp.asarray(rng.randn(*shape), jnp.float32)
    v = jnp.asarray(rng.randint(-16, 17, shape), jnp.int32)
    e = jnp.asarray(rng.randn(*shape), jnp.float32)
    t = jnp.asarray(rng.randint(-1, 2, shape), jnp.int8)

    # jit the jnp reference sides too — the engine's jnp backend runs inside
    # the jitted train step, so eager dispatch overhead is not part of what a
    # backend switch trades off
    sparsign_jnp = jax.jit(lambda x: sparsign_ref(x, 1.0, 7))
    vote_update_jnp = jax.jit(lambda a, b: vote_update_ref(a, b, 0.01))
    ef_server_jnp = jax.jit(lambda d, r: ef_server_ref(d, r, ef_scale(d, r))[0])
    # all-jnp two-pass uplink (what the engine's jnp backend runs for the
    # packed wire): reference compress + reference pack over the canonical view
    uplink_jnp = jax.jit(lambda x: pack2bit_ref(
        kcommon.to_2d(sparsign_ref(x, 1.0, 7).reshape(-1))[0]))

    cases = [
        ("sparsign", "pallas",
         lambda: jax.block_until_ready(sparsign_op(g, 1.0, 7))),
        ("sparsign", "jnp",
         lambda: jax.block_until_ready(sparsign_jnp(g))),
        ("vote_update", "pallas",
         lambda: jax.block_until_ready(vote_update_op(w, v, 0.01))),
        ("vote_update", "jnp",
         lambda: jax.block_until_ready(vote_update_jnp(w, v))),
        ("ef_server", "pallas",
         lambda: jax.block_until_ready(ef_server_op(g, e)[0])),
        ("ef_server", "jnp",
         lambda: jax.block_until_ready(ef_server_jnp(g, e))),
        ("pack2bit", "pallas",
         lambda: jax.block_until_ready(pack2bit_op(t))),
        ("uplink_fused", "pallas",
         lambda: jax.block_until_ready(sparsign_pack2bit_op(g, 1.0, 7))),
        ("uplink_two_pass", "pallas",
         lambda: jax.block_until_ready(pack2bit_op(sparsign_op(g, 1.0, 7)))),
        ("uplink_two_pass", "jnp",
         lambda: jax.block_until_ready(uplink_jnp(g))),
    ]
    # the generic ternary template's fused uplinks (noisy_sign sigma=0.01 as
    # Appendix B tunes it; terngrad against its local L-inf normalizer) — one
    # tuple drives both the timing cases and the int8-HBM assertions below
    s_t = float(np.max(np.abs(np.asarray(g))))
    ternary_uplinks = (("noisy_sign", "noisy_sign", 0.01),
                       ("terngrad", "stochastic_ternary", s_t))
    for label, rule, param in ternary_uplinks:
        cases += [
            (f"uplink_fused_{label}", "pallas",
             lambda rule=rule, param=param: jax.block_until_ready(
                 ternary_pack2bit_op(g, param, 7, rule=rule))),
            (f"uplink_two_pass_{label}", "pallas",
             lambda rule=rule, param=param: jax.block_until_ready(
                 pack2bit_op(ternary_compress_op(g, param, 7, rule=rule)))),
        ]
    # the entropy-coded golomb uplink (sparsign at ~5% realized density vs a
    # plan capacity of p=0.05): fused gradient->coded-bytes kernel vs the
    # two-pass compress-then-encode chain, plus the engine's all-jnp reference
    # (sparsign_ref + the format-defining reference coder)
    p_g, budget_g = 0.05, 0.06
    golomb_jnp = jax.jit(lambda x: golomb_encode_ref(
        sparsign_ref(x, budget_g, 7), p=p_g))
    cases += [
        ("uplink_fused_golomb", "pallas",
         lambda: jax.block_until_ready(
             sparsign_golomb_op(g, budget_g, 7, p=p_g))),
        ("uplink_two_pass_golomb", "pallas",
         lambda: jax.block_until_ready(
             golomb_pack_op(sparsign_op(g, budget_g, 7), p=p_g))),
        ("uplink_two_pass_golomb", "jnp",
         lambda: jax.block_until_ready(golomb_jnp(g))),
    ]
    # the 8-bit QSGD (pack8) uplink vs the decoded-psum chain it replaces
    # (1 B/coord wire payload vs 4 B/coord fp32); seed passed as uint32 like
    # the engine supplies it, so the no-int32 jaxpr pin below stays exact
    s8 = max(float(np.linalg.norm(np.asarray(g))), 1e-12) / 127.0
    seed8 = jnp.uint32(7)
    qsgd8_decoded_jnp = jax.jit(
        lambda x: qsgd8_levels_ref(x, s8, seed8).astype(jnp.float32)
        * jnp.float32(s8))
    cases += [
        ("uplink_fused_qsgd8", "pallas",
         lambda: jax.block_until_ready(qsgd8_pack8_op(g, s8, seed8))),
        ("uplink_decoded_psum_qsgd8", "pallas",
         lambda: jax.block_until_ready(
             qsgd8_op(g, s8, seed8).astype(jnp.float32) * jnp.float32(s8))),
        ("uplink_decoded_psum_qsgd8", "jnp",
         lambda: jax.block_until_ready(qsgd8_decoded_jnp(g))),
    ]
    # structural guarantee behind the fused uplinks' byte count: no int8
    # ternary tensor at the HBM level (the two-pass chains have one of >= n),
    # measured per backend on the exact chains timed above.  The "no
    # intermediate" side is the declarative NoHbmIntermediate rule from
    # repro.analysis (same rule CI's `python -m repro.analysis` gate runs per
    # CompressorSpec); the two-pass counts stay numeric for the JSON records.
    no_i8 = NoHbmIntermediate(jnp.int8)
    findings = no_i8.check(
        "uplink_fused", lambda x: sparsign_pack2bit_op(x, 1.0, 7), g)
    assert findings == [], "\n".join(f.render() for f in findings)
    two_pass_i8 = kcommon.int8_hbm_elems(
        lambda x: pack2bit_op(sparsign_op(x, 1.0, 7)), g)
    two_pass_jnp_i8 = kcommon.int8_hbm_elems(uplink_jnp, g)
    assert two_pass_i8 >= n and two_pass_jnp_i8 >= n
    int8_hbm = {("uplink_fused", "pallas"): 0,
                ("uplink_two_pass", "pallas"): two_pass_i8,
                ("uplink_two_pass", "jnp"): two_pass_jnp_i8}
    for label, rule, param in ternary_uplinks:
        findings = no_i8.check(
            f"uplink_fused_{label}",
            lambda x: ternary_pack2bit_op(x, param, 7, rule=rule), g)
        assert findings == [], "\n".join(f.render() for f in findings)
        t_i8 = kcommon.int8_hbm_elems(
            lambda x: pack2bit_op(ternary_compress_op(x, param, 7, rule=rule)), g)
        assert t_i8 >= n
        int8_hbm[(f"uplink_fused_{label}", "pallas")] = 0
        int8_hbm[(f"uplink_two_pass_{label}", "pallas")] = t_i8
    # golomb structural pin: the fused coded uplink never materializes the
    # int8 ternary tensor (both two-pass chains do, >= n elements) — and its
    # payload really is the sub-2-bit capacity buffer the ledger bills
    findings = no_i8.check(
        "uplink_fused_golomb",
        lambda x: sparsign_golomb_op(x, budget_g, 7, p=p_g), g)
    assert findings == [], "\n".join(f.render() for f in findings)
    gp_i8 = kcommon.int8_hbm_elems(
        lambda x: golomb_pack_op(sparsign_op(x, budget_g, 7), p=p_g), g)
    gj_i8 = kcommon.int8_hbm_elems(golomb_jnp, g)
    assert gp_i8 >= n and gj_i8 >= n
    assert sparsign_golomb_op(g, budget_g, 7, p=p_g).nbytes \
        == golomb_nbytes(n, p_g) < pack2bit_op(t).nbytes
    int8_hbm[("uplink_fused_golomb", "pallas")] = 0
    int8_hbm[("uplink_two_pass_golomb", "pallas")] = gp_i8
    int8_hbm[("uplink_two_pass_golomb", "jnp")] = gj_i8
    # pack8 structural pin: the fused qsgd8 uplink has no int32 level tensor
    # at the HBM level (limit=1 allows the to_2d pad's scatter-start index,
    # exactly qsgd8's declared hbm_limits); the decoded chain necessarily
    # re-reads its int8 levels for the f32 decode
    findings = NoHbmIntermediate(jnp.int32, limit=1).check(
        "uplink_fused_qsgd8", lambda x: qsgd8_pack8_op(x, s8, seed8), g)
    assert findings == [], "\n".join(f.render() for f in findings)
    f8_i32 = kcommon.int32_hbm_elems(lambda x: qsgd8_pack8_op(x, s8, seed8), g)
    d8_i8 = kcommon.int8_hbm_elems(
        lambda x: qsgd8_op(x, s8, seed8).astype(jnp.float32)
        * jnp.float32(s8), g)
    assert d8_i8 >= n
    int32_hbm = {("uplink_fused_qsgd8", "pallas"): f8_i32}
    int8_hbm[("uplink_decoded_psum_qsgd8", "pallas")] = d8_i8

    for kernel, backend, fn in cases:
        _, dt = timed(fn)
        label = pallas_label if backend == "pallas" else "jnp"
        rec = {
            "kernel": kernel,
            "shape": name,
            "dims": list(shape),
            "n_coords": n,
            "backend": label,
            "us_per_call": round(dt * 1e6, 1),
            "hbm_bytes_per_coord_tpu": BYTES_PER_COORD.get((kernel, backend)),
        }
        if (kernel, backend) in int8_hbm:
            rec["int8_hbm_intermediate_elems"] = int8_hbm[(kernel, backend)]
        if (kernel, backend) in int32_hbm:
            rec["int32_hbm_intermediate_elems"] = int32_hbm[(kernel, backend)]
        records.append(rec)
        csv_row([kernel, name, label, rec["us_per_call"],
                 rec["hbm_bytes_per_coord_tpu"]])


def main(fast: bool = False, out: Path | None = None):
    shapes = SHAPES_QUICK if fast else SHAPES_FULL
    on_tpu = jax.default_backend() == "tpu"
    pallas_label = "pallas" if on_tpu else "pallas-interpret"
    print(f"# kernel bench: jnp vs {pallas_label} "
          f"(jax backend={jax.default_backend()})")
    csv_header(["kernel", "shape", "backend", "us_per_call", "hbm_bytes_per_coord_tpu"])
    records: list[dict] = []
    for name, shape in shapes.items():
        _bench_shape(name, shape, records, pallas_label)

    doc = {
        "schema": 1,
        "bench": "kernels",
        "jax_backend": jax.default_backend(),
        "pallas_mode": "compiled" if on_tpu else "interpret",
        "jax_version": jax.__version__,
        "quick": fast,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "note": ("us_per_call on CPU times the interpret/reference paths; "
                 "hbm_bytes_per_coord_tpu is the structural TPU traffic model "
                 "behind the roofline term."),
        "results": records,
    }
    # quick runs get their own default path so a CI-smoke invocation can't
    # silently clobber the committed full-shape baseline
    out = out or (QUICK_OUT_PATH if fast else OUT_PATH)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    main(fast=args.quick, out=args.out)
