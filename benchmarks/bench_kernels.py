"""Compression-kernel microbench: us/call (CPU interpret mode — correctness
path; TPU lowering is the target) + the structural byte accounting that drives
the roofline memory term for the compression stage."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_header, csv_row, timed
from repro.core.compressors import sparsign
from repro.kernels.ef_server.ops import ef_server_op
from repro.kernels.pack2bit.ops import pack2bit_op
from repro.kernels.sparsign.ops import sparsign_op
from repro.kernels.vote_update.ops import vote_update_op


def main(fast: bool = False):
    n = 1 << 18 if fast else 1 << 20
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(n), jnp.float32)
    w = jnp.asarray(rng.randn(n), jnp.float32)
    t = jnp.asarray(rng.randint(-1, 2, n), jnp.int8)
    v = jnp.asarray(rng.randint(-16, 17, n), jnp.int32)
    e = jnp.asarray(rng.randn(n), jnp.float32)

    print(f"# kernel microbench, n={n} coords (CPU interpret mode)")
    csv_header(["name", "us_per_call", "hbm_bytes_per_coord_tpu", "note"])

    _, dt = timed(lambda: jax.block_until_ready(sparsign_op(g, 1.0, 7)))
    csv_row(["sparsign_kernel", f"{dt*1e6:.0f}", 4 + 1, "read f32 + write i8; RNG in-register"])
    _, dt = timed(lambda: jax.block_until_ready(sparsign(g, budget=1.0, seed=7).values))
    csv_row(["sparsign_jnp_ref", f"{dt*1e6:.0f}", 4 + 4 + 4 + 1, "extra u32 idx + f32 uniform traffic"])
    _, dt = timed(lambda: jax.block_until_ready(pack2bit_op(t)))
    csv_row(["pack2bit", f"{dt*1e6:.0f}", 1 + 0.25, "i8 -> 2-bit wire"])
    _, dt = timed(lambda: jax.block_until_ready(ef_server_op(g, e)[0]))
    csv_row(["ef_server_fused", f"{dt*1e6:.0f}", 8 + 8, "2 reads + 2 writes f32 (vs 4-pass unfused)"])
    _, dt = timed(lambda: jax.block_until_ready(vote_update_op(w, v, 0.01)))
    csv_row(["vote_update_fused", f"{dt*1e6:.0f}", 4 + 4 + 4, "w + votes -> w' one pass"])


if __name__ == "__main__":
    main()
