"""Paper Table 2: CIFAR-10 (alpha=0.5), 20% worker participation, CNN.

Reduced-width VGG-style CNN on 32x32x3 synthetic data (CPU budget);
participation 0.2 exactly as the paper's CIFAR-10 protocol.
"""

from __future__ import annotations

import jax

from benchmarks.common import ALGORITHMS, csv_header, csv_row
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import ImageDataConfig, make_image_dataset
from repro.fl.models import cnn_cifar
from repro.fl.simulation import FLConfig, run_fl, stack_partitions

SUBSET = ["signSGD", "noisy_signSGD", "terngrad", "sparsignSGD_B1", "ef_sparsignSGD"]


def main(fast: bool = False, target: float = 0.55):
    n_workers = 20
    rounds = 30 if fast else 120
    x, y, xt, yt = make_image_dataset(ImageDataConfig(
        n_classes=10, shape=(32, 32, 3), n_train=2000 if fast else 6000,
        n_test=500, noise=1.0, seed=1))
    parts = dirichlet_partition(y, n_workers=n_workers, alpha=0.5, seed=1)
    xp, yp = stack_partitions(x, y, parts)
    v0, apply_fn = cnn_cifar(jax.random.PRNGKey(1))

    algos = SUBSET if fast else list(ALGORITHMS)
    print(f"# Table 2 analog: cifar-like synthetic, alpha=0.5, 20% participation, "
          f"M={n_workers}, {rounds} rounds")
    csv_header(["algorithm", "final_acc", "rounds_to_target", "uplink_bits_to_target"])
    for name in algos:
        comp = ALGORITHMS[name]
        cfg = FLConfig(n_workers=n_workers, rounds=rounds, participation=0.2,
                       batch_size=32, lr=0.03, comp=comp, seed=1, eval_every=5)
        res = run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)
        hit = next((r for r, a in res["acc"] if a >= target), None)
        bits = res["uplink_bits_per_round"] * 0.2 / 1.0 * hit if hit else None
        csv_row([name, f"{res['final_acc']:.4f}", hit if hit else "N.A.",
                 f"{bits:.3e}" if bits else "N.A."])


if __name__ == "__main__":
    main()
