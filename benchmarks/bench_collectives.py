"""Wire-byte accounting for one training round: the paper's communication claim
on TPU terms. First-principles per-device bytes for every exchange variant, per
architecture — the numbers the collective roofline term is built from, and the
before/after ledger for §Perf.

Exchange granularity is per TRAINER MODE: the simple trainer exchanges each
(stacked) leaf once at full size, but the streamed trainer exchanges every
block leaf once PER SUPERBLOCK at its per-layer size — n_repeats exchanges,
each paying its own canonical-view padding. The ledger columns bill the real
granularity (``exchange_sizes``); billing a streamed stack as one exchange
understates the padding tax by up to n_repeats x.

Two packed-wire columns: ``packed_model`` is the closed-form d/4-per-worker
model; ``packed_real`` is the *actual* ledger from the VoteWire implementation
(``collectives.PackedVoteWire.wire_bytes`` summed over the real per-exchange
sizes), which ships padded canonical views — the delta is the padding tax the
idealized model hides. ``bucketed_real`` is the bucketized-uplink twin
(``repro.dist.bucketing`` plans): one collective per bucket, padding amortized
per bucket, launch counts collapsed (the ``launch_ratio`` column).

Ring columns (``mono_peak_hbm`` / ``ring_peak_hbm`` / ``ring_launches``) cost
the ring-pipelined gather at the production chunk size: peak gathered-payload
residency of the monolithic all_gather (M x payload) vs the chunked ppermute
ring (send + recv chunk, O(1) in M), plus the ring's launch count (one
(M-1)-hop ring per chunk). A third traced census (``ring_census_bytes``)
asserts the ring program bills the SAME fabric bytes as the monolithic ledger.

Elastic-participation columns (``elastic_real`` / ``weight_side`` /
``weight_tax``): the weighted vote's packed gather ships the same payload plus
one (1,) f32 participation weight per peer per exchange — weight_side =
launches x (M-1) x 4 B, asserted to be EXACTLY the elastic-vs-legacy ledger
delta. The step-time section adds ``elastic_full`` (weighted exchange, full
participation) and ``elastic_mask50`` (50% per-round report dropout — masked
payloads are exact zeros but every byte still rides the fixed-shape wire).

The step-time section times real train steps (per-leaf vs bucketed wire, both
trainers, plus ``ring_*`` chunked-ppermute configs) on forced host devices and
writes the tracked ``BENCH_collectives.json`` at the repo root (``--quick``
writes ``BENCH_collectives.quick.json`` — the CI smoke artifact — so it can't
clobber the baseline).

  python -m benchmarks.bench_collectives            # full table + step times
  python -m benchmarks.bench_collectives --quick    # CI smoke
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time
from collections import Counter
from pathlib import Path

# before any jax backend init: the step-time section wants real host devices
# (harmless if another module initialized jax first — the section falls back)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from benchmarks.common import csv_header, csv_row, timed
from repro.configs.registry import ARCH_IDS, get_config, trainer_mode

ROOT = Path(__file__).resolve().parents[1]
OUT_PATH = ROOT / "BENCH_collectives.json"            # tracked baseline
QUICK_OUT_PATH = ROOT / "BENCH_collectives.quick.json"  # CI smoke; never tracked


# ---------------------------------------------------------------------------
# per-trainer-mode exchange granularity
# ---------------------------------------------------------------------------

def exchange_sizes(cfg, trainer: str) -> Counter:
    """{exchange_coords: launches_per_round} at the trainer's REAL uplink
    granularity. simple: one exchange per stacked leaf. streamed: one exchange
    per block leaf PER SUPERBLOCK (n_repeats launches at per-layer size — the
    scan re-exchanges each layer slice), outer leaves once."""
    import jax

    from repro.models.model import Model

    shapes = Model(cfg).param_shapes()
    sizes: Counter = Counter()
    if trainer == "simple":
        for s in jax.tree_util.tree_leaves(shapes):
            sizes[int(math.prod(s.shape))] += 1
        return sizes
    for s in jax.tree_util.tree_leaves(shapes["blocks"]):
        sizes[int(math.prod(s.shape[1:]))] += cfg.n_repeats
    for k in shapes:
        if k == "blocks":
            continue
        for s in jax.tree_util.tree_leaves(shapes[k]):
            sizes[int(math.prod(s.shape))] += 1
    return sizes


def packed_real_bytes(cfg, trainer: str, n_data: int = 16, n_pod: int = 1) -> float:
    """Per-device bytes of the real allgather_packed wire for one round:
    (M-1) x padded 2-bit payload, summed over the trainer's real exchanges."""
    from repro.dist.collectives import PackedVoteWire

    wire = PackedVoteWire(axes=("data",), n_workers=n_data * n_pod)
    return sum(count * wire.wire_bytes(n)
               for n, count in exchange_sizes(cfg, trainer).items())


def elastic_packed_bytes(cfg, trainer: str, n_data: int = 16,
                         n_pod: int = 1) -> tuple[float, float]:
    """(elastic_total, weight_side) per-device bytes of the elastic packed
    wire for one round: the payload is unchanged, but every exchange also
    gathers each peer's (1,) f32 participation weight — the side channel the
    weighted vote normalizes by. weight_side = launches x (M-1) x 4 B."""
    from repro.dist.collectives import ParticipationSpec, PackedVoteWire

    wire = PackedVoteWire(axes=("data",), n_workers=n_data * n_pod,
                          participation=ParticipationSpec(q_frac=0.5))
    total = weight = 0.0
    for n, count in exchange_sizes(cfg, trainer).items():
        total += count * (wire.wire_bytes(n)
                          + wire.weight_bytes() * wire.ring_chunks(n))
        weight += count * wire.weight_bytes() * wire.ring_chunks(n)
    return total, weight


def packed_census_bytes(cfg, trainer: str, n_data: int = 16, n_pod: int = 1) -> float:
    """Traced-jaxpr cross-check of the ``packed_real`` ledger column: run the
    repro.analysis CollectiveCensus over the actual PackedVoteWire exchange
    program (one trace per distinct exchange size), ring-costed at the same M.
    Equals packed_real_bytes unless the wire implementation and the ledger
    drift apart — which is exactly what the column is for."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import collective_census
    from repro.dist import compat
    from repro.dist.collectives import PackedVoteWire
    from repro.kernels import common as kcommon
    from repro.launch.mesh import make_host_mesh

    m = n_data * n_pod
    wire = PackedVoteWire(axes=("data",), n_workers=m, backend="interpret")
    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    total = 0.0
    for n, count in exchange_sizes(cfg, trainer).items():
        packed = jax.ShapeDtypeStruct(
            (kcommon.canonical_rows(n), kcommon.LANES // 4), jnp.uint8)
        fn = compat.shard_map(lambda p, n=n: wire.exchange(p, n, (n,)),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False)
        census = collective_census(jax.make_jaxpr(fn)(packed))
        total += census.total_bytes({"data": m}) * count
    return total


# ---------------------------------------------------------------------------
# bucketized uplink: bytes + launch counts
# ---------------------------------------------------------------------------

def _bucket_plans(cfg, trainer: str, wire):
    """(plans, launches) — the BucketPlans one bucketed round applies and the
    payload-launch count they cost (streamed block plans ride n_repeats + 1
    times: the double-buffered scan's prime/drain)."""
    import jax

    from repro.dist import bucketing
    from repro.models.model import Model

    fmt = wire.native_format
    shapes = Model(cfg).param_shapes()
    if trainer == "simple":
        plan = bucketing.build_bucket_plan(
            jax.tree_util.tree_leaves(shapes), fmt)
        return {"plan": plan}, len(plan.buckets)
    block_plan = bucketing.build_bucket_plan(
        [jax.ShapeDtypeStruct(s.shape[1:], s.dtype)
         for s in jax.tree_util.tree_leaves(shapes["blocks"])], fmt)
    outer_plan = bucketing.build_bucket_plan(
        [s for k in shapes if k != "blocks"
         for s in jax.tree_util.tree_leaves(shapes[k])], fmt)
    launches = ((cfg.n_repeats + 1) * len(block_plan.buckets)
                + len(outer_plan.buckets))
    return {"block": block_plan, "outer": outer_plan}, launches


def bucketed_real_bytes(cfg, trainer: str, n_data: int = 16,
                        n_pod: int = 1) -> float:
    """Per-device bytes of the bucketized packed wire for one round — the
    ``bucketing.plan_ledger`` twin of ``packed_real_bytes``."""
    from repro.dist import bucketing
    from repro.dist.collectives import PackedVoteWire

    wire = PackedVoteWire(axes=("data",), n_workers=n_data * n_pod)
    plans, _ = _bucket_plans(cfg, trainer, wire)
    if trainer == "simple":
        pay, scal = bucketing.plan_ledger("votes", wire, plans["plan"])
        return pay + scal
    pay, scal = bucketing.streamed_plan_ledger(
        "votes", wire, plans["block"], plans["outer"], cfg.n_repeats)
    return pay + scal


def bucketed_census_bytes(cfg, trainer: str, n_data: int = 16,
                          n_pod: int = 1) -> float:
    """Traced cross-check of ``bucketed_real_bytes``: census the actual
    ``exchange_bucket`` program per distinct bucket, ring-costed at M."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import collective_census
    from repro.dist import bucketing, compat
    from repro.dist.collectives import PackedVoteWire
    from repro.launch.mesh import make_host_mesh

    m = n_data * n_pod
    wire = PackedVoteWire(axes=("data",), n_workers=m, backend="interpret")
    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    plans, _ = _bucket_plans(cfg, trainer, wire)
    if trainer == "simple":
        reps = [(plans["plan"], 1)]
    else:
        reps = [(plans["block"], cfg.n_repeats + 1), (plans["outer"], 1)]
    total = 0.0
    for plan, trips in reps:
        for b in plan.buckets:
            buf = jax.ShapeDtypeStruct(
                (b.rows, bucketing.ROW_WIDTH[plan.fmt]),
                bucketing.ROW_DTYPE[plan.fmt])
            fn = compat.shard_map(
                lambda p, b=b: wire.exchange_bucket(p, b),
                mesh=mesh, in_specs=P(), out_specs=[P()] * len(b.slots),
                check_vma=False)
            census = collective_census(jax.make_jaxpr(fn)(buf))
            total += census.total_bytes({"data": m}) * trips
    return total


def launch_counts(cfg, trainer: str, n_data: int = 16, n_pod: int = 1):
    """(per_leaf_launches, bucketed_launches) payload collectives per round."""
    from repro.dist.collectives import PackedVoteWire

    per_leaf = sum(exchange_sizes(cfg, trainer).values())
    wire = PackedVoteWire(axes=("data",), n_workers=n_data * n_pod)
    _, bucketed = _bucket_plans(cfg, trainer, wire)
    return per_leaf, bucketed


# ---------------------------------------------------------------------------
# ring-pipelined gather: peak payload residency + hop counts
# ---------------------------------------------------------------------------

def ring_stats(cfg, trainer: str, n_data: int = 16, n_pod: int = 1) -> dict:
    """Ring-gather columns at the documented production chunk size
    (``collectives.DEFAULT_RING_CHUNK_ROWS``): peak gathered-payload HBM of
    the monolithic all_gather (M x the largest exchange payload) vs the ring
    (send + recv chunk only), and the ring's payload launch count — one
    (M-1)-hop ppermute ring per chunk, where the monolithic wire launches one
    all_gather per exchange."""
    from repro.dist.collectives import DEFAULT_RING_CHUNK_ROWS, PackedVoteWire

    m = n_data * n_pod
    mono = PackedVoteWire(axes=("data",), n_workers=m)
    ring = PackedVoteWire(axes=("data",), n_workers=m,
                          ring_chunk_rows=DEFAULT_RING_CHUNK_ROWS)
    sizes = exchange_sizes(cfg, trainer)
    mono_hbm = max(mono.gather_hbm_bytes(n) for n in sizes)
    ring_hbm = max(ring.gather_hbm_bytes(n) for n in sizes)
    launches = sum(count * ring.ring_chunks(n) for n, count in sizes.items())
    return {"mono_peak_hbm": mono_hbm, "ring_peak_hbm": ring_hbm,
            "hbm_ratio": mono_hbm / ring_hbm,
            "ring_launches": launches, "ring_hops": launches * (m - 1)}


def ring_census_bytes(cfg, trainer: str, n_data: int = 16,
                      n_pod: int = 1) -> float:
    """Traced cross-check of the RING wire against the SAME ``packed_real``
    ledger: census the chunked-ppermute exchange program per distinct
    exchange size. The ring moves exactly the bytes the monolithic gather
    moves — (M-1) x payload, chunk by chunk — it just never holds them all,
    so this must equal ``packed_real_bytes`` to the byte. The chunk size is
    picked per exchange to give a genuinely multi-chunk (~3 chunk) program
    while keeping the trace small; byte-invariance holds for any chunk size."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import collective_census
    from repro.dist import compat
    from repro.dist.collectives import PackedVoteWire
    from repro.kernels import common as kcommon
    from repro.launch.mesh import make_host_mesh

    m = n_data * n_pod
    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    total = 0.0
    for n, count in exchange_sizes(cfg, trainer).items():
        rows = kcommon.canonical_rows(n)
        chunk = max(32, math.ceil(rows / 3 / 32) * 32)
        wire = PackedVoteWire(axes=("data",), n_workers=m,
                              backend="interpret", ring_chunk_rows=chunk)
        packed = jax.ShapeDtypeStruct((rows, kcommon.LANES // 4), jnp.uint8)
        fn = compat.shard_map(lambda p, n=n, w=wire: w.exchange(p, n, (n,)),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False)
        census = collective_census(jax.make_jaxpr(fn)(packed))
        total += census.total_bytes({"data": m}) * count
    return total


# ---------------------------------------------------------------------------
# closed-form byte models
# ---------------------------------------------------------------------------

def wire_model(n_params: int, mode: str, n_data: int = 16, n_pod: int = 1,
               variant: str = "sparsign_int8") -> dict:
    """Per-device wire bytes for one round's gradient exchange (+FSDP traffic).

    ring all-reduce:    2*(M-1)/M * payload
    ring all-gather:    (M-1)/M * payload
    """
    m = n_data * n_pod
    ar = lambda b: 2 * (m - 1) / m * b
    ag_data = lambda b: (n_data - 1) / n_data * b
    grad_exchange = {
        "fp32_dp": ar(4 * n_params),                   # uncompressed baseline
        "bf16_dp": ar(2 * n_params),
        "sparsign_int8": ar(1 * n_params),             # ternary votes, int8 wire
        "sparsign_int8_hier": 2 * (n_data - 1) / n_data * n_params
                               + (2 * (n_pod - 1) / max(n_pod, 1)) * 2 * n_params,
        "sparsign_packed_allgather": (m - 1) * (n_params / 4.0),  # 2-bit, no reduce
    }[variant]
    fsdp = ag_data(2 * n_params) if mode == "streamed" else 0.0  # bf16 param gather
    return {"grad_exchange": grad_exchange, "fsdp_gather": fsdp,
            "total": grad_exchange + fsdp}


# ---------------------------------------------------------------------------
# step-level wire time: per-leaf vs bucketed, both trainers
# ---------------------------------------------------------------------------

def _time_simple_steps(modes, records, repeats: int):
    import jax

    from repro.analysis import drivers
    from repro.dist import compat

    for mode in modes:
        for bucketed in (False, True):
            step, state, batch, model, mesh, _ = drivers.build_mode_step(
                mode, bucketed=bucketed)
            with compat.set_mesh(mesh):
                (_, metrics), dt = timed(
                    lambda: jax.block_until_ready(step(state, batch)),
                    repeats=repeats)
            records.append({
                "case": f"step_simple/{mode}/{'bucketed' if bucketed else 'per_leaf'}",
                "trainer": "simple", "wire_mode": mode, "bucketed": bucketed,
                "ms_per_step": dt * 1e3,
                "wire_bytes_per_device": float(metrics["wire_bytes_per_device"]),
                "gather_hbm_bytes": float(metrics["gather_hbm_bytes"]),
            })
            csv_row([records[-1]["case"], f"{dt*1e3:.2f}",
                     f"{records[-1]['wire_bytes_per_device']:.0f}",
                     f"{records[-1]['gather_hbm_bytes']:.0f}"])


def _time_elastic_steps(records, repeats: int):
    """Elastic-participation timing rows on the votes wire: the weighted
    exchange at full participation, and the chaos configuration (50%%
    per-round report dropout) where half the fleet's payloads are masked to
    exact zeros but — SPMD ships fixed shapes — every byte still rides."""
    import jax

    from repro.analysis import drivers
    from repro.dist import compat
    from repro.dist.collectives import ParticipationSpec

    for tag, part in (
            ("elastic_full", drivers.participation_spec()),
            ("elastic_mask50", ParticipationSpec(q_frac=0.5, dropout=0.5))):
        step, state, batch, model, mesh, _ = drivers.build_mode_step(
            "votes", participation=part)
        with compat.set_mesh(mesh):
            (_, metrics), dt = timed(
                lambda: jax.block_until_ready(step(state, batch)),
                repeats=repeats)
        records.append({
            "case": f"step_simple/votes/{tag}",
            "trainer": "simple", "wire_mode": "votes", "bucketed": False,
            "ms_per_step": dt * 1e3,
            "wire_bytes_per_device": float(metrics["wire_bytes_per_device"]),
            "gather_hbm_bytes": float(metrics["gather_hbm_bytes"]),
            "participated": float(metrics["participated"]),
        })
        csv_row([records[-1]["case"], f"{dt*1e3:.2f}",
                 f"{records[-1]['wire_bytes_per_device']:.0f}",
                 f"{records[-1]['gather_hbm_bytes']:.0f}"])


def _time_streamed_steps(modes, records, repeats: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import drivers
    from repro.core.algorithm import CompressionConfig
    from repro.core.budgets import BudgetConfig
    from repro.dist import compat
    from repro.models.model import Model
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_streamed import (StreamedStepConfig,
                                           build_streamed_train_step,
                                           fsdp_param_shardings)

    n_dev = jax.device_count()
    if n_dev < 2:
        print("# streamed step timing skipped: needs >= 2 devices "
              f"(have {n_dev})")
        return
    data = 4 if n_dev >= 8 else 2
    mesh = compat.make_mesh((data, n_dev // data), ("data", "model"))
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shardings = fsdp_param_shardings(model, mesh, "data")
    params = jax.tree_util.tree_map(jax.device_put, params, shardings)
    rng = np.random.RandomState(0)
    b, s = 8, 16
    batch = {
        "inputs": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }
    lr = LrSchedule(base=0.01)
    for mode in modes:
        comp_name, server, vote_impl, value = drivers._setup_of(mode)
        kind = "target_sparsity" if mode.endswith("golomb") else "fixed"
        comp = CompressionConfig(compressor=comp_name,
                                 budget=BudgetConfig(kind=kind, value=value),
                                 server=server)
        ring_rows = (drivers.RING_SWEEP_CHUNK_ROWS
                     if mode in drivers.RING_SETUPS else None)
        for bucketed in (False, True):
            step = build_streamed_train_step(model, StreamedStepConfig(
                compression=comp, lr=lr, worker_axes=("data",),
                fsdp_axis="data", vote_impl=vote_impl, donate=False,
                backend="jnp", bucketed=bucketed,
                ring_chunk_rows=ring_rows), mesh)
            state = init_state(params, server=server, seed=42)
            with compat.set_mesh(mesh):
                (_, metrics), dt = timed(
                    lambda: jax.block_until_ready(step(state, batch)),
                    repeats=repeats)
            records.append({
                "case": f"step_streamed/{mode}/"
                        f"{'double_buffered' if bucketed else 'per_leaf'}",
                "trainer": "streamed", "wire_mode": mode, "bucketed": bucketed,
                "ms_per_step": dt * 1e3,
                "wire_bytes_per_device": float(metrics["wire_bytes_per_device"]),
                "gather_hbm_bytes": float(metrics["gather_hbm_bytes"]),
            })
            csv_row([records[-1]["case"], f"{dt*1e3:.2f}",
                     f"{records[-1]['wire_bytes_per_device']:.0f}",
                     f"{records[-1]['gather_hbm_bytes']:.0f}"])


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main(fast: bool = False, out: Path | None = None):
    import jax

    print("# per-device wire bytes per round, by exchange variant (single pod, 16 data)")
    csv_header(["arch", "mode", "params_B", "fp32_dp", "sparsign_int8",
                "vs_fp32", "fsdp_gather", "hier_2pod", "packed_model",
                "packed_real", "packed_census", "pad_tax", "bucketed_real",
                "bucket_pad_tax", "launches", "launches_bucketed",
                "launch_ratio", "mono_peak_hbm", "ring_peak_hbm",
                "hbm_ratio", "ring_launches", "elastic_real",
                "weight_side", "weight_tax"])
    table = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        mode = trainer_mode(arch)
        base = wire_model(n, mode, variant="fp32_dp")
        ours = wire_model(n, mode, variant="sparsign_int8")
        hier = wire_model(n, mode, n_pod=2, variant="sparsign_int8_hier")
        packed = wire_model(n, mode, variant="sparsign_packed_allgather")
        real = packed_real_bytes(cfg, mode)
        census = packed_census_bytes(cfg, mode)
        assert census == real, (
            f"{arch}: traced census {census:.6g} != ledger {real:.6g}")
        breal = bucketed_real_bytes(cfg, mode)
        bcensus = bucketed_census_bytes(cfg, mode)
        assert bcensus == breal, (
            f"{arch}: bucketed census {bcensus:.6g} != ledger {breal:.6g}")
        # the ring wire moves the SAME bytes over the fabric — assert its
        # traced census against the monolithic ledger, to the byte
        rcensus = ring_census_bytes(cfg, mode)
        assert rcensus == real, (
            f"{arch}: ring census {rcensus:.6g} != ledger {real:.6g}")
        per_leaf, bucketed = launch_counts(cfg, mode)
        ratio = per_leaf / max(bucketed, 1)
        rs = ring_stats(cfg, mode)
        ereal, wside = elastic_packed_bytes(cfg, mode)
        assert ereal == real + wside, (
            f"{arch}: elastic packed wire must be payload + weight side "
            f"channel exactly, got {ereal:.6g} vs {real + wside:.6g}")
        csv_row([arch, mode, f"{n/1e9:.2f}e9",
                 f"{base['grad_exchange']:.3e}", f"{ours['grad_exchange']:.3e}",
                 f"{base['grad_exchange']/ours['grad_exchange']:.1f}x",
                 f"{ours['fsdp_gather']:.3e}", f"{hier['grad_exchange']:.3e}",
                 f"{packed['grad_exchange']:.3e}", f"{real:.3e}",
                 f"{census:.3e}",
                 f"{real / packed['grad_exchange'] - 1:+.1%}",
                 f"{breal:.3e}",
                 f"{breal / packed['grad_exchange'] - 1:+.1%}",
                 per_leaf, bucketed, f"{ratio:.1f}x",
                 f"{rs['mono_peak_hbm']:.3e}", f"{rs['ring_peak_hbm']:.3e}",
                 f"{rs['hbm_ratio']:.1f}x", rs["ring_launches"],
                 f"{ereal:.3e}", f"{wside:.3e}",
                 f"{wside / real:+.2%}"])
        table.append({
            "arch": arch, "trainer": mode, "params": n,
            "packed_real_bytes": real, "bucketed_real_bytes": breal,
            "launches_per_leaf": per_leaf, "launches_bucketed": bucketed,
            "launch_ratio": ratio,
            "mono_peak_hbm_bytes": rs["mono_peak_hbm"],
            "ring_peak_hbm_bytes": rs["ring_peak_hbm"],
            "gather_hbm_ratio": rs["hbm_ratio"],
            "ring_launches": rs["ring_launches"],
            "ring_hops": rs["ring_hops"],
            "elastic_real_bytes": ereal,
            "weight_side_bytes": wside,
        })

    print("\n# step time: per-leaf vs bucketed wire "
          f"(jax backend={jax.default_backend()}, {jax.device_count()} devices)")
    csv_header(["case", "ms_per_step", "wire_bytes_per_device",
                "gather_hbm_bytes"])
    modes = (("votes", "ring_pack2") if fast
             else ("votes", "scaled_votes", "pack8", "decoded",
                   "ring_pack2", "ring_pack8"))
    repeats = 2 if fast else 3
    records: list[dict] = []
    _time_simple_steps(modes, records, repeats)
    _time_elastic_steps(records, repeats)
    _time_streamed_steps(modes, records, repeats)

    doc = {
        "schema": 1,
        "bench": "collectives",
        "jax_backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "jax_version": jax.__version__,
        "quick": fast,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "note": ("ledger table bills the trainer's REAL exchange granularity "
                 "(streamed: n_repeats per-layer exchanges per block leaf); "
                 "step times compare the per-leaf wire against the bucketed "
                 "(simple) / double-buffered (streamed) wire on host devices "
                 "— launch-count savings, not fabric bandwidth. Ring columns "
                 "are at collectives.DEFAULT_RING_CHUNK_ROWS: the ring moves "
                 "the same fabric bytes as the monolithic gather (asserted "
                 "via the traced ring census) but holds only ~2 chunks of "
                 "payload instead of M exchanges' worth; ring_* step-time "
                 "rows run the chunked ppermute wire and report its "
                 "gather_hbm_bytes metric. elastic_real/weight_side columns "
                 "bill the weighted exchange's (M-1)x4B-per-launch f32 weight "
                 "side channel; elastic_* step rows time the weighted vote at "
                 "full participation and under 50% report dropout."),
        "ledger": table,
        "results": records,
    }
    out = out or (QUICK_OUT_PATH if fast else OUT_PATH)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke subset")
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()
    main(fast=args.quick, out=args.out)
