"""Wire-byte accounting for one training round: the paper's communication claim
on TPU terms. First-principles per-device bytes for every exchange variant, per
architecture — the numbers the collective roofline term is built from, and the
before/after ledger for §Perf.

Two packed-wire columns: ``sparsign_packed_allgather`` is the closed-form
d/4-per-worker model; ``packed_real`` is the *actual* ledger from the VoteWire
implementation (``collectives.PackedVoteWire.wire_bytes`` summed over the real
per-leaf shapes), which ships padded canonical views — the delta is the
padding tax the idealized model hides."""

from __future__ import annotations

from benchmarks.common import csv_header, csv_row
from repro.configs.registry import ARCH_IDS, get_config, trainer_mode


def packed_real_bytes(cfg, n_data: int = 16, n_pod: int = 1) -> float:
    """Per-device bytes of the real allgather_packed wire for one round:
    (M-1) x sum over gradient leaves of the padded 2-bit payload."""
    import math

    import jax

    from repro.dist.collectives import PackedVoteWire
    from repro.models.model import Model

    wire = PackedVoteWire(axes=("data",), n_workers=n_data * n_pod)
    shapes = Model(cfg).param_shapes()
    return sum(wire.wire_bytes(math.prod(s.shape))
               for s in jax.tree_util.tree_leaves(shapes))


def packed_census_bytes(cfg, n_data: int = 16, n_pod: int = 1) -> float:
    """Traced-jaxpr cross-check of the ``packed_real`` ledger column: run the
    repro.analysis CollectiveCensus over the actual PackedVoteWire exchange
    program (one trace per distinct leaf size), ring-costed at the same M.
    Equals packed_real_bytes unless the wire implementation and the ledger
    drift apart — which is exactly what the column is for."""
    import math
    from collections import Counter

    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import collective_census
    from repro.dist import compat
    from repro.dist.collectives import PackedVoteWire
    from repro.kernels import common as kcommon
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model

    m = n_data * n_pod
    wire = PackedVoteWire(axes=("data",), n_workers=m, backend="interpret")
    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    sizes = Counter(int(math.prod(s.shape))
                    for s in jax.tree_util.tree_leaves(Model(cfg).param_shapes()))
    total = 0.0
    for n, count in sizes.items():
        packed = jax.ShapeDtypeStruct(
            (kcommon.canonical_rows(n), kcommon.LANES // 4), jnp.uint8)
        fn = compat.shard_map(lambda p, n=n: wire.exchange(p, n, (n,)),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False)
        census = collective_census(jax.make_jaxpr(fn)(packed))
        total += census.total_bytes({"data": m}) * count
    return total


def wire_model(n_params: int, mode: str, n_data: int = 16, n_pod: int = 1,
               variant: str = "sparsign_int8") -> dict:
    """Per-device wire bytes for one round's gradient exchange (+FSDP traffic).

    ring all-reduce:    2*(M-1)/M * payload
    ring all-gather:    (M-1)/M * payload
    """
    m = n_data * n_pod
    ar = lambda b: 2 * (m - 1) / m * b
    ag_data = lambda b: (n_data - 1) / n_data * b
    grad_exchange = {
        "fp32_dp": ar(4 * n_params),                   # uncompressed baseline
        "bf16_dp": ar(2 * n_params),
        "sparsign_int8": ar(1 * n_params),             # ternary votes, int8 wire
        "sparsign_int8_hier": 2 * (n_data - 1) / n_data * n_params
                               + (2 * (n_pod - 1) / max(n_pod, 1)) * 2 * n_params,
        "sparsign_packed_allgather": (m - 1) * (n_params / 4.0),  # 2-bit, no reduce
    }[variant]
    fsdp = ag_data(2 * n_params) if mode == "streamed" else 0.0  # bf16 param gather
    return {"grad_exchange": grad_exchange, "fsdp_gather": fsdp,
            "total": grad_exchange + fsdp}


def main(fast: bool = False):
    print("# per-device wire bytes per round, by exchange variant (single pod, 16 data)")
    csv_header(["arch", "mode", "params_B", "fp32_dp", "sparsign_int8",
                "vs_fp32", "fsdp_gather", "hier_2pod", "packed_model",
                "packed_real", "packed_census", "pad_tax"])
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        mode = trainer_mode(arch)
        base = wire_model(n, mode, variant="fp32_dp")
        ours = wire_model(n, mode, variant="sparsign_int8")
        hier = wire_model(n, mode, n_pod=2, variant="sparsign_int8_hier")
        packed = wire_model(n, mode, variant="sparsign_packed_allgather")
        real = packed_real_bytes(cfg)
        census = packed_census_bytes(cfg)
        assert census == real, (
            f"{arch}: traced census {census:.6g} != ledger {real:.6g}")
        csv_row([arch, mode, f"{n/1e9:.2f}e9",
                 f"{base['grad_exchange']:.3e}", f"{ours['grad_exchange']:.3e}",
                 f"{base['grad_exchange']/ours['grad_exchange']:.1f}x",
                 f"{ours['fsdp_gather']:.3e}", f"{hier['grad_exchange']:.3e}",
                 f"{packed['grad_exchange']:.3e}", f"{real:.3e}",
                 f"{census:.3e}",
                 f"{real / packed['grad_exchange'] - 1:+.1%}"])


if __name__ == "__main__":
    main()
