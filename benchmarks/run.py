"""Benchmark orchestrator: one module per paper table/figure.

``python -m benchmarks.run``        — fast defaults (CPU-budget)
``python -m benchmarks.run --full`` — paper-scale rounds
``python -m benchmarks.run --only table1`` — single bench
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (bench_collectives, bench_golomb_bits, bench_kernels,
                        bench_roofline, bench_rosenbrock, bench_table1_fashion,
                        bench_table2_cifar, bench_table3_local_steps)

BENCHES = {
    "rosenbrock": bench_rosenbrock.main,       # Figs 1-2
    "table1": bench_table1_fashion.main,       # Table 1
    "table2": bench_table2_cifar.main,         # Table 2
    "table3": bench_table3_local_steps.main,   # Table 3 (+ alpha sweep of 4-7)
    "golomb": bench_golomb_bits.main,          # Eq. 12
    "kernels": bench_kernels.main,             # compression kernels
    "collectives": bench_collectives.main,     # wire-byte ledger
    "roofline": bench_roofline.main,           # dry-run roofline table
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args(argv)
    names = [args.only] if args.only else list(BENCHES)
    for name in names:
        print(f"\n##### bench: {name} #####")
        t0 = time.time()
        BENCHES[name](fast=not args.full)
        print(f"##### {name} done in {time.time()-t0:.1f}s #####")


if __name__ == "__main__":
    main()
