"""Shared benchmark plumbing: CSV emission + the standard algorithm grid."""

from __future__ import annotations

import sys
import time

from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig


def emit(row: dict, file=sys.stdout):
    print(",".join(f"{k}={v}" for k, v in row.items()), file=file, flush=True)


def csv_header(cols, file=sys.stdout):
    print(",".join(cols), file=file, flush=True)


def csv_row(vals, file=sys.stdout):
    print(",".join(str(v) for v in vals), file=file, flush=True)


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


# The paper's §6 algorithm grid (Tables 1-2)
ALGORITHMS = {
    "signSGD": CompressionConfig(compressor="sign", server="majority_vote"),
    "scaled_signSGD": CompressionConfig(compressor="scaled_sign", server="mean"),
    "noisy_signSGD": CompressionConfig(compressor="noisy_sign",
                                       budget=BudgetConfig(value=0.01),
                                       server="majority_vote"),
    "qsgd_1bit_l2": CompressionConfig(compressor="qsgd_1bit_l2", server="mean"),
    "qsgd_1bit_linf": CompressionConfig(compressor="qsgd_1bit_linf", server="mean"),
    "terngrad": CompressionConfig(compressor="terngrad", server="mean"),
    "sparsignSGD_B1": CompressionConfig(compressor="sparsign",
                                        budget=BudgetConfig(value=1.0),
                                        server="majority_vote"),
    "ef_sparsignSGD": CompressionConfig(compressor="sparsign",
                                        budget=BudgetConfig(value=1.0),
                                        server="scaled_sign_ef"),
}
