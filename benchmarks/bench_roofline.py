"""Roofline terms per (arch x shape x mesh).

WHY ANALYTIC: XLA's HLO cost analysis counts while-loop bodies ONCE regardless
of trip count (measured 8x undercount on an 8-iteration scan — see
EXPERIMENTS.md §Dry-run). Every hot loop here is a scan (superblocks, attention
chunks, loss chunks), so cost_analysis-derived terms are systematically wrong
for exactly the programs that matter. The three terms are therefore computed
from a first-principles model of the program we compiled (we wrote every
collective explicitly; the dry-run HLO census is cross-checked for op kinds /
shard shapes), and the HLO statics are reported alongside.

    compute_s    = analytic_FLOPs_per_device / 197e12
    memory_s     = analytic_HBM_bytes_per_device / 819e9
    collective_s = analytic_wire_bytes_per_device / 50e9

Program model (matches the shipped step functions):
  train (simple):   remat factor 4 (fwd + 2x bwd + recompute-fwd) on matmul
                    FLOPs; params resident TP-sharded; votes int8 all-reduce.
  train (streamed): same + FSDP bf16 param all-gather (fwd and bwd) over data.
  prefill:          factor 1; attention quadratic terms windowed where the
                    layer is windowed (structural, thanks to windowed_attention).
  decode:           params read once per token; KV-cache dot products linear in
                    (ring-bounded) cache depth; no worker collectives.
"""

from __future__ import annotations

import json
import math
import os

from benchmarks.common import csv_header, csv_row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DEFAULT_SWEEP = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_sweep.json")


def _layers(cfg):
    seq = list(cfg.pattern) * cfg.n_repeats + list(cfg.tail_pattern)
    attn = [s for s in seq if s.mixer == "attn"]
    return seq, attn


def analytic_cell(arch: str, shape_name: str, mesh_name: str, mode: str,
                  server: str = "scaled_sign_ef") -> dict:
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_pod = 2 if mesh_name == "2x16x16" else 1
    n_data, tp = 16, 16
    chips = n_pod * n_data * tp
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    seq_layers, attn_layers = _layers(cfg)
    hdh = cfg.n_heads * cfg.head_dim
    d = cfg.d_model

    if shape.kind in ("train", "prefill"):
        tokens = shape.seq_len * shape.global_batch
        tokens_loc = tokens / (n_pod * n_data)
        s = shape.seq_len
        # matmul flops (global): factor 4 for remat-train, 1 for prefill
        f_factor = 4.0 if shape.kind == "train" else 1.0
        matmul = 2.0 * n_active * tokens * f_factor
        # attention score+value flops (global), causal ~ /2, windowed capped
        attn = 0.0
        for spec in attn_layers:
            s_eff = min(s, spec.window) if spec.window else s / 2.0
            attn += 4.0 * shape.global_batch * s * s_eff * hdh * f_factor
        flops_pd = (matmul + attn) / chips

        # HBM bytes per device
        shard = tp * (n_data if mode == "streamed" else 1)
        param_reads = 3.0 if shape.kind == "train" else 1.0  # fwd + bwd + update
        pbytes = 2.0 * n_total / tp * param_reads + (2.0 * n_total / shard)  # reads + write
        ef_bytes = (8.0 * n_total / shard) if (shape.kind == "train" and server == "scaled_sign_ef") else 0.0
        vote_bytes = (2.0 * n_total / tp) if shape.kind == "train" else 0.0   # int8 rw
        act_passes = 10.0 if shape.kind == "train" else 4.0
        abytes = act_passes * len(seq_layers) * tokens_loc * d * 2.0 / 1.0
        bytes_pd = pbytes + ef_bytes + vote_bytes + abytes

        # wire bytes per device
        m = n_pod * n_data
        wire = 0.0
        if shape.kind == "train":
            wire += 2.0 * (m - 1) / m * (n_total / tp) * 1.0          # int8 vote ring AR
            if mode == "streamed":
                wire += 2.0 * (n_data - 1) / n_data * (2.0 * n_total / tp)  # fwd+bwd FSDP AG
        # Megatron-SP boundary gathers over the model axis (fwd [+bwd +remat])
        sp_passes = 3.0 if shape.kind == "train" else 1.0
        wire += sp_passes * len(seq_layers) * tokens_loc * d * 2.0 * (tp - 1) / tp
    else:  # decode
        bsz = shape.global_batch
        tokens_loc = max(bsz / (n_pod * n_data), 1)
        flops = 2.0 * n_active * bsz
        cache_bytes_pd = 0.0
        for spec in attn_layers:
            w_eff = min(shape.seq_len, spec.window) if spec.window else shape.seq_len
            flops += 4.0 * bsz * w_eff * cfg.n_kv_heads * cfg.head_dim \
                     + 2.0 * bsz * w_eff * hdh
            cache_bytes_pd += 2.0 * tokens_loc * w_eff * cfg.n_kv_heads * cfg.head_dim * 2.0 / tp
        flops_pd = flops / chips
        bytes_pd = 2.0 * n_total / tp + cache_bytes_pd
        wire = 2.0 * len(seq_layers) * tokens_loc * d * 2.0 * (tp - 1) / tp

    return {
        "flops_pd": flops_pd, "bytes_pd": bytes_pd, "wire_pd": wire,
        "compute_s": flops_pd / PEAK_FLOPS,
        "memory_s": bytes_pd / HBM_BW,
        "collective_s": wire / LINK_BW,
        "model_flops": (6.0 if shape.kind == "train" else 2.0) * n_active *
                       (shape.seq_len * shape.global_batch if shape.kind != "decode"
                        else shape.global_batch),
        "chips": chips,
    }


def analyze(records: list[dict]) -> list[dict]:
    out = []
    for r in records:
        base = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"]}
        if r.get("status") != "ok":
            out.append({**base, "status": r.get("status"),
                        "why": r.get("skip_reason") or (r.get("error") or "")[:60]})
            continue
        mode = r.get("mode") or "simple"
        server = (r.get("server") or "scaled_sign_ef").split(" ")[0]
        a = analytic_cell(r["arch"], r["shape"], r["mesh"],
                          mode if mode in ("simple", "streamed") else "simple", server)
        terms = {"compute": a["compute_s"], "memory": a["memory_s"],
                 "collective": a["collective_s"]}
        dom = max(terms, key=terms.get)
        bound_s = max(terms.values())
        frac = (a["model_flops"] / a["chips"] / PEAK_FLOPS) / max(bound_s, 1e-30)
        full = r["depths"]["full"]
        out.append({
            **base, "status": "ok", **{f"{k}_s": v for k, v in terms.items()},
            "dominant": dom, "roofline_frac": frac,
            "useful_ratio": a["model_flops"] / max(a["flops_pd"] * a["chips"], 1.0),
            "hlo_static_flops": full.get("flops", 0.0),
            "hlo_collective_counts": full["collectives"]["counts"],
            "mem_args_gib": full.get("memory", {}).get("argument_bytes", 0) / 2**30,
            "mem_temp_gib": full.get("memory", {}).get("temp_bytes", 0) / 2**30,
            "compile_s": full.get("compile_s"),
        })
    return out


def main(fast: bool = False, sweep_path: str | None = None):
    path = sweep_path or DEFAULT_SWEEP
    if not os.path.exists(path):
        print(f"# no sweep json at {path}; run repro.launch.dryrun first")
        return
    with open(path) as f:
        records = json.load(f)
    rows = analyze(records)
    print("# roofline terms (analytic program model; seconds per step per device)")
    csv_header(["arch", "shape", "mesh", "status", "compute_s", "memory_s",
                "collective_s", "dominant", "useful_flops_ratio", "roofline_frac",
                "mem_args_gib", "mem_temp_gib"])
    for r in rows:
        if r["status"] != "ok":
            csv_row([r["arch"], r["shape"], r["mesh"], r["status"],
                     "-", "-", "-", "-", "-", "-", "-", r.get("why", "")])
        else:
            csv_row([r["arch"], r["shape"], r["mesh"], "ok",
                     f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
                     f"{r['collective_s']:.4g}", r["dominant"],
                     f"{r['useful_ratio']:.3f}", f"{r['roofline_frac']:.3f}",
                     f"{r['mem_args_gib']:.1f}", f"{r['mem_temp_gib']:.1f}"])


if __name__ == "__main__":
    main()
