"""Paper Figs 1-2: Rosenbrock wrong-aggregation probability & convergence under
80/100 adversarial heterogeneity, plus the worker-sampling sweep."""

from __future__ import annotations

from benchmarks.common import csv_header, csv_row
from repro.fl.rosenbrock import run


def main(fast: bool = False):
    rounds = 100 if fast else 250
    print("# Fig 1: deterministic sign vs sparsign (B in {0.01, 0.1}), full participation")
    csv_header(["method", "budget", "wrong_agg_mean", "F_start", "F_end", "converged"])
    for method, budget in [("sign", None), ("sparsign", 0.01), ("sparsign", 0.1)]:
        r = run(method, budget=budget or 0.0, rounds=rounds, n_sel=100, lr=1e-3)
        csv_row([method, budget, f"{r.wrong_agg.mean():.3f}",
                 f"{r.values[0]:.1f}", f"{r.values[-1]:.1f}",
                 r.values[-1] < r.values[0]])

    print("# Fig 2: worker sampling (sparsign B=0.01, 5/10/50 of 100 workers)")
    csv_header(["n_selected", "wrong_agg_mean", "F_end"])
    for n_sel in (5, 10, 50):
        r = run("sparsign", budget=0.01, rounds=rounds, n_sel=n_sel, lr=2e-4)
        csv_row([n_sel, f"{r.wrong_agg.mean():.3f}", f"{r.values[-1]:.1f}"])


if __name__ == "__main__":
    main()
