"""Paper Table 1: Fashion-MNIST (alpha=0.1), 8 algorithms, full participation.

Synthetic class-conditional data (offline container — see DESIGN.md §10);
the deliverable is the paper's *ordering* and the communication accounting:
final accuracy, rounds to the target, uplink bits to the target.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import ALGORITHMS, csv_header, csv_row
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import ImageDataConfig, make_image_dataset
from repro.fl.models import mlp_fashion
from repro.fl.simulation import FLConfig, run_fl, stack_partitions


def main(fast: bool = False, target: float = 0.70):
    n_workers = 20 if fast else 50
    rounds = 60 if fast else 150
    x, y, xt, yt = make_image_dataset(ImageDataConfig(
        n_train=4000 if fast else 10000, n_test=1000, seed=0))
    parts = dirichlet_partition(y, n_workers=n_workers, alpha=0.1, seed=0)
    xp, yp = stack_partitions(x, y, parts)
    v0, apply_fn = mlp_fashion(jax.random.PRNGKey(0))

    print(f"# Table 1 analog: fashion-like synthetic, alpha=0.1, M={n_workers}, "
          f"{rounds} rounds, target acc {target}")
    csv_header(["algorithm", "final_acc", "rounds_to_target", "uplink_bits_to_target"])
    for name, comp in ALGORITHMS.items():
        cfg = FLConfig(n_workers=n_workers, rounds=rounds, batch_size=64,
                       lr=0.05, comp=comp, seed=0, eval_every=5)
        res = run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)
        hit = next((r for r, a in res["acc"] if a >= target), None)
        bits = res["uplink_bits_per_round"] * hit if hit else None
        csv_row([name, f"{res['final_acc']:.4f}", hit if hit else "N.A.",
                 f"{bits:.3e}" if bits else "N.A."])


if __name__ == "__main__":
    main()
