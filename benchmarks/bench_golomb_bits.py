"""Paper Eq. 12: Golomb position-coding bit accounting across sparsity levels,
plus the per-algorithm uplink table (bits/coordinate) used by Tables 1-2.

The Eq. 12 numbers are cross-checked against the REAL encoder: each sparsity
row also encodes a random ternary message with ``kernels.golomb.ref`` (the
wire-format definition the fused Pallas kernel is pinned against bitwise) and
reports the measured coded bits/coord next to the model, plus the bytes the
fixed-shape gather actually ships (static capacity rows — header and padding
tax included, ``golomb_nbytes``) vs the flat 2-bit wire. A tolerance assert
keeps the model honest: the measured stream must sit within 10% of Eq. 12
(gaps between Bernoulli nonzeros are geometric, which is exactly the source
the Golomb parameter is tuned for).

  python -m benchmarks.bench_golomb_bits            # full sweep (n = 2^20)
  python -m benchmarks.bench_golomb_bits --quick    # CI smoke   (n = 2^16)
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_header, csv_row
from repro.core.encoding import (baseline_bits_per_round, golomb_bits_per_index,
                                 golomb_bstar, ternary_stream_bits)
from repro.dist.collectives import packed_nbytes
from repro.kernels.golomb.ref import (golomb_encode_ref, golomb_nbytes,
                                      golomb_rows, rice_b)

#: measured-vs-Eq.12 tolerance on the coded stream (relative); the residual is
#: finite-message noise + the truncated final gap, both O(1/sqrt(nnz))
MODEL_RTOL = 0.10

SPARSITIES_FULL = (0.001, 0.01, 0.05, 0.1, 0.2, 0.3)
SPARSITIES_QUICK = (0.01, 0.05)


def measured_stream_bits(t: np.ndarray, p: float) -> int:
    """Realized coded bits of one message by the format definition: per
    nonzero a Rice code of the zero-run gap ((gap >> b) unary + 1 stop + b
    remainder) plus 1 sign bit. Pure arithmetic over the nonzero positions —
    the byte-level truth is separately pinned bitwise in tests/test_golomb.py."""
    b = rice_b(p)
    pos = np.flatnonzero(t)
    if pos.size == 0:
        return 0
    gaps = np.diff(pos, prepend=-1) - 1
    return int(np.sum(gaps >> b)) + pos.size * (2 + b)


def measured_section(n: int, sparsities) -> None:
    print("# measured encoder vs Eq. 12 vs the flat 2-bit wire "
          f"(random ternary message, n={n})")
    csv_header(["p", "b_star", "nnz", "model_bits_per_coord",
                "measured_stream_bits_per_coord", "wire_bits_per_coord",
                "pack2_wire_bits_per_coord", "wire_vs_pack2"])
    pack2_bits = packed_nbytes(n) * 8.0
    rng = np.random.RandomState(0)
    for p in sparsities:
        t = rng.choice(np.array([-1, 0, 1], np.int8), size=n,
                       p=[p / 2, 1.0 - p, p / 2])
        payload = golomb_encode_ref(jnp.asarray(t), p=p)
        flat = np.asarray(payload).reshape(-1)
        shipped = int.from_bytes(flat[:4].tobytes(), "little")
        dropped = int.from_bytes(flat[4:8].tobytes(), "little")
        assert dropped == 0, (p, dropped)   # six-sigma capacity at plan density
        assert shipped == int(np.abs(t.astype(np.int32)).sum())
        stream = measured_stream_bits(t, p)
        model = ternary_stream_bits(n, shipped, coder="golomb")
        wire_bits = golomb_nbytes(n, p) * 8.0
        assert wire_bits == payload.nbytes * 8.0   # ledger == shipped buffer
        if shipped >= 200:
            assert abs(stream - model) <= MODEL_RTOL * model, (
                f"measured {stream} b vs Eq.12 {model:.0f} b at p={p} — "
                f"the bit model drifted off the real encoder")
        csv_row([p, rice_b(p), shipped, f"{model / n:.4f}", f"{stream / n:.4f}",
                 f"{wire_bits / n:.4f}", f"{pack2_bits / n:.4f}",
                 f"{wire_bits / pack2_bits:.3f}"])
    # above ~35% density the static capacity cannot beat the flat wire: the
    # build refuses (callers fall back to pack2) — record it, don't hide it
    try:
        golomb_rows(n, 0.5)
        raise AssertionError("golomb_rows(0.5) must refuse — pack2 regime")
    except ValueError:
        csv_row([0.5, rice_b(0.5), "-", "-", "-", "build-error(fallback=pack2)",
                 f"{pack2_bits / n:.4f}", ">=1"])


def main(fast: bool = False):
    d = 235146  # the paper's fashion MLP dimension
    print("# Eq. 12: bits per nonzero index vs sparsity ratio p")
    csv_header(["p", "b_star", "bits_per_index", "total_bits_vs_dense_ternary"])
    for p in (0.001, 0.01, 0.05, 0.1, 0.3, 0.5):
        nnz = int(p * d)
        total = ternary_stream_bits(d, nnz, coder="golomb")
        dense = ternary_stream_bits(d, nnz, coder="dense")
        csv_row([p, golomb_bstar(p), f"{golomb_bits_per_index(p):.2f}",
                 f"{total / dense:.3f}"])

    measured_section(n=(1 << 16) if fast else (1 << 20),
                     sparsities=SPARSITIES_QUICK if fast else SPARSITIES_FULL)

    print("# uplink bits/coordinate by algorithm (nnz = 5% for ternary methods)")
    csv_header(["algorithm", "bits_per_coord"])
    nnz = int(0.05 * d)
    for algo in ("sign", "noisy_sign", "sparsign", "sparsign_golomb",
                 "terngrad", "qsgd8", "identity"):
        bits = baseline_bits_per_round(d, algo, nnz=nnz)
        csv_row([algo, f"{bits / d:.3f}"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller message, fewer sparsity levels")
    args = ap.parse_args()
    main(fast=args.quick)
