"""Paper Eq. 12: Golomb position-coding bit accounting across sparsity levels,
plus the per-algorithm uplink table (bits/coordinate) used by Tables 1-2."""

from __future__ import annotations

from benchmarks.common import csv_header, csv_row
from repro.core.encoding import (baseline_bits_per_round, golomb_bits_per_index,
                                 golomb_bstar, ternary_stream_bits)


def main(fast: bool = False):
    d = 235146  # the paper's fashion MLP dimension
    print("# Eq. 12: bits per nonzero index vs sparsity ratio p")
    csv_header(["p", "b_star", "bits_per_index", "total_bits_vs_dense_ternary"])
    for p in (0.001, 0.01, 0.05, 0.1, 0.3, 0.5):
        nnz = int(p * d)
        total = ternary_stream_bits(d, nnz, coder="golomb")
        dense = ternary_stream_bits(d, nnz, coder="dense")
        csv_row([p, golomb_bstar(p), f"{golomb_bits_per_index(p):.2f}",
                 f"{total / dense:.3f}"])

    print("# uplink bits/coordinate by algorithm (nnz = 5% for ternary methods)")
    csv_header(["algorithm", "bits_per_coord"])
    nnz = int(0.05 * d)
    for algo in ("sign", "noisy_sign", "sparsign", "terngrad", "qsgd8", "identity"):
        bits = baseline_bits_per_round(d, algo, nnz=nnz)
        csv_row([algo, f"{bits / d:.3f}"])


if __name__ == "__main__":
    main()
