"""End-to-end driver: pretrain a ~100M-parameter LM with compressed gradient
exchange on a multi-device mesh — deliverable (b)'s training scenario.

    PYTHONPATH=src python examples/distributed_pretrain.py              # ~100M, 300 steps
    PYTHONPATH=src python examples/distributed_pretrain.py --tiny      # CI-speed

Uses 8 forced host CPU devices as a (4 data x 2 model) mesh: the identical
shard_map/GSPMD program a TPU slice runs (only the mesh constructor differs).
Checkpoints + resume are on; kill it mid-run and re-invoke to see the replay.
"""

import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.synthetic import LMStreamConfig, lm_batch
from repro.dist import compat
from repro.models.model import Model
from repro.train import loop as loop_lib
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step


def lm_100m() -> ModelConfig:
    # embed 50k x 640 (32M) + 10 blocks x ~4.9M + untied head (32M) ~= 114M params
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, d_ff=1712, vocab_size=50000,
        pattern=(LayerSpec(mixer="attn"),), dtype="float32",
        attn_chunk=128, q_chunk=64, loss_chunk=64)


def lm_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512,
        pattern=(LayerSpec(mixer="attn"),), dtype="float32",
        attn_chunk=32, q_chunk=32, loss_chunk=32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pretrain_ckpt")
    args = ap.parse_args(argv)

    cfg = lm_tiny() if args.tiny else lm_100m()
    steps = args.steps or (30 if args.tiny else 300)
    seq = args.seq_len or (32 if args.tiny else 128)
    model = Model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params; {steps} steps, "
          f"batch {args.batch} x seq {seq}")

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=1.0),
                             server="scaled_sign_ef")
    step = build_train_step(model, TrainStepConfig(
        compression=comp, lr=LrSchedule(base=2e-3, warmup=2 if args.tiny else 20),
        worker_axes=("data",)), mesh)
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, server=comp.server, seed=1)

    stream = LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                            global_batch=args.batch, seed=5)
    batch_fn = lambda i: {k: jnp.asarray(v) for k, v in lm_batch(stream, i).items()}

    lcfg = loop_lib.LoopConfig(total_steps=steps, ckpt_dir=args.ckpt_dir,
                               ckpt_every=max(10, steps // 5), log_every=max(1, steps // 20))
    with compat.set_mesh(mesh):
        state, history = loop_lib.run(step, state, batch_fn, lcfg)
    if not history:
        print(f"\nnothing to do: checkpoint in {args.ckpt_dir} is already at "
              f"step {int(state.step)} >= {steps} (delete it to re-run)")
        return
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss: {first:.4f} -> {last:.4f} over {steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'}); "
          f"mean vote sparsity {history[-1]['nnz_frac']:.4f}")


if __name__ == "__main__":
    main()
