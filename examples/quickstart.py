"""Quickstart: the paper's compressor + vote + theory in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BudgetConfig, CompressionConfig, expected_sparsity,
                        reference_round, sparsign)
from repro.core import theory
from repro.core.encoding import ternary_stream_bits

# --- 1. compress one gradient (Def. 1) -------------------------------------
g = jnp.asarray(np.random.RandomState(0).randn(10000), jnp.float32)
msg = sparsign(g, budget=0.5, seed=42)
nnz = int(jnp.sum(jnp.abs(msg.values)))
print(f"sparsign: {nnz}/{g.size} coordinates transmitted "
      f"(expected {float(expected_sparsity(g, 0.5)) * g.size:.0f})")
print(f"uplink cost: {ternary_stream_bits(g.size, nnz) / g.size:.3f} bits/coord "
      f"(signSGD: 1.000, fp32: 32)")

# --- 2. why it fixes signSGD: the wrong-aggregation bound (Thm 1) ----------
# 80 of 100 workers carry small adversarially-flipped gradients
rng = np.random.RandomState(1)
u = jnp.asarray(np.concatenate([-rng.uniform(0.005, 0.015, 80),
                                rng.uniform(0.05, 0.15, 20)]), jnp.float32)
p_det, q_det = theory.deterministic_sign_pq(u)
p_sp, q_sp = theory.sparsign_pq(u, budget=5.0)
print(f"\ndeterministic sign: p_bar={float(p_det):.3f} > q_bar={float(q_det):.3f}"
      f"  -> majority vote is WRONG (80 wrong heads win)")
print(f"sparsign:           p_bar={float(p_sp):.4f} < q_bar={float(q_sp):.4f}"
      f"  -> Thm 1 bound P(wrong) <= "
      f"{float(theory.wrong_aggregation_bound(p_sp, q_sp, 100)):.3f}")

# --- 3. one full Algorithm-1 round on 16 workers ----------------------------
comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=1.0),
                         server="majority_vote")
w = jnp.zeros(100)
per_worker_grads = jnp.asarray(rng.randn(16, 100), jnp.float32) + 0.5
w2, _ = reference_round(w, per_worker_grads, comp, eta=0.1, seed=7)
print(f"\nAlg. 1 round: |w| moved from 0 to {float(jnp.abs(w2).mean()):.3f} "
      f"(majority vote followed the shared +0.5 drift on "
      f"{int((w2 < 0).sum())}/100 coords negative)")
