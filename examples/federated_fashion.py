"""Paper §6.2 demo: federated training on heterogeneous (Dirichlet alpha=0.1)
fashion-like data — EF-SPARSIGNSGD vs signSGD vs TernGrad, with communication
accounting.

    PYTHONPATH=src python examples/federated_fashion.py
"""

import jax

from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.dirichlet import dirichlet_partition, heterogeneity_stats
from repro.data.synthetic import ImageDataConfig, make_image_dataset
from repro.fl.models import mlp_fashion
from repro.fl.simulation import FLConfig, run_fl, stack_partitions

ALGOS = {
    "signSGD": CompressionConfig(compressor="sign", server="majority_vote"),
    "terngrad": CompressionConfig(compressor="terngrad", server="mean"),
    "sparsignSGD (B=1)": CompressionConfig(
        compressor="sparsign", budget=BudgetConfig(value=1.0), server="majority_vote"),
    "EF-sparsignSGD": CompressionConfig(
        compressor="sparsign", budget=BudgetConfig(value=1.0), server="scaled_sign_ef"),
    "EF-sparsign local5": CompressionConfig(
        compressor="sparsign", budget=BudgetConfig(value=1.0), server="scaled_sign_ef",
        local_steps=5, local_budget=10.0),
}


def main():
    x, y, xt, yt = make_image_dataset(ImageDataConfig(n_train=6000, n_test=1000))
    parts = dirichlet_partition(y, n_workers=30, alpha=0.1, seed=0)
    print("heterogeneity:", heterogeneity_stats(y, parts))
    xp, yp = stack_partitions(x, y, parts)
    v0, apply_fn = mlp_fashion(jax.random.PRNGKey(0))

    for name, comp in ALGOS.items():
        cfg = FLConfig(n_workers=30, rounds=60, batch_size=64, lr=0.05,
                       local_lr=0.02, comp=comp, seed=0, eval_every=20)
        res = run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)
        print(f"{name:24s} final_acc={res['final_acc']:.4f} "
              f"uplink={res['uplink_bits_per_round']/8/1024:.1f} KiB/round "
              f"({res['uplink_bits_per_round']/res['d']/30:.3f} bits/coord/worker)")


if __name__ == "__main__":
    main()
