"""Paper §6.1 demo: watch signSGD diverge and SPARSIGNSGD converge on the
heterogeneous Rosenbrock problem (Figs 1-2), as ASCII curves.

    PYTHONPATH=src python examples/rosenbrock_demo.py
"""

import numpy as np

from repro.fl.rosenbrock import run


def ascii_curve(values, width=60, label=""):
    v = np.asarray(values)
    v = v[:: max(1, len(v) // width)][:width]
    lo, hi = float(np.min(v)), float(np.max(v))
    rng = max(hi - lo, 1e-9)
    chars = " .:-=+*#%@"
    line = "".join(chars[int((x - lo) / rng * (len(chars) - 1))] for x in v)
    print(f"{label:22s} |{line}|  [{lo:.1f}, {hi:.1f}]")


print("F(x_t) over 250 rounds, 100 workers, 80 with adversarially flipped scales")
print("(higher character = higher loss; left -> right = training time)\n")
for name, method, budget in [("signSGD", "sign", 0.0),
                             ("sparsignSGD B=0.01", "sparsign", 0.01),
                             ("sparsignSGD B=0.1", "sparsign", 0.1)]:
    r = run(method, budget=budget, rounds=250, n_sel=100, lr=1e-3)
    ascii_curve(r.values, label=name)
    print(f"{'':22s}  wrong-aggregation probability: {r.wrong_agg.mean():.3f}"
          f"  (Thm 1 needs < 0.5)\n")

print("worker sampling (Fig 2): sparsign B=0.01, select k of 100 per round")
for k in (5, 10, 50):
    r = run("sparsign", budget=0.01, rounds=250, n_sel=k, lr=2e-4)
    ascii_curve(r.values, label=f"  {k} workers/round")
