"""The bucketized uplink wire (repro.dist.bucketing): static plan invariants,
payload round-trips, the bucketed-vs-per-leaf bitwise equivalence of the simple
train step on every wire mode, per-slot quorum attribution through the bucket,
and the launch-count budgets the analysis gate blocks on.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import drivers
from repro.dist import bucketing, collectives
from repro.kernels import common as kcommon

# odd, tile-hostile shapes on purpose: scalars-adjacent vectors, non-multiple
# of LANES, bf16 leaves
ODD_SHAPES = [
    jax.ShapeDtypeStruct((33,), jnp.float32),
    jax.ShapeDtypeStruct((7, 129), jnp.bfloat16),
    jax.ShapeDtypeStruct((2, 3, 85), jnp.float32),
    jax.ShapeDtypeStruct((513,), jnp.bfloat16),
    jax.ShapeDtypeStruct((64, 511), jnp.float32),
]


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", bucketing.BUCKET_FORMATS)
def test_plan_offsets_and_alignment(fmt):
    kw = {}
    wire = None
    if fmt == "golomb":
        # the variable-length format sizes slots by plan-time CAPACITY rows,
        # so the plan needs the wire's rows rule (not a coordinate count)
        wire = collectives.GolombWire(axes=("data",), n_workers=4, p=0.05)
        kw["rows_fn"] = wire.payload_rows
    plan = bucketing.build_bucket_plan(ODD_SHAPES, fmt, **kw)
    align = bucketing.format_align_rows(fmt)
    assert plan.align_rows == align
    seen = []
    for b in plan.buckets:
        row = 0
        for s in b.slots:
            assert s.row_start == row, "slots must be contiguous"
            assert s.row_start % align == 0
            if fmt == "golomb":
                # each slot is one whole self-describing capacity stream
                assert s.rows == wire.payload_rows(s.size)
            else:
                assert s.rows == bucketing.leaf_rows(s.size, align)
                assert s.rows * kcommon.LANES >= s.size
            assert s.size == math.prod(s.shape)
            row += s.rows
            seen.append(s.index)
        # tail padding only for the kernel-decoded packed formats
        if fmt in ("pack2", "pack8"):
            assert b.rows % kcommon.SUBLANE_PAD == 0
            assert b.rows - row < kcommon.SUBLANE_PAD
        else:
            assert b.rows == row
    assert sorted(seen) == list(range(len(ODD_SHAPES)))


def test_plan_golomb_requires_rows_fn():
    with pytest.raises(ValueError, match="rows_fn"):
        bucketing.build_bucket_plan(ODD_SHAPES, "golomb")
    with pytest.raises(ValueError, match="rows_fn"):
        bucketing.build_bucket_plan(ODD_SHAPES, "int8",
                                    rows_fn=lambda n: n)


def test_pack8_slots_are_canonical_views():
    """align_rows=SUBLANE_PAD makes every pack8 slot slice exactly the leaf's
    own canonical 2D view — the precondition for per-slot kernel decode."""
    plan = bucketing.build_bucket_plan(ODD_SHAPES, "pack8")
    for b in plan.buckets:
        for s in b.slots:
            assert s.rows == kcommon.canonical_rows(s.size)


def test_plan_bucket_bytes_cap_and_oversized_leaf():
    fmt = "int8"
    row_bytes = bucketing.ROW_BYTES[fmt]
    cap = 4 * row_bytes  # 4 rows per bucket
    shapes = [jax.ShapeDtypeStruct((600,), jnp.float32),      # 2 rows
              jax.ShapeDtypeStruct((600,), jnp.float32),      # 2 rows
              jax.ShapeDtypeStruct((600,), jnp.float32),      # 2 rows -> split
              jax.ShapeDtypeStruct((5000,), jnp.float32)]     # 10 rows oversize
    plan = bucketing.build_bucket_plan(shapes, fmt, bucket_bytes=cap)
    assert [len(b.slots) for b in plan.buckets] == [2, 1, 1]
    # leaves are never split: the oversized leaf rides one bucket whole
    assert plan.buckets[-1].slots[0].rows == 10
    # unbounded: everything in one bucket
    one = bucketing.build_bucket_plan(shapes, fmt)
    assert len(one.buckets) == 1 and one.n_slots == 4


# ---------------------------------------------------------------------------
# payload round-trip: leaf -> rows -> bucket -> split is bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["int8", "f32"])
def test_assemble_split_roundtrip_bitwise(fmt):
    rng = np.random.RandomState(0)
    plan = bucketing.build_bucket_plan(ODD_SHAPES, fmt)
    dt = np.int8 if fmt == "int8" else np.float32
    leaves = [jnp.asarray(rng.randint(-100, 100, s.shape).astype(dt))
              for s in ODD_SHAPES]
    for b in plan.buckets:
        payloads = [bucketing.as_rows(leaves[s.index], fmt, s.rows)
                    for s in b.slots]
        buf = bucketing.assemble_bucket(payloads, b, fmt)
        assert buf.shape == (b.rows, bucketing.ROW_WIDTH[fmt])
        parts = bucketing.split_bucket(buf, b)
        for s, part in zip(b.slots, parts):
            assert part.shape == s.shape
            np.testing.assert_array_equal(np.asarray(part),
                                          np.asarray(leaves[s.index]))


def test_as_rows_preserves_flat_index():
    """Coordinate (r, c) of the row view must be flat index r*LANES + c —
    the counter-RNG layout invariant bucketing must not disturb."""
    n = 1000
    v = jnp.arange(n, dtype=jnp.float32)
    rows = bucketing.leaf_rows(n, 1)
    out = np.asarray(bucketing.as_rows(v, "f32", rows)).reshape(-1)
    np.testing.assert_array_equal(out[:n], np.arange(n, dtype=np.float32))
    assert (out[n:] == 0).all()


# ---------------------------------------------------------------------------
# the acceptance property: bucketed step == per-leaf step, bitwise
# ---------------------------------------------------------------------------

def _run(mode, **kw):
    from repro.dist import compat

    step, state, batch, model, mesh, _ = drivers.build_mode_step(mode, **kw)
    with compat.set_mesh(mesh):
        out, metrics = step(state, batch)
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(out.params)]
    return leaves, metrics


@pytest.mark.parametrize("mode", list(drivers.MODE_SETUPS))
def test_bucketed_step_bitwise_equals_per_leaf(mode):
    ref, m_ref = _run(mode, bucketed=False)
    got, m_got = _run(mode, bucketed=True)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    # nnz attribution survives bucket granularity exactly
    assert float(m_ref["nnz_frac"]) == float(m_got["nnz_frac"])


def test_bucketed_per_slot_quorum_attribution():
    """Per-leaf quorum must address the right slot through the bucket: with a
    one-worker vote in {-1, 0, +1}, quorum=2 freezes exactly the leaves it is
    assigned to while quorum=1 leaves keep stepping."""
    from repro.dist import compat
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_simple import TrainStepConfig, build_train_step

    mode = "votes"
    _, server, vote_impl, _ = drivers.MODE_SETUPS[mode]
    comp = drivers.mode_comp(mode)
    model = drivers.tiny_model()
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = drivers.tiny_batch(model.cfg.vocab_size)
    # freeze only the embed leaf
    quorum = {k: (2 if k == "embed" else 1) for k in model.param_shapes()}
    outs = []
    for bucketed in (False, True):
        scfg = TrainStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                               worker_axes=("data",), vote_impl=vote_impl,
                               quorum=quorum, donate=False,
                               backend="interpret", bucketed=bucketed)
        step = build_train_step(model, scfg, mesh)
        state = init_state(params, server=server, seed=7)
        with compat.set_mesh(mesh):
            out, _ = step(state, batch)
        outs.append(out.params)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # embed frozen (|vote| <= 1 < 2), at least one other leaf stepped
    assert np.array_equal(np.asarray(outs[1]["embed"]),
                          np.asarray(params["embed"]))
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(outs[1]),
                                jax.tree_util.tree_leaves(params)))
    assert moved


# ---------------------------------------------------------------------------
# ledgers and launch-count budgets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", list(drivers.MODE_SETUPS))
def test_bucketed_census_pins_plan_ledger(mode):
    findings, census, payload, scalar = drivers.census_check(mode, bucketed=True)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert payload > 0
    assert census.payload_bytes({"data": drivers.HYPOTHETICAL_M}) == \
        pytest.approx(payload)


@pytest.mark.parametrize("bucketed", [False, True])
def test_count_budgets_exact(bucketed):
    findings, census, expected = drivers.count_check("votes", bucketed=bucketed)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert census.payload_count() == expected
    if bucketed:
        assert expected == 1  # whole tiny tree rides ONE collective


def test_count_ratio_floor_on_stacked_configs():
    findings, checks = drivers.count_ratio_checks()
    assert findings == [], "\n".join(f.render() for f in findings)
    assert checks == len(drivers.RATIO_CONFIGS) * len(drivers.MODE_SETUPS)


def test_uplink_ledger_bucket_vs_plan_ledger():
    """plan_ledger must be exactly the per-bucket uplink_ledger_bucket sum
    (plus the one shared-linf vector term when requested)."""
    m = drivers.HYPOTHETICAL_M
    for mode in drivers.MODE_SETUPS:
        wire = drivers.mode_wire(mode, m)
        fmt = bucketing.wire_bucket_format(mode, wire)
        kw = {"rows_fn": wire.payload_rows} if fmt == "golomb" else {}
        plan = bucketing.build_bucket_plan(ODD_SHAPES, fmt,
                                           bucket_bytes=4096, **kw)
        pay, scal = bucketing.plan_ledger(mode, wire, plan)
        want_p = want_s = 0.0
        for b in plan.buckets:
            p, s = collectives.uplink_ledger_bucket(mode, wire, b.n_coords,
                                                    len(b.slots), rows=b.rows)
            want_p += p
            want_s += s
        assert pay == pytest.approx(want_p)
        assert scal == pytest.approx(want_s)
        pay_sh, _ = bucketing.plan_ledger(mode, wire, plan, share_linf=True)
        extra = collectives.allreduce_scalar_bytes(m) * plan.n_slots
        assert pay_sh == pytest.approx(pay + extra)
