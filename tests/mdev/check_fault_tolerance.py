"""Fault-tolerance + elastic-scaling checks on 8 host devices.

1. Crash/restart: run A trains 8 steps straight; run B checkpoints every 2
   steps, dies (injected) at step 5, restarts from the checkpoint, finishes.
   Final params must be BITWISE identical (pure-function-of-step data stream +
   deterministic per-round compression seeds).
2. Elastic rescale: checkpoint from a 4-worker mesh restores onto a 2-worker
   mesh and training continues (majority vote is M-invariant).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compat
from repro.configs.registry import get_config
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.synthetic import LMStreamConfig, lm_batch
from repro.models.model import Model
from repro.train import loop as loop_lib
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step

CKPT = "/tmp/repro_ft_ckpt"


def setup(mesh_shape=(4, 2)):
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = Model(cfg)
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=2.0),
                             server="scaled_sign_ef")
    step = build_train_step(model, TrainStepConfig(
        compression=comp, lr=LrSchedule(base=0.01), worker_axes=("data",), donate=False), mesh)
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, server=comp.server, seed=77)
    stream = LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=3)
    batch_fn = lambda i: {k: jnp.asarray(v) for k, v in lm_batch(stream, i).items()}
    return mesh, step, state, batch_fn


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    # --- run A: uninterrupted ---
    mesh, step, state, batch_fn = setup()
    with compat.set_mesh(mesh):
        ref_state, _ = loop_lib.run(step, state, batch_fn,
                                    loop_lib.LoopConfig(total_steps=8, log_every=100))
    # --- run B: checkpoint every 2, die at 5, restart ---
    mesh, step, state, batch_fn = setup()
    cfgB = loop_lib.LoopConfig(total_steps=8, ckpt_dir=CKPT, ckpt_every=2,
                               fail_at_step=5, log_every=100)
    died = False
    try:
        with compat.set_mesh(mesh):
            loop_lib.run(step, state, batch_fn, cfgB)
    except RuntimeError as e:
        died = True
        print("injected failure:", e)
    assert died
    # restart (fresh everything, as after a pod loss)
    mesh, step, state, batch_fn = setup()
    cfgB2 = loop_lib.LoopConfig(total_steps=8, ckpt_dir=CKPT, ckpt_every=2, log_every=100)
    with compat.set_mesh(mesh):
        state_b, _ = loop_lib.run(step, state, batch_fn, cfgB2)
    for pa, pb in zip(jax.tree_util.tree_leaves(ref_state.params),
                      jax.tree_util.tree_leaves(state_b.params)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), "restart diverged"
    print("OK crash/restart: final params bitwise identical to uninterrupted run")

    # --- elastic: restore the checkpoint on a (2, 4) mesh and keep training ---
    mesh2, step2, state2, batch_fn2 = setup(mesh_shape=(2, 4))
    with compat.set_mesh(mesh2):
        state2b, hist = loop_lib.run(step2, state2, batch_fn2,
                                     loop_lib.LoopConfig(total_steps=10, ckpt_dir=CKPT,
                                                         ckpt_every=100, log_every=100))
    assert int(state2b.step) == 10
    assert np.isfinite(hist[-1]["loss"])
    print("OK elastic: resumed 4-worker checkpoint on a 2-worker mesh; loss",
          hist[-1]["loss"])


if __name__ == "__main__":
    main()
