"""Fault-tolerance + elastic-participation checks on 8 host devices.

1. Crash/restart: run A trains 8 steps straight; run B checkpoints every 2
   steps, dies (injected) at step 5, restarts from the checkpoint, finishes.
   Final params must be BITWISE identical (pure-function-of-step data stream +
   deterministic per-round compression seeds).
2. Elastic rescale: checkpoint from a 4-worker mesh restores onto a 2-worker
   mesh and training continues (majority vote is M-invariant).
3. Elastic parity: a ParticipationSpec with uniform weights, zero dropout and
   q_frac == quorum/M is BITWISE the legacy fixed-quorum round on every wire
   mode (votes/psum, votes/gather, pack8/gather, decoded/psum), both kernel
   backends, on the real 4-worker data axis.
4. Chaos: 50% per-round report dropout + non-uniform (data-volume) weights on
   every wire — including every gather wire (pack2, pack8, golomb) — trains
   finite, and the billed participation drops below the full fleet.
5. M-invariance: a 4-worker and a 2-worker fleet fed identical aggregate data
   produce BITWISE-identical params under the participation-normalized vote
   (q_frac), while the legacy fixed integer quorum silently freezes the
   smaller fleet — the failure mode the normalization exists to fix.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compat
from repro.dist.collectives import ParticipationSpec
from repro.configs.base import LayerSpec, ModelConfig
from repro.configs.registry import get_config
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.synthetic import LMStreamConfig, lm_batch
from repro.models.model import Model
from repro.train import loop as loop_lib
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step

CKPT = "/tmp/repro_ft_ckpt"


def setup(mesh_shape=(4, 2)):
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = Model(cfg)
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=2.0),
                             server="scaled_sign_ef")
    step = build_train_step(model, TrainStepConfig(
        compression=comp, lr=LrSchedule(base=0.01), worker_axes=("data",), donate=False), mesh)
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, server=comp.server, seed=77)
    stream = LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=3)
    batch_fn = lambda i: {k: jnp.asarray(v) for k, v in lm_batch(stream, i).items()}
    return mesh, step, state, batch_fn


# --- elastic-participation sections: a tiny dense model (the wire layer does
# --- not care about model size; ~20 extra step builds must stay cheap)

def tiny_model():
    cfg = ModelConfig(name="ft-tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      pattern=(LayerSpec(mixer="attn"),), dtype="float32",
                      attn_chunk=8, q_chunk=8, loss_chunk=8, remat=False)
    return Model(cfg)


def tiny_batch(vocab, rows, step_i):
    rng = np.random.RandomState(1000 + step_i)
    s = 8
    return {
        "inputs": jnp.asarray(rng.randint(0, vocab, (rows, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, vocab, (rows, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (rows, s)).astype(jnp.int32),
    }


def run_tiny(mesh_shape, comp, n_steps, batch_of, **cfg_kw):
    """n_steps of the tiny model on a fresh mesh; returns (params, metrics list)."""
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))
    model = tiny_model()
    step = build_train_step(model, TrainStepConfig(
        compression=comp, lr=LrSchedule(base=0.05), worker_axes=("data",),
        donate=False, **cfg_kw), mesh)
    state = init_state(model.init(jax.random.PRNGKey(0)), server=comp.server, seed=7)
    hist = []
    with compat.set_mesh(mesh):
        for i in range(n_steps):
            state, metrics = step(state, batch_of(i))
            hist.append({k: float(v) for k, v in metrics.items()
                         if jnp.asarray(v).size == 1})
    return jax.tree_util.tree_map(np.asarray, state.params), hist


WIRE_MODES = [  # (tag, compressor, server, vote_impl, quorum, extra cfg)
    ("votes/psum   ", "sparsign", "majority_vote", "psum", 2, {}),
    ("votes/gather ", "sparsign", "majority_vote", "allgather_packed", 2, {}),
    ("pack8/gather ", "qsgd8", "mean", "allgather_packed", 1, {}),
    ("decoded/psum ", "qsgd8", "mean", "psum", 1, {}),
]
OTHER = "interpret" if jax.default_backend() != "tpu" else "pallas"


def elastic_parity():
    m = 4
    batch_of = lambda i: tiny_batch(64, rows=8, step_i=i)
    for tag, compressor, server, vote_impl, quorum, extra in WIRE_MODES:
        comp = CompressionConfig(compressor=compressor,
                                 budget=BudgetConfig(value=1.0), server=server)
        legacy, _ = run_tiny((m, 2), comp, 2, batch_of, vote_impl=vote_impl,
                             quorum=quorum, **extra)
        for backend in ("jnp", OTHER):
            spec = ParticipationSpec(q_frac=quorum / m)
            elastic, hist = run_tiny((m, 2), comp, 2, batch_of,
                                     vote_impl=vote_impl, quorum=quorum,
                                     participation=spec, backend=backend, **extra)
            for (ka, a), (_, b) in zip(
                    jax.tree_util.tree_flatten_with_path(legacy)[0],
                    jax.tree_util.tree_flatten_with_path(elastic)[0]):
                assert np.array_equal(a, b), \
                    (tag, backend, jax.tree_util.keystr(ka))
            assert all(h["participated"] == m for h in hist)
        print(f"OK elastic parity {tag} weighted(q_frac={quorum}/{m}) == "
              f"legacy(quorum={quorum}) bitwise, both backends")


CHAOS_WIRES = [  # every wire; gather wires (pack2, pack8, golomb) included
    ("votes/psum   ", "sparsign", "majority_vote", "psum", {}),
    ("votes/gather ", "sparsign", "majority_vote", "allgather_packed", {}),
    ("pack8/gather ", "qsgd8", "mean", "allgather_packed", {}),
    ("golomb/gather", "sparsign_golomb", "majority_vote", "allgather_packed",
     {"golomb_p": 0.25}),
    ("decoded/psum ", "qsgd8", "mean", "psum", {}),
]


def chaos():
    m, steps = 4, 4
    spec = ParticipationSpec(weights=(1.5, 0.5, 2.0, 1.0), q_frac=0.5, dropout=0.5)
    batch_of = lambda i: tiny_batch(64, rows=8, step_i=i)
    for tag, compressor, server, vote_impl, extra in CHAOS_WIRES:
        comp = CompressionConfig(compressor=compressor,
                                 budget=BudgetConfig(value=1.0), server=server)
        _, hist = run_tiny((m, 2), comp, steps, batch_of, vote_impl=vote_impl,
                           participation=spec, **extra)
        assert all(np.isfinite(h["loss"]) for h in hist), tag
        parts = [h["participated"] for h in hist]
        assert all(0.0 <= p <= m for p in parts), (tag, parts)
        assert min(parts) < m, \
            (tag, "50% dropout never dropped a report", parts)
        print(f"OK chaos {tag} dropout=0.5 weighted: loss={hist[-1]['loss']:.4f} "
              f"participated={parts}")


def m_invariance():
    # budget 1e38: p = clip(|g| * 1e38, 0, 1) saturates at 1 for every normal
    # float, so sparsign degenerates to the deterministic dense sign(g) and
    # identical worker shards vote unanimously — which is what makes a
    # 4-worker and a 2-worker fleet comparable at all.
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(value=1e38),
                             server="majority_vote")

    def batch_of(dp):
        # every worker's shard is the same 2-row base: the AGGREGATE data is
        # identical across fleet sizes (model axis stays 2 so per-worker
        # math is bitwise too)
        return lambda i: jax.tree_util.tree_map(
            lambda v: jnp.tile(v, (dp,) + (1,) * (v.ndim - 1)),
            tiny_batch(64, rows=2, step_i=i))

    finals = {}
    for dp in (4, 2):
        finals[dp], _ = run_tiny((dp, 2), comp, 4, batch_of(dp),
                                 participation=ParticipationSpec(q_frac=0.75))
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(finals[4])[0],
            jax.tree_util.tree_flatten_with_path(finals[2])[0]):
        assert np.array_equal(a, b), ("M-invariance", jax.tree_util.keystr(ka))
    print("OK M-invariance: 4-worker and 2-worker fleets on identical "
          "aggregate data agree bitwise under q_frac=0.75")

    # the legacy fixed integer quorum does NOT normalize: quorum=3 moves the
    # 4-worker fleet but silently freezes the 2-worker one (|2 sign| < 3
    # everywhere), which is exactly the bug the quorum fraction fixes
    init = jax.tree_util.tree_map(
        np.asarray, tiny_model().init(jax.random.PRNGKey(0)))
    for dp, should_move in ((4, True), (2, False)):
        params, _ = run_tiny((dp, 2), comp, 4, batch_of(dp), quorum=3)
        moved = any(not np.array_equal(a, b) for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(init)))
        assert moved == should_move, (dp, moved)
    print("OK M-invariance: legacy quorum=3 froze the 2-worker fleet "
          "(and moved the 4-worker one) — q_frac removes the M-dependence")


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    # --- run A: uninterrupted ---
    mesh, step, state, batch_fn = setup()
    with compat.set_mesh(mesh):
        ref_state, _ = loop_lib.run(step, state, batch_fn,
                                    loop_lib.LoopConfig(total_steps=8, log_every=100))
    # --- run B: checkpoint every 2, die at 5, restart ---
    mesh, step, state, batch_fn = setup()
    cfgB = loop_lib.LoopConfig(total_steps=8, ckpt_dir=CKPT, ckpt_every=2,
                               fail_at_step=5, log_every=100)
    died = False
    try:
        with compat.set_mesh(mesh):
            loop_lib.run(step, state, batch_fn, cfgB)
    except RuntimeError as e:
        died = True
        print("injected failure:", e)
    assert died
    # restart (fresh everything, as after a pod loss)
    mesh, step, state, batch_fn = setup()
    cfgB2 = loop_lib.LoopConfig(total_steps=8, ckpt_dir=CKPT, ckpt_every=2, log_every=100)
    with compat.set_mesh(mesh):
        state_b, _ = loop_lib.run(step, state, batch_fn, cfgB2)
    for pa, pb in zip(jax.tree_util.tree_leaves(ref_state.params),
                      jax.tree_util.tree_leaves(state_b.params)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), "restart diverged"
    print("OK crash/restart: final params bitwise identical to uninterrupted run")

    # --- elastic: restore the checkpoint on a (2, 4) mesh and keep training ---
    mesh2, step2, state2, batch_fn2 = setup(mesh_shape=(2, 4))
    with compat.set_mesh(mesh2):
        state2b, hist = loop_lib.run(step2, state2, batch_fn2,
                                     loop_lib.LoopConfig(total_steps=10, ckpt_dir=CKPT,
                                                         ckpt_every=100, log_every=100))
    assert int(state2b.step) == 10
    assert np.isfinite(hist[-1]["loss"])
    print("OK elastic: resumed 4-worker checkpoint on a 2-worker mesh; loss",
          hist[-1]["loss"])

    # --- elastic participation: parity, chaos, M-invariance ---
    elastic_parity()
    chaos()
    m_invariance()


if __name__ == "__main__":
    main()
