"""Vote-collective equivalence on 8 forced host devices.

Properties (the paper's server sum must not depend on HOW it is carried):
  1. vote_allgather_packed(v) == vote_psum(v)  on a (4 data, 2 model) mesh,
  2. vote_psum_hier == vote_psum               on a (2 pod, 2 data, 2 model) mesh,
  3. both equal a numpy per-worker oracle sum,
  4. worker_index/worker_count enumerate [0, M) in mesh row-major order.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import collectives, compat

SHAPE = (3, 257)  # deliberately unaligned with the pack2bit canonical view


def worker_votes(n_workers, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randint(-1, 2, (n_workers,) + SHAPE).astype(np.int8)


def main():
    assert jax.device_count() == 8, jax.device_count()

    # ---- flat mesh: psum vs packed all-gather vs oracle --------------------
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    votes = worker_votes(4, seed=1)
    stacked = jnp.asarray(votes.reshape(4 * SHAPE[0], SHAPE[1]))

    def body(v):
        n = collectives.worker_count(("data",))
        assert n == 4
        a = collectives.vote_psum(v, ("data",), n)
        b = collectives.vote_allgather_packed(v, ("data",), n)
        i = collectives.worker_index(("data",))
        gi = jax.lax.all_gather(i, ("data",), axis=0)
        return a.astype(jnp.int32), b.astype(jnp.int32), gi

    step = jax.jit(compat.shard_map(
        body, mesh=mesh,
        in_specs=P("data"),
        out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))
    a, b, gi = step(stacked)
    oracle = votes.astype(np.int32).sum(0)
    assert np.array_equal(np.asarray(a), oracle), "psum != oracle"
    assert np.array_equal(np.asarray(b), oracle), "allgather_packed != oracle"
    assert sorted(np.asarray(gi).tolist()) == [0, 1, 2, 3], np.asarray(gi)
    print("OK vote_psum == vote_allgather_packed == oracle (4 workers)")

    # ---- hierarchical mesh: two-level psum vs flat -------------------------
    mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    votes8 = worker_votes(4, seed=2)  # 4 workers = pod x data
    stacked8 = jnp.asarray(votes8.reshape(4 * SHAPE[0], SHAPE[1]))

    def body3(v):
        axes = ("pod", "data")
        n = collectives.worker_count(axes)
        assert n == 4
        flat = collectives.vote_psum(v, axes, n)
        hier = collectives.vote_psum_hier(
            v, "data", "pod",
            collectives.axis_size("data"), collectives.axis_size("pod"))
        packed = collectives.vote_allgather_packed(v, axes, n)
        idx = collectives.worker_index(axes)
        gi = jax.lax.all_gather(idx, axes, axis=0)
        return (flat.astype(jnp.int32), hier.astype(jnp.int32),
                packed.astype(jnp.int32), gi)

    step3 = jax.jit(compat.shard_map(
        body3, mesh=mesh3,
        in_specs=P(("pod", "data")),
        out_specs=(P(), P(), P(), P()),
        axis_names={"pod", "data"}, check_vma=False))
    flat, hier, packed, gi = step3(stacked8)
    oracle8 = votes8.astype(np.int32).sum(0)
    assert np.array_equal(np.asarray(flat), oracle8), "flat psum != oracle"
    assert np.array_equal(np.asarray(hier), np.asarray(flat)), "hier != flat"
    assert np.array_equal(np.asarray(packed), np.asarray(flat)), "packed != flat"
    assert sorted(np.asarray(gi).tolist()) == [0, 1, 2, 3], np.asarray(gi)
    print("OK vote_psum_hier == vote_psum == packed (2x2 pod/data workers)")


if __name__ == "__main__":
    main()
