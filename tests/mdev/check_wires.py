"""Wire-equivalence at the train-step level, on 8 forced host devices.

Mesh (2 pod, 2 data, 2 model), worker_axes=('pod','data') -> M=4 workers, so
all three wires are exercisable in one program. Property: the per-round param
update must not depend on HOW the vote sum is carried — for each mode
(simple, streamed) and each backend (jnp, interpret), the hier and
allgather_packed wires are bitwise-equal to the vote_psum stream of the SAME
mode+backend; and the interpret stream equals the jnp stream (engine
contract), so all 12 combinations collapse onto one oracle.

The packed wire runs the fused compress->pack2bit uplink kernels and the
fused unpack+accumulate decode on the interpret backend — this is the
acceptance check that the fused wire is bitwise-honest end-to-end.

Beyond sparsign, the non-sparsign ternary compressors run the same 3-wire x
2-backend sweep in simple mode: noisy_sign exercises the generic ternary
kernel template on the votes wire, terngrad exercises the scaled_votes wire
(magnitude-shared s_t pmax'd over ('pod','data'), ternary votes + one scalar
on the fabric, mean-server decode). Streamed mode runs the terngrad
scaled_votes sweep too — all four wire modes now run in both train modes.

qsgd8 (the FedCom 8-bit baseline) sweeps its two wires in BOTH modes: the
decoded fp32 psum (vote_impl=psum — the oracle stream) vs the pack8 gather
(vote_impl=allgather_packed: 1 B/coord int8 sign*level payloads + per-worker
f32 scales, fused dequantize-sum). Bitwise equality of a FLOAT sum across
wires holds because every implementation associates the adds in worker-index
order, which is also how the host-platform psum reduces; the pack8 kernel
rounds each decoded product through a VMEM scratch to pin the same rounding
points (see kernels/pack8). On a real TPU pod the psum association is the
runtime's choice, so there this check pins the gather wires against each
other rather than against psum.

sparsign_golomb sweeps the entropy-coded wire in BOTH modes: the int8 psum
(its fall-back wire, and the oracle stream) vs the Golomb/RLE coded gather
(vote_impl=allgather_packed: fused sparsign->coded-byte-stream uplink,
in-kernel decode-sum in strict worker order) — the acceptance check that the
sub-2-bit wire carries the exact same votes.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compat
from repro.configs.registry import get_config
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.models.model import Model
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step
from repro.train.step_streamed import (StreamedStepConfig,
                                       build_streamed_train_step,
                                       fsdp_param_shardings)

AXES = ("pod", "data")
WIRES = ("psum", "hier", "allgather_packed")
BACKENDS = ("jnp", "interpret")


def make_batch(cfg, b, s, key=0):
    rng = np.random.RandomState(key)
    return {
        "inputs": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }


def flat_np(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(np.asarray, tree))]


def check_mode(mode, mesh, model, params, batch, comp, lr, wires=WIRES):
    ref, ref_label = None, None
    for backend in BACKENDS:
        for wire in wires:
            if mode == "simple":
                scfg = TrainStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                                       vote_impl=wire, donate=False, backend=backend)
                step = build_train_step(model, scfg, mesh)
                state = init_state(params, server=comp.server, seed=42)
            else:
                scfg = StreamedStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                                          fsdp_axis="data", vote_impl=wire,
                                          donate=False, backend=backend)
                step = build_streamed_train_step(model, scfg, mesh)
                state = init_state(params, server=comp.server, seed=42)
            with compat.set_mesh(mesh):
                out, metrics = step(state, batch)
            got = flat_np(out.params)
            label = f"{mode}/{wire}/{backend}"
            if ref is None:
                ref, ref_label = got, label
                print(f"  oracle stream: {label} "
                      f"(wire_bytes/device={float(metrics['wire_bytes_per_device']):.0f})")
                continue
            ndiff = sum(int((a != b).sum()) for a, b in zip(got, ref))
            assert ndiff == 0, f"{label} != {ref_label}: {ndiff} coords differ"
            print(f"  OK {label} == {ref_label} bitwise "
                  f"(wire_bytes/device={float(metrics['wire_bytes_per_device']):.0f})")


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    lr = LrSchedule(base=0.01)

    cfg_s = get_config("qwen1.5-4b", smoke=True)
    model_s = Model(cfg_s)
    params_s = model_s.init(jax.random.PRNGKey(0))
    print("simple mode (qwen1.5-4b smoke):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16), comp, lr)
    print("OK simple-mode wires bitwise-equal (3 wires x 2 backends)")

    # non-sparsign ternary compressors: same wire-invariance sweep through the
    # generic ternary kernel template (simple mode)
    for name, server, value in (("noisy_sign", "majority_vote", 0.5),
                                ("terngrad", "mean", 1.0)):
        comp_n = CompressionConfig(compressor=name,
                                   budget=BudgetConfig(kind="fixed", value=value),
                                   server=server)
        print(f"simple mode ({name} / {server}):")
        check_mode("simple", mesh, model_s, params_s,
                   make_batch(cfg_s, 8, 16), comp_n, lr)
        print(f"OK {name} wires bitwise-equal (3 wires x 2 backends)")

    # qsgd8 on the pack8 wire vs its decoded-psum oracle stream (the FedCom
    # 8-bit baseline, Appendix B): vote_impl=psum negotiates the fp32 decoded
    # wire, allgather_packed the 1 B/coord pack8 gather — same round bitwise
    comp_q = CompressionConfig(compressor="qsgd8",
                               budget=BudgetConfig(kind="fixed", value=1.0),
                               server="mean")
    print("simple mode (qsgd8 / mean — decoded-psum oracle vs pack8 gather):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16),
               comp_q, lr, wires=("psum", "allgather_packed"))
    print("OK qsgd8 pack8 wire bitwise-equal to the decoded psum (2 backends)")

    # sparsign_golomb: same Def. 1 compressor, entropy-coded uplink. The psum
    # wire negotiates plain int8 votes (a fabric psum cannot reduce
    # variable-length byte streams — engine.wire_payload_format's fallback)
    # and is the oracle stream; allgather_packed rides the Golomb/RLE coded
    # byte wire (fused sparsign->coded-stream kernel + in-kernel decode-sum
    # on the interpret backend). Bitwise equality across them is the
    # acceptance check that the sub-2-bit wire is lossless end-to-end.
    comp_g = CompressionConfig(
        compressor="sparsign_golomb",
        budget=BudgetConfig(kind="target_sparsity", value=0.05),
        server="majority_vote")
    print("simple mode (sparsign_golomb — int8-psum oracle vs golomb gather):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16),
               comp_g, lr, wires=("psum", "hier", "allgather_packed"))
    print("OK sparsign_golomb wires bitwise-equal (3 wires x 2 backends)")

    cfg_t = get_config("qwen2-moe-a2.7b", smoke=True)
    model_t = Model(cfg_t)
    params_t = model_t.init(jax.random.PRNGKey(0))
    shardings = fsdp_param_shardings(model_t, mesh, "data")
    params_t = jax.tree_util.tree_map(jax.device_put, params_t, shardings)
    print("streamed mode (qwen2-moe-a2.7b smoke, FSDP over data):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16), comp, lr)
    print("OK streamed-mode wires bitwise-equal (3 wires x 2 backends)")

    # streamed mode is no longer pinned to vote servers: the terngrad
    # scaled_votes wire (integer votes + ONE shared scale, mean decode on the
    # FSDP shard) and the qsgd8 pack8/decoded wires run the same sweeps
    comp_tg = CompressionConfig(compressor="terngrad",
                                budget=BudgetConfig(kind="fixed", value=1.0),
                                server="mean")
    print("streamed mode (terngrad / mean — scaled_votes):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_tg, lr)
    print("OK streamed terngrad scaled_votes wires bitwise-equal "
          "(3 wires x 2 backends)")

    print("streamed mode (qsgd8 / mean — decoded-psum oracle vs pack8 gather):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_q, lr, wires=("psum", "allgather_packed"))
    print("OK streamed qsgd8 pack8 wire bitwise-equal to the decoded psum "
          "(2 backends)")

    print("streamed mode (sparsign_golomb — int8-psum oracle vs golomb gather):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_g, lr, wires=("psum", "allgather_packed"))
    print("OK streamed sparsign_golomb golomb wire bitwise-equal to the int8 "
          "psum (2 backends)")


if __name__ == "__main__":
    main()
