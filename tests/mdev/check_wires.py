"""Wire-equivalence at the train-step level, on 8 forced host devices.

Mesh (2 pod, 2 data, 2 model), worker_axes=('pod','data') -> M=4 workers, so
all three wires are exercisable in one program. Property: the per-round param
update must not depend on HOW the vote sum is carried — for each mode
(simple, streamed) and each backend (jnp, interpret), the hier and
allgather_packed wires are bitwise-equal to the vote_psum stream of the SAME
mode+backend; and the interpret stream equals the jnp stream (engine
contract), so all 12 combinations collapse onto one oracle.

The packed wire runs the fused compress->pack2bit uplink kernels and the
fused unpack+accumulate decode on the interpret backend — this is the
acceptance check that the fused wire is bitwise-honest end-to-end.

Beyond sparsign, the non-sparsign ternary compressors run the same 3-wire x
2-backend sweep in simple mode: noisy_sign exercises the generic ternary
kernel template on the votes wire, terngrad exercises the scaled_votes wire
(magnitude-shared s_t pmax'd over ('pod','data'), ternary votes + one scalar
on the fabric, mean-server decode). Streamed mode runs the terngrad
scaled_votes sweep too — all four wire modes now run in both train modes.

qsgd8 (the FedCom 8-bit baseline) sweeps its two wires in BOTH modes: the
decoded fp32 psum (vote_impl=psum — the oracle stream) vs the pack8 gather
(vote_impl=allgather_packed: 1 B/coord int8 sign*level payloads + per-worker
f32 scales, fused dequantize-sum). Bitwise equality of a FLOAT sum across
wires holds because every implementation associates the adds in worker-index
order, which is also how the host-platform psum reduces; the pack8 kernel
rounds each decoded product through a VMEM scratch to pin the same rounding
points (see kernels/pack8). On a real TPU pod the psum association is the
runtime's choice, so there this check pins the gather wires against each
other rather than against psum.

sparsign_golomb sweeps the entropy-coded wire in BOTH modes: the int8 psum
(its fall-back wire, and the oracle stream) vs the Golomb/RLE coded gather
(vote_impl=allgather_packed: fused sparsign->coded-byte-stream uplink,
in-kernel decode-sum in strict worker order) — the acceptance check that the
sub-2-bit wire carries the exact same votes.

The ring-pipelined gather (ring_chunk_rows set on the allgather_packed
configs) re-runs the gather-wire streams with the payload chunked around the
M-hop ppermute ring instead of one monolithic all_gather. The integer wires
(pack2, golomb) accumulate int32 chunk sums — addition commutes exactly, so
the ring stream is BITWISE the monolithic one. The pack8 wire sums f32
dequantized chunks in ring-arrival order (self, rank-1, rank-2, ...), a
different association than the monolithic worker-order decode — the ring
stream is run-twice deterministic and allclose, not bitwise (same caveat
class as TPU psum association, see ROADMAP). Bucketed ring configs chunk the
multi-leaf bucket buffers, exercising genuinely multi-chunk rings at
RING_CHUNK_ROWS=32.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compat
from repro.configs.registry import get_config
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.models.model import Model
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step
from repro.train.step_streamed import (StreamedStepConfig,
                                       build_streamed_train_step,
                                       fsdp_param_shardings)

AXES = ("pod", "data")
WIRES = ("psum", "hier", "allgather_packed")
BACKENDS = ("jnp", "interpret")
RING_CHUNK_ROWS = 32   # smallest legal chunk -> forces multi-chunk rings on
                       # the bucketed plans (per-leaf smoke leaves fit in one)


def make_batch(cfg, b, s, key=0):
    rng = np.random.RandomState(key)
    return {
        "inputs": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }


def flat_np(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(np.asarray, tree))]


def check_mode(mode, mesh, model, params, batch, comp, lr, wires=WIRES):
    ref, ref_label = None, None
    for backend in BACKENDS:
        for wire in wires:
            if mode == "simple":
                scfg = TrainStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                                       vote_impl=wire, donate=False, backend=backend)
                step = build_train_step(model, scfg, mesh)
                state = init_state(params, server=comp.server, seed=42)
            else:
                scfg = StreamedStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                                          fsdp_axis="data", vote_impl=wire,
                                          donate=False, backend=backend)
                step = build_streamed_train_step(model, scfg, mesh)
                state = init_state(params, server=comp.server, seed=42)
            with compat.set_mesh(mesh):
                out, metrics = step(state, batch)
            got = flat_np(out.params)
            label = f"{mode}/{wire}/{backend}"
            if ref is None:
                ref, ref_label = got, label
                print(f"  oracle stream: {label} "
                      f"(wire_bytes/device={float(metrics['wire_bytes_per_device']):.0f})")
                continue
            ndiff = sum(int((a != b).sum()) for a, b in zip(got, ref))
            assert ndiff == 0, f"{label} != {ref_label}: {ndiff} coords differ"
            print(f"  OK {label} == {ref_label} bitwise "
                  f"(wire_bytes/device={float(metrics['wire_bytes_per_device']):.0f})")


def _build(mode, mesh, model, comp, lr, backend, *, ring=None, bucketed=False):
    if mode == "simple":
        scfg = TrainStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                               vote_impl="allgather_packed", donate=False,
                               backend=backend, bucketed=bucketed,
                               ring_chunk_rows=ring)
        return build_train_step(model, scfg, mesh)
    scfg = StreamedStepConfig(compression=comp, lr=lr, worker_axes=AXES,
                              fsdp_axis="data", vote_impl="allgather_packed",
                              donate=False, backend=backend, bucketed=bucketed,
                              ring_chunk_rows=ring)
    return build_streamed_train_step(model, scfg, mesh)


def check_ring(mode, mesh, model, params, batch, comp, lr, *,
               equality="bitwise", bucketed=False):
    """Ring-pipelined gather vs the monolithic all_gather, same mode+backend.

    equality="bitwise" for the integer wires (pack2, golomb: int32 chunk adds
    commute); "allclose" for pack8 (f32 sums associate in ring-arrival order
    — deterministic, pinned by a second execution, but not bitwise vs the
    worker-order monolithic decode)."""
    for backend in BACKENDS:
        outs = []
        for ring in (None, RING_CHUNK_ROWS):
            step = _build(mode, mesh, model, comp, lr, backend,
                          ring=ring, bucketed=bucketed)
            state = init_state(params, server=comp.server, seed=42)
            with compat.set_mesh(mesh):
                out, metrics = step(state, batch)
            if ring is not None:
                # run-twice determinism of the ring stream
                state2 = init_state(params, server=comp.server, seed=42)
                with compat.set_mesh(mesh):
                    out2, _ = step(state2, batch)
                nd = sum(int((a != b).sum()) for a, b in
                         zip(flat_np(out.params), flat_np(out2.params)))
                assert nd == 0, \
                    f"{mode}/ring/{backend} nondeterministic: {nd} coords"
            outs.append((flat_np(out.params), metrics))
        (mono, mm), (ringed, rm) = outs
        hbm = (float(mm["gather_hbm_bytes"]), float(rm["gather_hbm_bytes"]))
        assert hbm[1] <= hbm[0], hbm
        label = f"{mode}{'/bucketed' if bucketed else ''}/ring/{backend}"
        if equality == "bitwise":
            nd = sum(int((a != b).sum()) for a, b in zip(ringed, mono))
            assert nd == 0, f"{label} != monolithic: {nd} coords differ"
            rel = "bitwise =="
        else:
            for a, b in zip(ringed, mono):
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)
            rel = "allclose ~="
        print(f"  OK {label} {rel} monolithic "
              f"(gather_hbm {hbm[0]:.0f} -> {hbm[1]:.0f} B)")


def check_ring_permute_fallback(mesh):
    """ring_permute over the 2-axis worker group: the tuple-axis ppermute and
    the old-jax nested fallback (compat.HAS_TUPLE_PPERMUTE=False) must both
    rotate the flat worker ring by one."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import collectives, compat as _compat

    x = np.arange(4 * 8, dtype=np.int32).reshape(4, 8)
    expect = np.roll(x, 1, axis=0)   # worker w receives worker w-1's slice

    def run():
        def f(v):
            return collectives.ring_permute(v, AXES)
        g = compat.shard_map(f, mesh=mesh, in_specs=P(AXES),
                             out_specs=P(AXES),
                             axis_names=set(AXES), check_vma=False)
        with compat.set_mesh(mesh):
            return np.asarray(g(jnp.asarray(x)))

    np.testing.assert_array_equal(run(), expect)
    orig = _compat.HAS_TUPLE_PPERMUTE
    _compat.HAS_TUPLE_PPERMUTE = False
    try:
        np.testing.assert_array_equal(run(), expect)
    finally:
        _compat.HAS_TUPLE_PPERMUTE = orig
    print("  OK ring_permute tuple-axis == nested single-axis fallback")


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    lr = LrSchedule(base=0.01)

    cfg_s = get_config("qwen1.5-4b", smoke=True)
    model_s = Model(cfg_s)
    params_s = model_s.init(jax.random.PRNGKey(0))
    print("simple mode (qwen1.5-4b smoke):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16), comp, lr)
    print("OK simple-mode wires bitwise-equal (3 wires x 2 backends)")

    # non-sparsign ternary compressors: same wire-invariance sweep through the
    # generic ternary kernel template (simple mode)
    for name, server, value in (("noisy_sign", "majority_vote", 0.5),
                                ("terngrad", "mean", 1.0)):
        comp_n = CompressionConfig(compressor=name,
                                   budget=BudgetConfig(kind="fixed", value=value),
                                   server=server)
        print(f"simple mode ({name} / {server}):")
        check_mode("simple", mesh, model_s, params_s,
                   make_batch(cfg_s, 8, 16), comp_n, lr)
        print(f"OK {name} wires bitwise-equal (3 wires x 2 backends)")

    # qsgd8 on the pack8 wire vs its decoded-psum oracle stream (the FedCom
    # 8-bit baseline, Appendix B): vote_impl=psum negotiates the fp32 decoded
    # wire, allgather_packed the 1 B/coord pack8 gather — same round bitwise
    comp_q = CompressionConfig(compressor="qsgd8",
                               budget=BudgetConfig(kind="fixed", value=1.0),
                               server="mean")
    print("simple mode (qsgd8 / mean — decoded-psum oracle vs pack8 gather):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16),
               comp_q, lr, wires=("psum", "allgather_packed"))
    print("OK qsgd8 pack8 wire bitwise-equal to the decoded psum (2 backends)")

    # sparsign_golomb: same Def. 1 compressor, entropy-coded uplink. The psum
    # wire negotiates plain int8 votes (a fabric psum cannot reduce
    # variable-length byte streams — engine.wire_payload_format's fallback)
    # and is the oracle stream; allgather_packed rides the Golomb/RLE coded
    # byte wire (fused sparsign->coded-stream kernel + in-kernel decode-sum
    # on the interpret backend). Bitwise equality across them is the
    # acceptance check that the sub-2-bit wire is lossless end-to-end.
    comp_g = CompressionConfig(
        compressor="sparsign_golomb",
        budget=BudgetConfig(kind="target_sparsity", value=0.05),
        server="majority_vote")
    print("simple mode (sparsign_golomb — int8-psum oracle vs golomb gather):")
    check_mode("simple", mesh, model_s, params_s, make_batch(cfg_s, 8, 16),
               comp_g, lr, wires=("psum", "hier", "allgather_packed"))
    print("OK sparsign_golomb wires bitwise-equal (3 wires x 2 backends)")

    # ring-pipelined gather vs the monolithic all_gather (simple mode): the
    # integer wires pin bitwise, pack8 pins deterministic + allclose; the
    # bucketed variants chunk the multi-leaf bucket buffers (multi-chunk ring)
    print("ring_permute old-jax fallback:")
    check_ring_permute_fallback(mesh)
    batch_s = make_batch(cfg_s, 8, 16)
    print("simple mode ring (sparsign pack2):")
    check_ring("simple", mesh, model_s, params_s, batch_s, comp, lr)
    check_ring("simple", mesh, model_s, params_s, batch_s, comp, lr,
               bucketed=True)
    print("simple mode ring (qsgd8 pack8):")
    check_ring("simple", mesh, model_s, params_s, batch_s, comp_q, lr,
               equality="allclose")
    check_ring("simple", mesh, model_s, params_s, batch_s, comp_q, lr,
               equality="allclose", bucketed=True)
    print("simple mode ring (sparsign_golomb):")
    check_ring("simple", mesh, model_s, params_s, batch_s, comp_g, lr)
    check_ring("simple", mesh, model_s, params_s, batch_s, comp_g, lr,
               bucketed=True)
    print("OK simple-mode ring == monolithic (3 wires x 2 backends, "
          "per-leaf + bucketed)")

    cfg_t = get_config("qwen2-moe-a2.7b", smoke=True)
    model_t = Model(cfg_t)
    params_t = model_t.init(jax.random.PRNGKey(0))
    shardings = fsdp_param_shardings(model_t, mesh, "data")
    params_t = jax.tree_util.tree_map(jax.device_put, params_t, shardings)
    print("streamed mode (qwen2-moe-a2.7b smoke, FSDP over data):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16), comp, lr)
    print("OK streamed-mode wires bitwise-equal (3 wires x 2 backends)")

    # streamed mode is no longer pinned to vote servers: the terngrad
    # scaled_votes wire (integer votes + ONE shared scale, mean decode on the
    # FSDP shard) and the qsgd8 pack8/decoded wires run the same sweeps
    comp_tg = CompressionConfig(compressor="terngrad",
                                budget=BudgetConfig(kind="fixed", value=1.0),
                                server="mean")
    print("streamed mode (terngrad / mean — scaled_votes):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_tg, lr)
    print("OK streamed terngrad scaled_votes wires bitwise-equal "
          "(3 wires x 2 backends)")

    print("streamed mode (qsgd8 / mean — decoded-psum oracle vs pack8 gather):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_q, lr, wires=("psum", "allgather_packed"))
    print("OK streamed qsgd8 pack8 wire bitwise-equal to the decoded psum "
          "(2 backends)")

    print("streamed mode (sparsign_golomb — int8-psum oracle vs golomb gather):")
    check_mode("streamed", mesh, model_t, params_t, make_batch(cfg_t, 8, 16),
               comp_g, lr, wires=("psum", "allgather_packed"))
    print("OK streamed sparsign_golomb golomb wire bitwise-equal to the int8 "
          "psum (2 backends)")

    # streamed-mode ring sweep (per-leaf, plus one bucketed double-buffered
    # config — the bucketed scan exchanges ride the same wire.exchange_bucket)
    batch_t = make_batch(cfg_t, 8, 16)
    print("streamed mode ring (sparsign pack2):")
    check_ring("streamed", mesh, model_t, params_t, batch_t, comp, lr)
    check_ring("streamed", mesh, model_t, params_t, batch_t, comp, lr,
               bucketed=True)
    print("streamed mode ring (qsgd8 pack8):")
    check_ring("streamed", mesh, model_t, params_t, batch_t, comp_q, lr,
               equality="allclose")
    print("streamed mode ring (sparsign_golomb):")
    check_ring("streamed", mesh, model_t, params_t, batch_t, comp_g, lr)
    print("OK streamed-mode ring == monolithic (3 wires x 2 backends)")


if __name__ == "__main__":
    main()
