"""Streamed-mode checks on 8 host devices:
1. streamed(majority_vote) == simple(majority_vote) — same algorithm bit-for-bit
   (identical seeds/counters), modulo float-assoc grad differences.
2. FSDP layout: params actually sharded (per-device bytes < full size).
3. EF server variant runs.
4. bucketed + double-buffered streamed step == per-leaf streamed step bitwise,
   all four wire modes x {jnp, interpret} backends (the comm/compute-overlap
   pipeline must be a pure re-scheduling of the same arithmetic).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat
from repro.configs.registry import get_config
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.models.model import Model
from repro.train.state import LrSchedule, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step
from repro.train.step_streamed import StreamedStepConfig, build_streamed_train_step, fsdp_param_shardings

def make_batch(cfg, b, s, key=0):
    rng = np.random.RandomState(key)
    return {
        "inputs": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }

def main():
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, b=8, s=16)
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    lr = LrSchedule(base=0.01)

    # --- simple reference ---
    s_simple = init_state(params, server=comp.server, seed=42)
    step_simple = build_train_step(model, TrainStepConfig(
        compression=comp, lr=lr, worker_axes=("data",), donate=False), mesh)
    with compat.set_mesh(mesh):
        out_simple, m_simple = step_simple(s_simple, batch)
    ref = jax.tree_util.tree_map(np.asarray, out_simple.params)

    # --- streamed ---
    shardings = fsdp_param_shardings(model, mesh, "data")
    params_sh = jax.tree_util.tree_map(jax.device_put, params, shardings)
    s_str = init_state(params_sh, server=comp.server, seed=42)
    step_str = build_streamed_train_step(model, StreamedStepConfig(
        compression=comp, lr=lr, worker_axes=("data",), fsdp_axis="data", donate=False), mesh)
    with compat.set_mesh(mesh):
        out_str, m_str = step_str(s_str, batch)
    got = jax.tree_util.tree_map(np.asarray, out_str.params)

    total, ndiff = 0, 0
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref)[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        total += a.size
        d = int((a != b).sum())
        ndiff += d
        if d: print("  diff in", jax.tree_util.keystr(pa), d)
    frac = ndiff / total
    print(f"streamed vs simple: {ndiff}/{total} coords differ ({frac:.2e})")
    assert frac < 1e-4, frac
    print("loss simple vs streamed:", float(m_simple["loss"]), float(m_str["loss"]))
    assert abs(float(m_simple["loss"]) - float(m_str["loss"])) < 1e-4

    # sharded bytes check
    blk = out_str.params["blocks"][0]["wq"]
    shard_bytes = blk.addressable_shards[0].data.size
    assert shard_bytes < blk.size, "wq not FSDP-sharded"
    print("OK FSDP sharding: wq local", shard_bytes, "of", blk.size)

    # EF variant
    comp_ef = CompressionConfig(compressor="sparsign", budget=BudgetConfig(kind="fixed", value=2.0),
                                server="scaled_sign_ef")
    s_ef = init_state(params_sh, server=comp_ef.server, seed=7)
    # ef residual must be sharded like params
    ef_shardings = jax.tree_util.tree_map(lambda s: s, shardings)
    s_ef.ef_residual = jax.tree_util.tree_map(
        lambda p, sh: jax.device_put(jnp.zeros(p.shape, jnp.float32), sh), params_sh, ef_shardings)
    step_ef = build_streamed_train_step(model, StreamedStepConfig(
        compression=comp_ef, lr=lr, worker_axes=("data",), donate=False), mesh)
    with compat.set_mesh(mesh):
        o1, m1 = step_ef(s_ef, batch)
        o2, m2 = step_ef(o1, batch)
    assert np.isfinite(float(m2["loss"]))
    efn = sum(float(jnp.sum(x.astype(jnp.float32)**2)) for x in jax.tree_util.tree_leaves(o2.ef_residual))
    assert np.isfinite(efn) and efn > 0
    print("OK streamed EF 2 rounds, loss:", float(m2["loss"]), "resid sq:", efn)

    # --- bucketed + double-buffered == per-leaf, all wire setups x 2 backends
    # (mode_comp picks each setup's budget kind: the golomb setup needs a
    # target_sparsity budget to size the wire's static capacity)
    from repro.analysis.drivers import MODE_SETUPS, mode_comp
    for wmode, (_, server, vote_impl, _) in MODE_SETUPS.items():
        comp_w = mode_comp(wmode)
        for backend in ("jnp", "interpret"):
            ref = None
            for bucketed in (False, True):
                step = build_streamed_train_step(model, StreamedStepConfig(
                    compression=comp_w, lr=lr, worker_axes=("data",),
                    fsdp_axis="data", vote_impl=vote_impl, donate=False,
                    backend=backend, bucketed=bucketed), mesh)
                st = init_state(params_sh, server=comp_w.server, seed=42)
                with compat.set_mesh(mesh):
                    out, m = step(st, batch)
                got = jax.tree_util.tree_leaves(
                    jax.tree_util.tree_map(np.asarray, out.params))
                got.append(np.asarray(m["nnz_frac"]))
                if ref is None:
                    ref = got
                    continue
                nd = sum(int((a != b).sum()) for a, b in zip(got, ref))
                assert nd == 0, f"{wmode}/{backend}: {nd} coords differ"
            print(f"OK streamed bucketed == per-leaf bitwise: {wmode}/{backend}")

if __name__ == "__main__":
    main()
