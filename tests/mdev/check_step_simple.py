"""Multi-device equivalence check for the simple-mode train step.

Runs on 8 host CPU devices: mesh (4 data, 2 model). Asserts the mesh train_step
update equals an explicit M=4-worker oracle (same seeds, same counters) built
with plain vmap on a single logical device view.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compat
from repro.configs.registry import get_config
from repro.core import prng
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.core.compressors import get_compressor
from repro.models.model import Model
from repro.train import sampling
from repro.train.state import LrSchedule, TrainState, init_state
from repro.train.step_simple import TrainStepConfig, build_train_step

def make_batch(cfg, b, s, key=0):
    rng = np.random.RandomState(key)
    return {
        "inputs": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }

def oracle_step(model, params, batch, comp, lr, n_workers, seed):
    """Explicit per-worker reference (no mesh)."""
    state_step = jnp.int32(0)
    rseed = sampling.round_seed(jnp.uint32(seed), state_step)
    fn = get_compressor(comp.compressor)
    loss_fn = lambda p, b: model.loss(p, b)[0]
    # split batch into worker microbatches
    def worker_grads(w):
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n_workers, -1) + x.shape[1:])[w], batch)
        return jax.grad(loss_fn)(params, micro)
    leaves0, treedef = jax.tree_util.tree_flatten(params)
    vote_sums = [jnp.zeros(l.shape, jnp.int32) for l in leaves0]
    for w in range(n_workers):
        grads = worker_grads(w)
        wseed = prng.fold_seed(rseed, 0x5EED) + jnp.uint32(w) * jnp.uint32(0x9E3779B9)
        gl = jax.tree_util.tree_flatten(grads)[0]
        for i, g in enumerate(gl):
            seed_i = prng.fold_seed(wseed, i)
            msg = fn(g, budget=jnp.float32(comp.budget.value), seed=seed_i, counter_base=0)
            vote_sums[i] = vote_sums[i] + msg.values.astype(jnp.int32)
    new_leaves = [
        (p.astype(jnp.float32) - lr * jnp.sign(v).astype(jnp.float32)).astype(p.dtype)
        for p, v in zip(leaves0, vote_sums)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)

def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen1.5-4b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    lr_sched = LrSchedule(base=0.01)
    scfg = TrainStepConfig(compression=comp, lr=lr_sched, worker_axes=("data",), donate=False)
    step = build_train_step(model, scfg, mesh)
    state = init_state(params, server=comp.server, seed=1234)
    batch = make_batch(cfg, b=8, s=16)

    with compat.set_mesh(mesh):
        new_state, metrics = step(state, batch)
    got = jax.tree_util.tree_map(np.asarray, new_state.params)
    want = jax.tree_util.tree_map(np.asarray, oracle_step(model, params, batch, comp, 0.01, 4, 1234))
    flat_g, _ = jax.tree_util.tree_flatten(got)
    flat_w, _ = jax.tree_util.tree_flatten(want)
    n_diff_total = 0
    for a, b in zip(flat_g, flat_w):
        if not np.array_equal(a, b):
            n_diff_total += int((a != b).sum())
    # bf16/f32 grad bit-level nondeterminism across shardings could flip marginal
    # Bernoulli outcomes; with f32 smoke config updates must match exactly.
    assert n_diff_total == 0, f"{n_diff_total} mismatched coordinates"
    print("OK simple-step == 4-worker oracle (majority vote, sparsign)")
    print("metrics:", {k: float(v) for k, v in metrics.items()})

    # engine backend check: the same step built on the Pallas kernels
    # (interpret mode on CPU) must match the jnp-backend oracle bitwise —
    # the oracle above is the pre-refactor reference stream (raw compressors,
    # no engine), so this pins kernels == engine == pre-refactor in one shot.
    scfg_i = TrainStepConfig(compression=comp, lr=lr_sched, worker_axes=("data",),
                             donate=False, backend="interpret")
    step_i = build_train_step(model, scfg_i, mesh)
    with compat.set_mesh(mesh):
        st_i, _ = step_i(state, batch)
    flat_i = jax.tree_util.tree_flatten(
        jax.tree_util.tree_map(np.asarray, st_i.params))[0]
    ndiff_i = sum(int((a != b).sum()) for a, b in zip(flat_i, flat_w))
    assert ndiff_i == 0, f"interpret backend: {ndiff_i} mismatched coordinates"
    print("OK engine interpret backend == pre-refactor oracle (bitwise)")

    # EF server variant runs + residual finite
    comp2 = CompressionConfig(compressor="sparsign", budget=BudgetConfig(kind="fixed", value=2.0),
                              server="scaled_sign_ef")
    scfg2 = TrainStepConfig(compression=comp2, lr=lr_sched, worker_axes=("data",), donate=False)
    step2 = build_train_step(model, scfg2, mesh)
    state2 = init_state(params, server=comp2.server, seed=99)
    with compat.set_mesh(mesh):
        s2, m2 = step2(state2, batch)
        s2, m2 = step2(s2, batch)
    efn = sum(float(jnp.sum(x**2)) for x in jax.tree_util.tree_leaves(s2.ef_residual))
    assert np.isfinite(efn) and efn > 0
    print("OK EF server 2 rounds, residual sq-norm:", efn)

    # local steps (tau=2) path compiles + runs
    comp3 = CompressionConfig(compressor="sparsign", budget=BudgetConfig(kind="fixed", value=1.0),
                              server="scaled_sign_ef", local_steps=2, local_budget=10.0)
    scfg3 = TrainStepConfig(compression=comp3, lr=lr_sched, local_lr=0.01, worker_axes=("data",), donate=False)
    step3 = build_train_step(model, scfg3, mesh)
    state3 = init_state(params, server=comp3.server, seed=7)
    tb = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), batch)  # tau leading axis
    with compat.set_mesh(mesh):
        s3, m3 = step3(state3, tb)
    assert np.isfinite(float(m3["loss"]))
    print("OK local-update (tau=2) EF-SPARSIGNSGD step, loss:", float(m3["loss"]))

if __name__ == "__main__":
    main()
