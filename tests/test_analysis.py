"""The repro.analysis subsystem: jaxpr walker descent (incl. the historical
custom_vjp blind spot), collective-census byte math (ppermute ring hops
included), the census==ledger acceptance pin over every wire mode (monolithic
AND ring-pipelined), the gather peak-HBM floor, the HLO agreement pass,
dtype-promotion drift, and the AST repo-lint (unit cases + repo-green + the
zero-entry allowlist pin).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import drivers
from repro.analysis.framework import Finding, Report, merge, report
from repro.analysis.hlo_audit import HloJaxprAgreement
from repro.analysis.jaxpr_audit import (CollectiveCensus, DtypePromotionDrift,
                                        NoHbmIntermediate, check_fused_uplink,
                                        collective_census, hbm_elems)
from repro.analysis.repolint import (ALLOWLIST, SpecsComplete, lint_source,
                                     run_repolint)


# ---------------------------------------------------------------------------
# framework
# ---------------------------------------------------------------------------

def test_report_exit_codes_and_render():
    ok = report([], checks=3)
    assert ok.ok and ok.exit_code() == 0 and "OK: 3 checks" in ok.render()
    f = Finding(rule="r", where="w", message="m")
    bad = report([f], checks=1)
    assert not bad.ok and bad.exit_code() == 1
    note = Finding(rule="r", where="w", message="m", severity="info")
    advisory = report([note], checks=1)
    assert advisory.ok and advisory.exit_code() == 0
    merged = merge([ok, bad, advisory])
    assert merged.checks == 5 and len(merged.findings) == 2
    assert not merged.ok


def test_finding_rejects_unknown_severity():
    with pytest.raises(AssertionError):
        Finding(rule="r", where="w", message="m", severity="warning")


# ---------------------------------------------------------------------------
# jaxpr walker descent
# ---------------------------------------------------------------------------

def test_walker_descends_custom_vjp():
    """Regression for the old hbm_elems blind spot: an int8 intermediate
    hidden inside a jax.custom_vjp body must still be counted."""
    @jax.custom_vjp
    def f(x):
        v = jnp.where(x > 0, 1, -1).astype(jnp.int8)   # hidden int8 tensor
        return x * v.astype(jnp.float32)

    def fwd(x):
        return f(x), jnp.sign(x)

    def bwd(res, g):
        return (g * res,)

    f.defvjp(fwd, bwd)
    x = jnp.ones((256,), jnp.float32)
    assert hbm_elems(f, x, dtype=jnp.int8) >= 256


@pytest.mark.parametrize("n", [63, 256, 1000])
def test_walker_descends_scan_while_pjit(n):
    """int8 tensors inside scan and while bodies, under a jit (pjit eqn),
    are all visible to the walker — for any leaf size."""
    @jax.jit
    def prog(x):
        def sbody(c, _):
            t = jnp.sign(c).astype(jnp.int8)
            return c + t.astype(jnp.float32), t
        c, ts = jax.lax.scan(sbody, x, None, length=3)

        def wcond(s):
            return s[1] < 2

        def wbody(s):
            y, i = s
            u = jnp.sign(y).astype(jnp.int8)
            return y + u.astype(jnp.float32), i + 1

        y, _ = jax.lax.while_loop(wcond, wbody, (c, 0))
        return y + ts.astype(jnp.float32).sum(0)

    x = jnp.ones((n,), jnp.float32)
    assert hbm_elems(prog, x, dtype=jnp.int8) >= 2 * n


def test_walker_excludes_pallas_body():
    """int8 values inside a pallas_call kernel body live in VMEM registers,
    not HBM — the walker must not count them."""
    import jax.experimental.pallas as pl

    def kern(x_ref, o_ref):
        t = x_ref[...].astype(jnp.int8)
        o_ref[...] = t.astype(jnp.float32)

    def op(x):
        return pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            interpret=True)(x)

    x = jnp.ones((8, 128), jnp.float32)
    assert hbm_elems(op, x, dtype=jnp.int8) == 0


def test_no_hbm_intermediate_limit_semantics():
    rule0 = NoHbmIntermediate(jnp.int8)
    rule_n = NoHbmIntermediate(jnp.int8, limit=128)
    fn = lambda x: jnp.sign(x).astype(jnp.int8).astype(jnp.float32)
    x = jnp.ones((128,), jnp.float32)
    assert len(rule0.check("lab", fn, x)) == 1        # 128 > 0
    assert rule_n.check("lab", fn, x) == []           # 128 <= 128


# ---------------------------------------------------------------------------
# collective census byte math (synthetic shard_map program)
# ---------------------------------------------------------------------------

def test_census_byte_math_on_shard_map_program():
    from repro.dist import compat
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    n = 1024

    def body(v, s):
        tot = jax.lax.psum(v, ("data",))                       # int8 payload
        mx = jax.lax.pmax(s, ("data",))                        # f32 scalar
        g = jax.lax.all_gather(v, ("data",), axis=0, tiled=False)
        return tot, mx, g

    fn = compat.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                          out_specs=(P(), P(), P()), check_vma=False)
    closed = jax.make_jaxpr(fn)(jnp.zeros((n,), jnp.int8),
                                jnp.zeros((), jnp.float32))
    census = collective_census(closed)
    assert census.counts() == {"psum": 1, "pmax": 1, "all_gather": 1}
    m = 8
    sizes = {"data": m}
    # psum all-reduce 2(m-1)/m * n B + all-gather (m-1) * n B
    assert census.payload_bytes(sizes) == pytest.approx(
        2 * (m - 1) / m * n + (m - 1) * n)
    assert census.scalar_bytes(sizes) == pytest.approx(2 * (m - 1) / m * 4)
    # degenerate group: every ring term vanishes
    assert census.total_bytes({"data": 1}) == 0.0

    rule = CollectiveCensus(axis_sizes=sizes)
    ok = rule.check("prog", census,
                    ledger_payload=2 * (m - 1) / m * n + (m - 1) * n,
                    ledger_scalar_min=2 * (m - 1) / m * 4)
    assert ok == []
    bad = rule.check("prog", census, ledger_payload=12345.0,
                     ledger_scalar_min=1e9)
    assert len(bad) == 2


# ---------------------------------------------------------------------------
# ppermute ring math, unknown-collective loudness, gather-HBM floor
# ---------------------------------------------------------------------------

def test_census_ppermute_ring_math():
    """ONE traced ppermute (the ring gather's hop primitive, while-looped at
    trips=1) bills as an (M-1)-hop ring of its operand."""
    from repro.analysis.jaxpr_audit import CollectiveRecord
    from repro.dist import collectives, compat
    from repro.launch.mesh import make_host_mesh

    rec = CollectiveRecord(primitive="ppermute", axes=("data",),
                           in_elems=2048, in_bytes=2048, out_bytes=2048)
    assert rec.ring_bytes({"data": 16}) == pytest.approx(15 * 2048)
    assert rec.ring_bytes({"data": 1}) == 0.0

    # and the traced program agrees: the sanctioned wrapper emits exactly one
    # ppermute eqn, billed at (m-1) x operand bytes
    mesh = make_host_mesh(1, 1)
    P = jax.sharding.PartitionSpec
    n = 2048
    fn = compat.shard_map(lambda v: collectives.ring_permute(v, ("data",)),
                          mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
    census = collective_census(jax.make_jaxpr(fn)(jnp.zeros((n,), jnp.int8)))
    assert census.counts() == {"ppermute": 1}
    assert census.payload_bytes({"data": 16}) == pytest.approx(15 * n)
    assert census.total_bytes({"data": 1}) == 0.0


def test_census_unknown_collective_blocks():
    """A payload-carrying named-axis equation the byte model does not cover
    must surface as a blocking finding — never a silent zero-byte bill."""
    from repro.analysis.jaxpr_audit import Census, CollectiveRecord

    mystery = CollectiveRecord(primitive="all_to_all_v", axes=("data",),
                               in_elems=512, in_bytes=512, out_bytes=512)
    census = Census(records=(), unknown=(mystery,))
    rule = CollectiveCensus(axis_sizes={"data": 16})
    findings = rule.check("prog", census, ledger_payload=0.0)
    assert any("does not cover" in f.message and "all_to_all_v" in f.message
               for f in findings)
    assert all(f.severity == "error" for f in findings)
    # unknowns are excluded from every byte sum — that's WHY the rule blocks
    assert census.payload_bytes({"data": 16}) == 0.0


def test_gather_hbm_budget_math():
    from repro.analysis.jaxpr_audit import GatherHbmBudget

    rule = GatherHbmBudget(min_ratio=8.0)
    # monolithic M x payload vs a 2-chunk ring at M=16: ratio 8x, at the floor
    assert rule.check("x", ring_bytes=2 * 4096.0,
                      mono_bytes=16 * 4096.0) == []
    bad = rule.check("x", ring_bytes=3 * 4096.0, mono_bytes=16 * 4096.0)
    assert len(bad) == 1 and "under the 8.0x floor" in bad[0].message


def test_gather_hbm_checks_green():
    """The blocking M/2 peak-HBM floor holds on every stacked-block config,
    every ring setup, per-leaf and bucketed — the acceptance criterion."""
    findings, checks = drivers.gather_hbm_checks()
    assert findings == [], "\n".join(f.render() for f in findings)
    assert checks == len(drivers.RATIO_CONFIGS) * len(drivers.RING_SETUPS) * 2


# ---------------------------------------------------------------------------
# the acceptance pin: step census == VoteWire ledger, all wire modes
# (monolithic AND ring-pipelined exchange strategies)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode",
                         list(drivers.MODE_SETUPS) + list(drivers.RING_SETUPS))
def test_step_census_matches_wire_ledger(mode):
    findings, census, payload, scalar = drivers.census_check(mode)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert payload > 0  # non-vacuous: the hypothetical-M ring terms are real
    assert census.payload_bytes({"data": drivers.HYPOTHETICAL_M}) == \
        pytest.approx(payload)


# ---------------------------------------------------------------------------
# per-spec fused-uplink rules + dtype promotion drift
# ---------------------------------------------------------------------------

def test_every_fused_spec_passes_its_declared_hbm_rules():
    from repro.core.compressors import SPECS
    g = jnp.asarray(np.random.RandomState(3).randn(2048), jnp.float32)
    ran = 0
    for spec in SPECS.values():
        if spec.fused_pack_op is None:
            continue
        assert check_fused_uplink(spec, g) == [], spec.name
        ran += 1
    assert ran >= 5  # all ternary fused rows + qsgd8


def test_dtype_promotion_drift_flags_f32_on_bf16_path():
    drift = DtypePromotionDrift()
    g16 = jnp.asarray(np.random.RandomState(4).randn(256), jnp.bfloat16)
    # the jnp reference path round-trips the whole leaf through f32: flagged
    bad = drift.check("ref", lambda x: jnp.sign(
        x.astype(jnp.float32)).astype(jnp.int8), g16)
    assert len(bad) == 1 and "float32" in bad[0].message
    # the fused kernel keeps f32 math in VMEM registers: clean
    from repro.core.compressors import get_spec
    spec = get_spec("sparsign")
    good = drift.check("fused", lambda x: spec.fused_pack_op(
        x, 1.0, jnp.uint32(7), interpret=True), g16)
    assert good == [], "\n".join(f.render() for f in good)


# ---------------------------------------------------------------------------
# HLO pass: synthetic-HLO parser math + agreement tolerance
# ---------------------------------------------------------------------------

def test_hlo_parser_ring_math_synthetic():
    from repro.launch.hlo_stats import parse_collectives
    hlo = """
  %ar = s8[1024] all-reduce(s8[1024] %x), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %ag = u8[8,256] all-gather(u8[256] %y), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
"""
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1}
    # ring models: 2*(8-1)/8*1024 and (8-1)/8*2048 == (8-1)*256
    assert stats.wire_bytes == pytest.approx(2 * 7 / 8 * 1024 + 7 * 256)


def test_hlo_jaxpr_agreement_tolerance():
    rule = HloJaxprAgreement(tolerance=0.05)
    assert rule.check("x", hlo_bytes=104.0, jaxpr_bytes=100.0,
                      ledger_bytes=100.0) == []
    bad = rule.check("x", hlo_bytes=120.0, jaxpr_bytes=100.0,
                     ledger_bytes=100.0)
    assert len(bad) == 2
    # 1-device degenerate case: all sides zero, trivially agree
    assert rule.check("x", hlo_bytes=0.0, jaxpr_bytes=0.0,
                      ledger_bytes=0.0) == []


def test_hlo_check_on_built_step():
    findings, checks = drivers.hlo_check("votes")
    assert checks == 1
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# AST repo-lint: unit cases via lint_source
# ---------------------------------------------------------------------------

def test_lint_flags_compressor_name_branching():
    src = "def f(cfg):\n    if cfg.compressor == 'sparsign':\n        return 1\n"
    hits = lint_source(src, "repro/train/foo.py")
    assert [f.rule for f in hits] == ["no-compressor-name-branching"]
    # membership test counts too
    src = "def f(algorithm):\n    return algorithm in ('sign', 'terngrad')\n"
    assert len(lint_source(src, "repro/train/foo.py")) == 1
    # prefix dispatch counts too
    src = "def f(cfg):\n    return cfg.compressor.startswith('qsgd')\n"
    assert len(lint_source(src, "repro/train/foo.py")) == 1


def test_lint_name_branching_negatives():
    # non-compressor identifiers comparing against a spec-name string: fine
    src = "def f(mode):\n    return mode == 'sign'\n"
    assert lint_source(src, "repro/train/foo.py") == []
    # spec capability lookup: fine
    src = "def f(spec):\n    return spec.wire_format == 'pack2'\n"
    assert lint_source(src, "repro/train/foo.py") == []
    # the registry module itself is exempt — names are DEFINED there
    src = "def g(compressor):\n    return compressor == 'sparsign'\n"
    assert lint_source(src, "repro/core/compressors.py") == []


def test_lint_flags_raw_collectives():
    src = "import jax\ndef f(x):\n    return jax.lax.psum(x, 'data')\n"
    hits = lint_source(src, "repro/train/foo.py")
    assert [f.rule for f in hits] == ["no-raw-collectives"]
    assert lint_source(src, "repro/dist/collectives.py") == []   # the home
    src = "from jax.lax import psum\n"
    assert len(lint_source(src, "repro/train/foo.py")) == 1
    # axis_index moves no payload: allowed anywhere
    src = "import jax\ndef f():\n    return jax.lax.axis_index('data')\n"
    assert lint_source(src, "repro/train/foo.py") == []


def test_lint_flags_jnp_alloc_in_kernel_bodies_only():
    kernel_src = ("import jax.numpy as jnp\n"
                  "def k(x_ref, o_ref):\n"
                  "    t = jnp.zeros((8, 128), jnp.float32)\n"
                  "    o_ref[...] = t\n")
    hits = lint_source(kernel_src, "repro/kernels/foo/kernel.py")
    assert [f.rule for f in hits] == ["no-jnp-alloc-in-kernel"]
    # *_like takes its shape from a Ref operand: kernel-legal
    like_src = ("import jax.numpy as jnp\n"
                "def k(x_ref, o_ref):\n"
                "    o_ref[...] = jnp.zeros_like(o_ref)\n")
    assert lint_source(like_src, "repro/kernels/foo/kernel.py") == []
    # same allocation outside a kernel body / outside kernel.py: fine
    assert lint_source(kernel_src, "repro/kernels/foo/ops.py") == []
    host_src = ("import jax.numpy as jnp\n"
                "def launcher(x):\n"
                "    return jnp.zeros((8,), jnp.float32) + x\n")
    assert lint_source(host_src, "repro/kernels/foo/kernel.py") == []


def test_repolint_repo_green_with_empty_allowlist():
    """The zero-entry allowlist pin: the whole package passes every AST rule
    with NO grandfathered sites."""
    assert len(ALLOWLIST) == 0
    findings, checks = run_repolint()
    assert findings == [], "\n".join(f.render() for f in findings)
    assert checks > 100  # every file x every rule actually ran


def test_specs_complete_rule_green():
    assert SpecsComplete().check() == []


# ---------------------------------------------------------------------------
# encoding bit model is a spec lookup
# ---------------------------------------------------------------------------

def test_baseline_bits_spec_lookup():
    from repro.core.encoding import baseline_bits_per_round, ternary_stream_bits
    d = 100_000
    assert baseline_bits_per_round(d, "scaled_sign") == d
    assert baseline_bits_per_round(d, "noisy_sign") == d
    assert baseline_bits_per_round(d, "terngrad", nnz=500) == pytest.approx(
        ternary_stream_bits(d, 500, coder="golomb") + 32.0)
    assert baseline_bits_per_round(d, "qsgd8") == 8 * d + 32
    with pytest.raises(ValueError):
        baseline_bits_per_round(d, "not_a_compressor")
