"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
assert output shapes + finiteness (no NaNs); decoders also run one decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model import Model


def _batch(cfg, b=2, s=16, key=0):
    rng = np.random.RandomState(key)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    if cfg.input_kind == "tokens":
        inputs = jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        inputs = jnp.array(rng.randn(b, s, cfg.d_model) * 0.3, cfg.activation_dtype)
    batch = {
        "inputs": inputs,
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": pos,
    }
    if cfg.mrope:
        batch["positions3"] = jnp.broadcast_to(jnp.arange(s)[:, None], (b, s, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    h = jax.jit(m.forward_hidden)(params, batch)
    assert h.shape == (2, 16, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    # one SGD train step on the smoke config
    def loss_fn(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{arch_id}: loss not finite"
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch_id}: bad grad norm {gnorm}"
    new_params = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_fn)(new_params)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS if get_config(a, smoke=True).supports_decode])
def test_smoke_decode_step(arch_id):
    cfg = get_config(arch_id, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    _, caches = jax.jit(m.prefill)(params, batch)

    b, s = 2, 16
    if cfg.input_kind == "tokens":
        nxt = jnp.array([[1], [2]], jnp.int32)
    else:
        nxt = jnp.zeros((b, 1, cfg.d_model), cfg.activation_dtype)
    dec = {"inputs": nxt, "positions": jnp.full((b, 1), s, jnp.int32)}
    if cfg.mrope:
        dec["positions3"] = jnp.full((b, 1, 3), s, jnp.int32)
    logits, new_caches = jax.jit(m.decode_step)(params, caches, dec)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
