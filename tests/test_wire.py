"""The uplink wire layer: fused sparsign->2-bit kernel, VoteWire abstraction,
wire-native engine messages, and the quorum deadband.

Blocking tier-1 coverage (single device); the multi-worker bitwise wire
equivalence (all three wires x both train modes) runs in tests/mdev/check_wires.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.dist import collectives
from repro.kernels import common
from repro.kernels.pack2bit.ops import pack2bit_op, unpack2bit_sum_op
from repro.kernels.pack2bit.ref import pack2bit_ref, unpack2bit_sum_ref
from repro.kernels.sparsign.ops import sparsign_op
from repro.kernels.sparsign_pack2bit.ops import sparsign_pack2bit_op
from repro.kernels.sparsign_pack2bit.ref import sparsign_pack2bit_ref

SHAPES = [(63,), (1000,), (7, 333), (513, 511)]
DTYPES = ["float32", "bfloat16"]


# ---------------------------------------------------------------------------
# fused kernel == two-pass chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_uplink_matches_two_pass(shape, dtype):
    g = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    for budget, seed, base in [(0.3, 1, 0), (1.5, 99, 12345), (50.0, 7, 2**20)]:
        fused = sparsign_pack2bit_op(g, budget, seed, base)
        two_pass = pack2bit_op(sparsign_op(g, budget, seed, base))
        ref = sparsign_pack2bit_ref(g, budget, seed, base)
        assert fused.dtype == jnp.uint8
        assert np.array_equal(np.asarray(fused), np.asarray(two_pass)), (shape, dtype, budget)
        assert np.array_equal(np.asarray(fused), np.asarray(ref)), (shape, dtype, budget)


def test_fused_uplink_no_int8_hbm_intermediate():
    """The whole point of the fusion: gradient -> wire bytes with no int8
    ternary tensor at the HBM level; the two-pass chain necessarily has one.
    The pin is the declarative per-spec rule (spec.hbm_limits), not a
    hand-written count."""
    from repro.analysis.jaxpr_audit import check_fused_uplink
    from repro.core.compressors import get_spec
    g = jnp.asarray(np.random.RandomState(1).randn(4096), jnp.float32)
    assert check_fused_uplink(get_spec("sparsign"), g, param=1.0) == []
    two_pass = common.int8_hbm_elems(lambda x: pack2bit_op(sparsign_op(x, 1.0, 7)), g)
    assert two_pass >= g.size


# ---------------------------------------------------------------------------
# fused decode-sum (the allgather_packed downlink side)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("n", [63, 1000])
def test_unpack_sum_fused_matches_ref(m, n):
    rng = np.random.RandomState(2)
    votes = [jnp.asarray(rng.randint(-1, 2, n), jnp.int8) for _ in range(m)]
    gathered = jnp.stack([pack2bit_op(v) for v in votes])
    got = unpack2bit_sum_op(gathered, n, (n,))
    want = common.from_2d(unpack2bit_sum_ref(gathered), n, (n,))
    oracle = sum(np.asarray(v, np.int32) for v in votes)
    assert got.dtype == jnp.int32
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got), oracle)


def test_packed_decode_sum_no_int8_hbm_intermediate():
    from repro.analysis.jaxpr_audit import NoHbmIntermediate
    gathered = jnp.stack([pack2bit_op(jnp.asarray(
        np.random.RandomState(s).randint(-1, 2, 4096), jnp.int8)) for s in range(4)])
    rule = NoHbmIntermediate(jnp.int8)
    assert rule.check("unpack2bit_sum",
                      lambda p: unpack2bit_sum_op(p, 4096, (4096,)),
                      gathered) == []
    unfused = common.int8_hbm_elems(
        lambda p: common.from_2d(unpack2bit_sum_ref(p), 4096, (4096,)), gathered)
    assert unfused >= 4 * 4096


# ---------------------------------------------------------------------------
# VoteWire construction + ledger
# ---------------------------------------------------------------------------

def test_make_vote_wire_validation():
    mesh = None  # sizes unused on the error paths
    with pytest.raises(ValueError, match="unknown vote_impl"):
        collectives.make_vote_wire("bogus", ("data",), mesh)
    # hier with a flat worker domain must fail LOUDLY at build time, not
    # silently substitute the flat psum wire
    with pytest.raises(ValueError, match="exactly two worker axes"):
        collectives.make_vote_wire("hier", ("data",), mesh)
    with pytest.raises(ValueError, match="exactly two worker axes"):
        collectives.make_vote_wire("hier", ("pod", "data", "extra"), mesh)


def test_vote_wire_formats_and_ledger():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    psum = collectives.make_vote_wire("psum", ("data",), mesh)
    packed = collectives.make_vote_wire("allgather_packed", ("data",), mesh)
    assert not psum.wants_packed and packed.wants_packed
    assert psum.n_workers == 1 and packed.n_workers == 1

    # ledger first principles at M=16 (psum wire: int8 sums fit M<=127)
    p16 = collectives.VoteWire(axes=("data",), n_workers=16)
    g16 = collectives.PackedVoteWire(axes=("data",), n_workers=16)
    n = 1 << 20
    assert p16.wire_bytes(n) == pytest.approx(2 * 15 / 16 * n)
    # all-gather wire: (M-1) x real padded payload — the padding is part of
    # the wire format, so the ledger must count it
    assert g16.wire_bytes(n) == 15 * collectives.packed_nbytes(n)
    assert collectives.packed_nbytes(1) == common.SUBLANE_PAD * (common.LANES // 4)
    assert collectives.packed_nbytes(n) == n // 4   # aligned case: exactly 2 bit/coord

    # hier ledger = narrow inner ring + widened outer ring
    h = collectives.HierVoteWire(axes=("pod", "data"), n_workers=32,
                                 inner_size=16, outer_size=2)
    assert h.wire_bytes(n) == pytest.approx(2 * 15 / 16 * n + 2 * 1 / 2 * n)


def test_packed_wire_nnz_and_mask():
    wire = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    t = jnp.asarray(np.random.RandomState(3).randint(-1, 2, 1000), jnp.int8)
    packed = pack2bit_op(t)
    # nnz off the packed bytes == nnz of the ternary tensor
    assert float(wire.message_nnz(packed)) == float(jnp.sum(jnp.abs(t)))
    # masking a packed message zeroes every vote (packed 0 decodes to 0)
    masked = wire.mask_message(packed, jnp.bool_(False))
    assert float(wire.message_nnz(masked)) == 0.0
    assert np.array_equal(np.asarray(wire.mask_message(packed, jnp.bool_(True))),
                          np.asarray(packed))


# ---------------------------------------------------------------------------
# engine wire-native messages
# ---------------------------------------------------------------------------

def _cfg(compressor="sparsign", value=2.0):
    return CompressionConfig(compressor=compressor,
                             budget=BudgetConfig(kind="fixed", value=value),
                             server="majority_vote")


OTHER = "interpret" if jax.default_backend() != "tpu" else "pallas"


@pytest.mark.parametrize("backend", ["jnp", OTHER])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("compressor", ["sparsign", "noisy_sign", "terngrad"])
def test_compress_leaf_wire_native(backend, dtype, compressor):
    """compress_leaf(wire=packed) returns the same wire bytes as packing the
    int8 message, on every backend (fused kernel vs two-pass reference), for
    every fused-kernel compressor — and the decode scale rides alongside."""
    wire = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    g = jnp.asarray(np.random.RandomState(4).randn(7, 333), dtype)
    msg_int8 = engine.compress_leaf(g, _cfg(compressor), 9, 123, backend=backend)
    msg_packed = engine.compress_leaf(g, _cfg(compressor), 9, 123, backend=backend, wire=wire)
    assert msg_int8.values.dtype == jnp.int8
    assert msg_packed.values.dtype == jnp.uint8
    view, _ = common.to_2d(msg_int8.values.reshape(-1))
    assert np.array_equal(np.asarray(msg_packed.values), np.asarray(pack2bit_ref(view)))
    assert np.array_equal(np.asarray(msg_packed.scale), np.asarray(msg_int8.scale))


@pytest.mark.parametrize("compressor,param", [("noisy_sign", 0.3), ("terngrad", None)])
def test_new_fused_uplinks_no_int8_hbm_intermediate(compressor, param):
    """Acceptance pin: noisy_sign and terngrad reach the packed wire through a
    single-pass kernel — no int8 ternary tensor at the HBM level (the two-pass
    chain necessarily has one)."""
    from repro.analysis.jaxpr_audit import check_fused_uplink
    from repro.core.compressors import get_spec
    g = jnp.asarray(np.random.RandomState(6).randn(4096), jnp.float32)
    spec = get_spec(compressor)
    p = param if param is not None else float(jnp.max(jnp.abs(g)))
    assert check_fused_uplink(spec, g, param=p) == [], compressor
    two_pass = common.int8_hbm_elems(
        lambda x: pack2bit_op(spec.pallas_op(x, p, 7, interpret=True),
                              interpret=True), g)
    assert two_pass >= g.size
    # and the fused bytes == pack2bit(reference compressor) byte-for-byte
    want_view, _ = common.to_2d(spec.values(g, p, 7, 0).reshape(-1))
    got = spec.fused_pack_op(g, p, 7, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(pack2bit_ref(want_view)))


@pytest.mark.parametrize("backend", ["jnp", OTHER])
def test_compress_leaf_wire_two_pass_fallback(backend):
    """Ternary compressors without a fused kernel still speak the packed wire."""
    wire = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    g = jnp.asarray(np.random.RandomState(5).randn(513), jnp.float32)
    cfg = _cfg(compressor="sign")
    msg = engine.compress_leaf(g, cfg, 1, backend=backend, wire=wire)
    view, _ = common.to_2d(jnp.sign(g).astype(jnp.int8))
    assert np.array_equal(np.asarray(msg.values), np.asarray(pack2bit_ref(view)))


def test_compress_leaf_wire_rejects_non_ternary():
    wire = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    g = jnp.zeros((8,), jnp.float32)
    with pytest.raises(ValueError, match="ternary"):
        engine.compress_leaf(g, _cfg(compressor="identity"), 0, wire=wire)


# ---------------------------------------------------------------------------
# end-to-end on a 1-device mesh: wires agree bitwise; quorum deadband
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.model import Model
    cfg = ModelConfig(name="wire-tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      pattern=(LayerSpec(mixer="attn"),), dtype="float32",
                      attn_chunk=8, q_chunk=8, loss_chunk=8, remat=False)
    return Model(cfg)


def _tiny_batch(vocab, b=2, s=8, seed=0):
    rng = np.random.RandomState(seed)
    return {
        "inputs": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }


def _one_step(model, params, batch, mesh, comp=None, **cfg_kw):
    from repro.dist import compat
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_simple import TrainStepConfig, build_train_step
    if comp is None:
        comp = CompressionConfig(compressor="sparsign",
                                 budget=BudgetConfig(kind="fixed", value=2.0),
                                 server="majority_vote")
    scfg = TrainStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                           worker_axes=("data",), donate=False, **cfg_kw)
    step = build_train_step(model, scfg, mesh)
    state = init_state(params, server=comp.server, seed=7)
    with compat.set_mesh(mesh):
        out, metrics = step(state, batch)
    return jax.tree_util.tree_map(np.asarray, out.params), metrics


def test_simple_step_wires_bitwise_equal_single_device():
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)

    ref, m_ref = _one_step(model, params, batch, mesh, vote_impl="psum")
    for vote_impl in ("allgather_packed",):
        for backend in ("jnp", OTHER):
            got, m = _one_step(model, params, batch, mesh,
                               vote_impl=vote_impl, backend=backend)
            for (ka, a), (kb, b) in zip(
                    jax.tree_util.tree_flatten_with_path(ref)[0],
                    jax.tree_util.tree_flatten_with_path(got)[0]):
                assert np.array_equal(a, b), (vote_impl, backend, jax.tree_util.keystr(ka))
    # the ledger metric is emitted and matches the wire's own accounting
    # (M=1: both ring collectives move zero bytes)
    assert float(m["wire_bytes_per_device"]) == 0.0
    assert float(m_ref["wire_bytes_per_device"]) == 0.0


@pytest.mark.parametrize("compressor,server", [
    ("noisy_sign", "majority_vote"),   # votes mode through a new fused kernel
    ("terngrad", "mean"),              # scaled_votes: ternary votes + shared s_t
])
def test_simple_step_nonsparsign_wires_bitwise_equal(compressor, server):
    """Non-sparsign ternary compressors ride all wires bitwise-identically —
    the spec-negotiated wire (votes / scaled_votes) must not change the round."""
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)
    comp = CompressionConfig(compressor=compressor,
                             budget=BudgetConfig(kind="fixed", value=0.5),
                             server=server)
    ref, _ = _one_step(model, params, batch, mesh, comp=comp, vote_impl="psum")
    moved = any(not np.array_equal(a, np.asarray(b)) for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(params)))
    assert moved, "the step must actually update params"
    for backend in ("jnp", OTHER):
        got, _ = _one_step(model, params, batch, mesh, comp=comp,
                           vote_impl="allgather_packed", backend=backend)
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(ref)[0],
                jax.tree_util.tree_flatten_with_path(got)[0]):
            assert np.array_equal(a, b), (compressor, backend, jax.tree_util.keystr(ka))


def test_per_leaf_quorum_tree_freezes_selected_leaves():
    """quorum as a pytree prefix: an unreachable quorum on one subtree freezes
    exactly that subtree; the rest matches the scalar-quorum run bitwise."""
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)
    shapes = model.param_shapes()
    frozen_key = "embed"
    qtree = {k: (10**6 if k == frozen_key else 1) for k in shapes}
    base, _ = _one_step(model, params, batch, mesh, quorum=1)
    got, _ = _one_step(model, params, batch, mesh, quorum=qtree)
    p0 = jax.tree_util.tree_map(np.asarray, params)
    for k in shapes:
        for a, b, c in zip(jax.tree_util.tree_leaves(got[k]),
                           jax.tree_util.tree_leaves(base[k]),
                           jax.tree_util.tree_leaves(p0[k])):
            if k == frozen_key:
                assert np.array_equal(a, c), f"{k} must be frozen by its quorum"
            else:
                assert np.array_equal(a, b), f"{k} must match the scalar-quorum run"
    # malformed quorum trees fail at build time, before tracing
    from repro.train.state import LrSchedule
    from repro.train.step_simple import TrainStepConfig, build_train_step
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    with pytest.raises(ValueError, match="prefix"):
        build_train_step(model, TrainStepConfig(
            compression=comp, lr=LrSchedule(base=0.05), worker_axes=("data",),
            quorum={"embed": 2}), mesh)
    # a quorum the wire would silently ignore is a build-time error too
    mean_comp = CompressionConfig(compressor="terngrad",
                                  budget=BudgetConfig(kind="fixed", value=1.0),
                                  server="mean")
    with pytest.raises(ValueError, match="silently ignored"):
        build_train_step(model, TrainStepConfig(
            compression=mean_comp, lr=LrSchedule(base=0.05),
            worker_axes=("data",), quorum=5), mesh)


def test_quorum_deadband_blocks_minority_updates():
    """M=1 worker can never reach a quorum of 2: params must not move."""
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)
    got, _ = _one_step(model, params, batch, mesh, quorum=2)
    for (k, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(jax.tree_util.tree_map(np.asarray, params))[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        assert np.array_equal(a, b), jax.tree_util.keystr(k)


def test_streamed_config_exposes_vote_impl_and_quorum():
    from repro.train.state import LrSchedule
    from repro.train.step_streamed import StreamedStepConfig
    cfg = StreamedStepConfig(compression=CompressionConfig(),
                             lr=LrSchedule(base=0.1),
                             vote_impl="allgather_packed", quorum=3)
    assert cfg.vote_impl == "allgather_packed" and cfg.quorum == 3


# ---------------------------------------------------------------------------
# the pack8 (8-bit QSGD) wire: fused kernel, decode-sum, Pack8Wire, engine
# ---------------------------------------------------------------------------

from repro.kernels.pack8.ops import qsgd8_op, qsgd8_pack8_op, unpack8_sum_op
from repro.kernels.pack8.ref import (QSGD8_LEVELS, qsgd8_levels_ref,
                                     qsgd8_pack8_ref, unpack8_sum_ref)


def _qsgd8_param(g):
    from repro.core.compressors import qsgd8_scale
    return qsgd8_scale(g)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pack8_fused_matches_ref(shape, dtype):
    """Fused quantize->wire kernel == quantize-then-pad reference, byte for
    byte, across odd shapes / bf16 / counter bases (the pack8 round-trip)."""
    g = jnp.asarray(np.random.RandomState(7).randn(*shape), dtype)
    param = _qsgd8_param(g)
    for seed, base in [(1, 0), (99, 12345), (7, 2**20)]:
        fused = qsgd8_pack8_op(g, param, seed, base)
        ref = qsgd8_pack8_ref(g, param, seed, base)
        assert fused.dtype == jnp.int8
        assert np.array_equal(np.asarray(fused), np.asarray(ref)), (shape, dtype, seed)
        # leaf-shaped op unpads the same payload
        leaf = qsgd8_op(g, param, seed, base)
        assert leaf.shape == g.shape
        assert np.array_equal(np.asarray(leaf),
                              np.asarray(qsgd8_levels_ref(g, param, seed, base)))
        assert int(np.abs(np.asarray(leaf).astype(np.int32)).max()) <= QSGD8_LEVELS


def test_pack8_fused_no_int32_hbm_intermediate():
    """The fused uplink's structural guarantee: gradient -> int8 wire bytes
    with no int32 level tensor at the HBM level (the legacy generic-qsgd jnp
    chain necessarily materializes one)."""
    from repro.analysis.jaxpr_audit import check_fused_uplink
    from repro.core.compressors import _qsgd_level_values, get_spec
    g = jnp.asarray(np.random.RandomState(8).randn(4096), jnp.float32)
    # the spec declares hbm_limits=(("int32", 1),): the single scatter-start
    # index of the to_2d canonical-view pad is allowed (every canonical-view
    # op carries it); the point is no O(n) level tensor.  check_fused_uplink
    # supplies a uint32 seed, as the engine does (a python-int seed would add
    # one i32->u32 scalar conversion to the jaxpr and muddy the pin)
    assert check_fused_uplink(get_spec("qsgd8"), g) == []
    param = _qsgd8_param(g)
    legacy_i32 = common.int32_hbm_elems(
        lambda x: _qsgd_level_values(x, param, jnp.uint32(7), 0), g)
    assert legacy_i32 >= g.size


@pytest.mark.parametrize("m", [1, 3, 8, 40])  # 40 exercises worker chunking
@pytest.mark.parametrize("n", [63, 1000])
def test_unpack8_sum_matches_sequential_oracle(m, n):
    """Fused dequantize-sum == eager worker-order accumulation of the decoded
    payloads — the association the decoded-psum wire uses, which is what makes
    the pack8 wire bitwise-honest against the fp32 oracle stream. m=40 splits
    into worker chunks (the VMEM bound for large M), whose grid accumulation
    must preserve the same worker-order association."""
    rng = np.random.RandomState(9)
    payloads, scales = [], []
    for i in range(m):
        gi = jnp.asarray(rng.randn(n), jnp.float32)
        pi = _qsgd8_param(gi)
        payloads.append(qsgd8_pack8_op(gi, pi, i))
        scales.append(jnp.float32(pi))
    gathered = jnp.stack(payloads)
    scales = jnp.stack(scales)
    got = jax.jit(lambda ga, s: unpack8_sum_op(ga, s, n, (n,)))(gathered, scales)
    want = common.from_2d(unpack8_sum_ref(gathered, scales), n, (n,))
    assert got.dtype == jnp.float32
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # eager sequential oracle (rounded products, worker-order adds)
    acc = np.zeros(n, np.float32)
    for i in range(m):
        dec = np.asarray(common.from_2d(gathered[i], n, (n,)), np.float32) * np.asarray(scales)[i]
        acc = (acc + dec).astype(np.float32)
    assert np.array_equal(np.asarray(got), acc)


def test_pack8_wire_nnz_mask_and_ledger():
    wire = collectives.Pack8Wire(axes=("data",), n_workers=4)
    assert wire.native_format == "pack8" and wire.wants_packed
    g = jnp.asarray(np.random.RandomState(10).randn(1000), jnp.float32)
    payload = qsgd8_pack8_op(g, _qsgd8_param(g), 3)
    # nnz counts nonzero LEVELS (not their magnitudes)
    levels = np.asarray(common.from_2d(payload, 1000, (1000,)))
    assert float(wire.message_nnz(payload)) == float((levels != 0).sum())
    masked = wire.mask_message(payload, jnp.bool_(False))
    assert float(wire.message_nnz(masked)) == 0.0
    # ledger: (M-1) x real padded int8 payload + (M-1) gathered f32 scales
    n = 1 << 20
    assert wire.wire_bytes(n) == 3 * collectives.packed8_nbytes(n)
    assert collectives.packed8_nbytes(n) == n       # aligned: exactly 1 B/coord
    assert collectives.packed8_nbytes(1) == common.SUBLANE_PAD * common.LANES
    assert wire.scalar_bytes() == 3 * 4.0
    # integer vote wires reject an in-exchange scale loudly
    with pytest.raises(ValueError, match="pack8-wire concept"):
        collectives.VoteWire(axes=("data",), n_workers=4).exchange(
            jnp.zeros(8, jnp.int8), 8, (8,), scale=jnp.float32(1.0))


def test_wire_ledger_matches_real_payload_nbytes():
    """Satellite pin: every wire impl's ledger == the bytes of the REAL
    (padded) message buffers it exchanges, from first principles — no
    idealized d/4 or d models anywhere."""
    n = 1000  # unaligned on purpose: the pad must be counted
    g = jnp.asarray(np.random.RandomState(12).randn(n), jnp.float32)
    t = jnp.sign(g).astype(jnp.int8)

    m = 16
    psum = collectives.VoteWire(axes=("data",), n_workers=m)
    # psum payload: leaf-shaped votes in the narrowest sum dtype (no padding)
    votes = t.astype(collectives._sum_dtype(m))
    assert psum.wire_bytes(n) == pytest.approx(2 * (m - 1) / m * votes.nbytes)

    hier = collectives.HierVoteWire(axes=("pod", "data"), n_workers=m,
                                    inner_size=8, outer_size=2)
    inner_payload = t.astype(collectives._sum_dtype(8)).nbytes
    outer_payload = t.astype(collectives._sum_dtype(16)).nbytes
    assert hier.wire_bytes(n) == pytest.approx(
        2 * 7 / 8 * inner_payload + 2 * 1 / 2 * outer_payload)

    packed = collectives.PackedVoteWire(axes=("data",), n_workers=m)
    payload2 = pack2bit_op(t)
    assert packed.wire_bytes(n) == (m - 1) * payload2.nbytes

    p8 = collectives.Pack8Wire(axes=("data",), n_workers=m)
    payload8 = qsgd8_pack8_op(g, _qsgd8_param(g), 0)
    assert p8.wire_bytes(n) == (m - 1) * payload8.nbytes
    assert p8.scalar_bytes() == (m - 1) * jnp.float32(0).nbytes


def test_make_vote_wire_pack8_validation():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    wire = collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                      wire_format="pack8")
    assert isinstance(wire, collectives.Pack8Wire)
    # the pack8 payload cannot ride a fabric reduction
    for impl in ("psum", "hier"):
        with pytest.raises(ValueError, match="allgather_packed"):
            collectives.make_vote_wire(impl, ("pod", "data"), mesh,
                                       wire_format="pack8")
    with pytest.raises(ValueError, match="payload format"):
        collectives.make_vote_wire("psum", ("data",), mesh, wire_format="float")


@pytest.mark.parametrize("backend", ["jnp", OTHER])
def test_compress_leaf_pack8_wire_native(backend):
    """compress_leaf(wire=Pack8Wire) returns the canonical int8 level payload
    (fused kernel or padded reference — identical bytes) with the per-worker
    decode scale riding alongside."""
    wire = collectives.Pack8Wire(axes=("data",), n_workers=4)
    g = jnp.asarray(np.random.RandomState(13).randn(7, 333), jnp.float32)
    cfg = _cfg(compressor="qsgd8")
    msg_plain = engine.compress_leaf(g, cfg, 9, 123, backend=backend)
    msg_wire = engine.compress_leaf(g, cfg, 9, 123, backend=backend, wire=wire)
    assert msg_plain.values.dtype == jnp.int8 and msg_plain.values.shape == g.shape
    assert msg_wire.values.dtype == jnp.int8
    view, _ = common.to_2d(msg_plain.values.reshape(-1))
    assert np.array_equal(np.asarray(msg_wire.values), np.asarray(view))
    assert np.array_equal(np.asarray(msg_wire.scale), np.asarray(msg_plain.scale))
    assert float(msg_wire.scale) == float(_qsgd8_param(g))


def test_compress_leaf_wire_format_mismatch_is_loud():
    g = jnp.zeros((8,), jnp.float32)
    # ternary wire refuses pack8/float specs (pre-existing contract)
    pack2 = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    with pytest.raises(ValueError, match="ternary"):
        engine.compress_leaf(g, _cfg(compressor="qsgd8"), 0, wire=pack2)
    # pack8 wire refuses ternary/float specs
    p8 = collectives.Pack8Wire(axes=("data",), n_workers=4)
    with pytest.raises(ValueError, match="pack8"):
        engine.compress_leaf(g, _cfg(compressor="sparsign"), 0, wire=p8)
    with pytest.raises(ValueError, match="pack8"):
        engine.compress_leaf(g, _cfg(compressor="identity"), 0, wire=p8)


def test_server_ef_off_the_votes_wire_is_loud():
    """scaled_sign_ef keeps a residual that only updates on the integer vote
    wire; pairing it with a pack8/float compressor must fail at build time,
    not silently train plain mean while carrying a dead full-model EF tree."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.state import LrSchedule
    from repro.train.step_simple import TrainStepConfig, build_train_step
    from repro.train.step_streamed import (StreamedStepConfig,
                                           build_streamed_train_step)
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    for compressor in ("qsgd8", "identity"):
        comp = CompressionConfig(compressor=compressor,
                                 budget=BudgetConfig(kind="fixed", value=1.0),
                                 server="scaled_sign_ef")
        with pytest.raises(ValueError, match="error-feedback residual"):
            build_train_step(model, TrainStepConfig(
                compression=comp, lr=LrSchedule(base=0.05),
                worker_axes=("data",)), mesh)
        with pytest.raises(ValueError, match="error-feedback residual"):
            build_streamed_train_step(model, StreamedStepConfig(
                compression=comp, lr=LrSchedule(base=0.05),
                worker_axes=("data",), fsdp_axis="data"), mesh)


def test_simple_step_qsgd8_pack8_bitwise_equals_decoded_psum():
    """The acceptance pin at M=1: qsgd8 end-to-end on the pack8 gather wire ==
    the decoded-psum stream bitwise, jnp and kernel backends; the ledger
    metric is emitted from the pack8 wire's accounting."""
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)
    comp = CompressionConfig(compressor="qsgd8",
                             budget=BudgetConfig(kind="fixed", value=1.0),
                             server="mean")
    ref, m_ref = _one_step(model, params, batch, mesh, comp=comp, vote_impl="psum")
    moved = any(not np.array_equal(a, np.asarray(b)) for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(params)))
    assert moved, "the step must actually update params"
    for backend in ("jnp", OTHER):
        got, m_got = _one_step(model, params, batch, mesh, comp=comp,
                               vote_impl="allgather_packed", backend=backend)
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(ref)[0],
                jax.tree_util.tree_flatten_with_path(got)[0]):
            assert np.array_equal(a, b), (backend, jax.tree_util.keystr(ka))
        # M=1 ring collectives move zero bytes on both wires
        assert float(m_got["wire_bytes_per_device"]) == 0.0
    assert float(m_ref["wire_bytes_per_device"]) == 0.0
