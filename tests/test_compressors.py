"""Unit + property tests for the compressor family (Def. 1 + Appendix B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.budgets import BudgetConfig, expected_sparsity, resolve_budget, solve_budget_for_sparsity
from repro.core.compressors import (COMPRESSORS, SPECS, compress_leaf_chunked,
                                    get_compressor, get_spec, qsgd_1bit_l2,
                                    sparsign, terngrad)

TERNARY = ("sparsign", "sign", "scaled_sign", "noisy_sign",
           "qsgd_1bit_l2", "qsgd_1bit_linf", "terngrad")


@pytest.mark.parametrize("name", TERNARY)
def test_ternary_domain(name):
    g = jnp.asarray(np.random.RandomState(0).randn(4096) * 3, jnp.float32)
    msg = get_compressor(name)(g, budget=0.5, seed=7, counter_base=0)
    vals = np.asarray(msg.values)
    assert set(np.unique(vals)).issubset({-1, 0, 1}), name
    assert msg.values.dtype == jnp.int8


@given(budget=st.floats(0.01, 50.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_sparsign_expected_sparsity(budget, seed):
    """Realized nnz ~ sum min(|g|B, 1) (Def. 1) within binomial noise."""
    rng = np.random.RandomState(seed % 100000)
    g = jnp.asarray(rng.randn(20000), jnp.float32)
    msg = sparsign(g, budget=budget, seed=seed)
    expect = float(expected_sparsity(g, budget)) * g.size
    realized = float(jnp.sum(jnp.abs(msg.values)))
    tol = 5.0 * np.sqrt(max(expect, 1.0))  # 5 sigma
    assert abs(realized - expect) <= tol


def test_sparsign_sign_correctness():
    """Whenever a coordinate is transmitted, it carries the true sign."""
    g = jnp.asarray(np.random.RandomState(1).randn(10000), jnp.float32)
    msg = sparsign(g, budget=1.0, seed=3)
    v = np.asarray(msg.values)
    gs = np.sign(np.asarray(g))
    nz = v != 0
    assert np.array_equal(v[nz], gs[nz])


def test_sparsign_counter_layout_invariance():
    """The Bernoulli draw of a coordinate depends only on its flat index:
    compressing a reshaped view gives the same symbols."""
    g = jnp.asarray(np.random.RandomState(2).randn(6, 64), jnp.float32)
    a = sparsign(g, budget=0.7, seed=11).values
    b = sparsign(g.reshape(-1), budget=0.7, seed=11).values.reshape(6, 64)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sparsign_counter_base_offsets():
    """Shard-by-shard compression with counter_base == whole-tensor compression."""
    g = jnp.asarray(np.random.RandomState(3).randn(1000), jnp.float32)
    whole = sparsign(g, budget=0.9, seed=5).values
    parts = [sparsign(g[i * 250:(i + 1) * 250], budget=0.9, seed=5,
                      counter_base=i * 250).values for i in range(4)]
    assert np.array_equal(np.asarray(whole), np.concatenate([np.asarray(p) for p in parts]))


def test_compress_leaf_chunked_stream_identity():
    g = jnp.asarray(np.random.RandomState(4).randn(3, 1000), jnp.float32)
    whole = sparsign(g, budget=0.5, seed=9).values
    chunked = compress_leaf_chunked(sparsign, g, budget=0.5, seed=9, max_chunk=500).values
    assert np.array_equal(np.asarray(whole), np.asarray(chunked))


@pytest.mark.parametrize("name", ["qsgd_1bit_l2", "qsgd_1bit_linf", "terngrad"])
def test_stochastic_ternary_unbiased(name):
    """TernGrad/1-bit QSGD decode is unbiased: E[scale*values] = g.

    Per-coordinate stdev of the n-trial mean is scale*sqrt(p(1-p)/n) with
    p = |g_i|/scale; we test against 3x the analytic expected |error|."""
    rng = np.random.RandomState(5)
    d, n = 200, 400
    g = jnp.asarray(rng.randn(d), jnp.float32)
    acc = np.zeros(d, np.float64)
    scale_val = None
    for s in range(n):
        msg = get_compressor(name)(g, seed=s)
        scale_val = float(msg.scale)
        acc += np.asarray(msg.values, np.float64) * scale_val
    est = acc / n
    p = np.clip(np.abs(np.asarray(g)) / scale_val, 0, 1)
    expected_abs_err = np.sqrt(2 / np.pi) * scale_val * np.sqrt(p * (1 - p) / n)
    err = np.abs(est - np.asarray(g))
    assert err.mean() < 3.0 * max(expected_abs_err.mean(), 1e-6), (name, err.mean())


def test_qsgd8_registered_and_bounded():
    """The FedCom 8-bit baseline is reachable via the registry; sign*level
    fits int8 losslessly (1 sign bit + 7 level bits, s = 127) and the
    compressor honors the shared compress signature."""
    fn = get_compressor("qsgd8")
    g = jnp.asarray(np.random.RandomState(10).randn(4096) * 2, jnp.float32)
    msg = fn(g, budget=1.0, seed=3, counter_base=0)
    vals = np.asarray(msg.values)
    assert vals.dtype == np.int8
    assert np.abs(vals.astype(np.int32)).max() <= 127
    # transmitted coordinates carry the true sign
    nz = vals != 0
    assert np.array_equal(np.sign(vals[nz]), np.sign(np.asarray(g))[nz])


def test_qsgd8_level_clip_keeps_int8_lossless():
    """The edge the clip exists for: a single-coordinate tensor has
    |g| == ||g||_2, so the level ratio sits exactly at s and a float ulp
    (or the stochastic round-up) would otherwise produce level 128 — which
    wraps to -128 in int8, flipping the sign on the wire."""
    fn = get_compressor("qsgd8")
    # values above the 1e-12 norm floor (below it the scale saturates at
    # eps/127 and the level honestly collapses to 0)
    for v in (1.0, 3.7e8, 1.2e-6):
        for seed in range(8):
            msg = fn(jnp.asarray([v], jnp.float32), seed=seed)
            lvl = int(np.asarray(msg.values)[0])
            assert lvl == 127, (v, seed, lvl)  # never 128/-128
            dec = lvl * float(msg.scale)
            assert dec == pytest.approx(v, rel=1e-5)


def test_qsgd8_unbiased_decode():
    """E[decode] = g: with s=127 levels a single draw is already within
    half a level, so a small trial count pins the mean tightly."""
    rng = np.random.RandomState(11)
    g = jnp.asarray(rng.randn(256), jnp.float32)
    fn = get_compressor("qsgd8")
    n = 50
    acc = np.zeros(256, np.float64)
    for s in range(n):
        msg = fn(g, seed=s)
        acc += np.asarray(msg.values, np.float64) * float(msg.scale)
    # per-coord sigma of the n-trial mean <= level/(2 sqrt(n)) ~ level/14, so
    # level/3 passes comfortably for stochastic rounding but fails a biased
    # floor-only implementation (whose mean error is uniform in [0, level))
    level = float(np.linalg.norm(np.asarray(g))) / 127.0
    err = np.abs(acc / n - np.asarray(g))
    assert err.max() < level / 3.0, err.max()


def test_compressors_table_is_spec_derived():
    """COMPRESSORS is a view over the CompressorSpec registry — same names,
    spec.api is the public callable, and ternariness matches the table."""
    assert set(COMPRESSORS) == set(SPECS)
    for name in COMPRESSORS:
        assert get_compressor(name) is get_spec(name).api
    for name in TERNARY:
        assert SPECS[name].is_ternary, name
    assert not SPECS["qsgd8"].is_ternary
    assert not SPECS["identity"].is_ternary


def test_terngrad_shared_max_kwarg():
    """Magnitude sharing: a larger shared normalizer raises the scale and
    thins the transmitted set; decode stays unbiased around g by scale*E[t]."""
    g = jnp.asarray(np.random.RandomState(12).randn(4096), jnp.float32)
    local = terngrad(g, seed=1)
    big = jnp.float32(4.0) * jnp.max(jnp.abs(g))
    shared = terngrad(g, seed=1, shared_max=big)
    assert float(shared.scale) == float(big)
    assert float(jnp.sum(jnp.abs(shared.values))) < float(jnp.sum(jnp.abs(local.values)))


def test_scaled_sign_scale():
    g = jnp.asarray(np.random.RandomState(6).randn(512), jnp.float32)
    msg = get_compressor("scaled_sign")(g)
    assert np.isclose(float(msg.scale), float(jnp.mean(jnp.abs(g))), rtol=1e-5)


@given(target=st.floats(0.02, 0.9))
@settings(max_examples=20, deadline=None)
def test_budget_bisection_hits_target(target):
    g = jnp.asarray(np.random.RandomState(7).randn(5000), jnp.float32)
    b = solve_budget_for_sparsity(g, target)
    got = float(expected_sparsity(g, b))
    assert abs(got - target) < 0.02


def test_budget_kinds():
    g = jnp.asarray(np.random.RandomState(8).randn(100), jnp.float32)
    for kind, val in [("fixed", 2.0), ("linf_share", 1.0), ("l2_norm", 1.0),
                      ("target_sparsity", 0.3)]:
        b = resolve_budget(BudgetConfig(kind=kind, value=val), g)
        assert np.isfinite(float(b)) and float(b) > 0, kind
