"""Model-layer equivalence tests: attention oracles, SSD, MoE, RoPE, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.attention import chunked_attention, decode_attention, windowed_attention
from repro.models.mamba2 import MambaDims, mamba_decode_step, mamba_forward, mamba_param_defs, ssd_chunked
from repro.models.moe import MoEDims, moe_ffn, moe_param_shapes
from repro.models.model import Model
from repro.models.rope import apply_mrope, apply_rope

B, S, H, KV, D = 2, 64, 8, 4, 16


def _qkv(seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    return q, k, v, pos


def _naive(q, k, v, causal=True, window=None):
    g = H // KV
    qg = q.reshape(B, S, KV, g, D) * (D ** -0.5)
    s_ = jnp.einsum("bqkgd,bckd->bkgqc", qg, k)
    i = jnp.arange(S)
    m = jnp.ones((S, S), bool) if not causal else (i[None, :] <= i[:, None])
    if window is not None:
        m = m & (i[:, None] - i[None, :] < window)
    s_ = jnp.where(m[None, None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


@pytest.mark.parametrize("chunk", [16, 64, 7])
def test_chunked_attention_matches_naive(chunk):
    q, k, v, pos = _qkv()
    out = chunked_attention(q, k, v, positions_q=pos, positions_kv=pos,
                            causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v)),
                               rtol=2e-5, atol=2e-5)


def test_noncausal_attention():
    q, k, v, pos = _qkv(1)
    out = chunked_attention(q, k, v, positions_q=pos, positions_kv=pos,
                            causal=False, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v, causal=False)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,q_chunk", [(20, 16), (8, 8), (33, 16)])
def test_windowed_attention(window, q_chunk):
    q, k, v, pos = _qkv(2)
    ref = _naive(q, k, v, window=window)
    out = windowed_attention(q, k, v, positions=pos, window=window, q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    out2 = chunked_attention(q, k, v, positions_q=pos, positions_kv=pos,
                             causal=True, window=window, chunk=16)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full():
    q, k, v, pos = _qkv(3)
    ref = _naive(q, k, v)
    out = decode_attention(q[:, -1:], k, v, pos, pos[:, -1:], chunk=16)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_attention_grads_flow():
    """flash-remat chunk bodies must be differentiable."""
    q, k, v, pos = _qkv(4)

    def f(q, k, v):
        return chunked_attention(q, k, v, positions_q=pos, positions_kv=pos,
                                 causal=True, chunk=16).sum()

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).sum()) > 0


# ---------------------------------------------------------------------------
# SSD / Mamba2
# ---------------------------------------------------------------------------

def _ssd_inputs(seed=0, s=32):
    dims = MambaDims(d_model=32, d_inner=64, n_heads=4, head_dim=16, d_state=8, chunk=8)
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(B, s, 4, 16) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.randn(B, s, 4), jnp.float32)
    a_log = jnp.asarray(rng.randn(4) * 0.1, jnp.float32)
    bm = jnp.asarray(rng.randn(B, s, 8) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.randn(B, s, 8) * 0.5, jnp.float32)
    d_skip = jnp.asarray(rng.randn(4), jnp.float32)
    return dims, x, dt, a_log, bm, cm, d_skip


def _ssd_sequential(x, dt, a_log, bm, cm, d_skip):
    b, s, h, p = x.shape
    n = bm.shape[-1]
    dtf = jax.nn.softplus(dt)
    a = -jnp.exp(a_log)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dtf[:, t] * a[None, :])
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t] * dtf[:, t][..., None], bm[:, t])
        state = state * decay[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, cm[:, t]) + d_skip[None, :, None] * x[:, t])
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("s", [32, 17])  # incl. non-chunk-multiple
def test_ssd_chunked_vs_sequential(s):
    dims, x, dt, a_log, bm, cm, d_skip = _ssd_inputs(s=s)
    y_ref, st_ref = _ssd_sequential(x, dt, a_log, bm, cm, d_skip)
    y, st = ssd_chunked(x, dt, a_log, bm, cm, d_skip, dims)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=3e-4, atol=3e-4)


def test_mamba_forward_vs_decode():
    dims = MambaDims(d_model=32, d_inner=64, n_heads=4, head_dim=16, d_state=8, chunk=8)
    rng = np.random.RandomState(1)
    params = {k: jnp.asarray(rng.randn(*shp) * 0.1, dt)
              for k, (shp, dt, _) in mamba_param_defs(dims, jnp.float32).items()}
    h_in = jnp.asarray(rng.randn(B, 32, 32) * 0.5, jnp.float32)
    out_full, (tail, st_final) = mamba_forward(params, h_in, dims, return_cache=True)
    cache = (jnp.zeros((B, dims.d_conv - 1, dims.d_inner + 2 * dims.d_state)),
             jnp.zeros((B, 4, 16, 8)))
    outs = []
    for t in range(32):
        o, cache = mamba_decode_step(params, h_in[:, t:t + 1], cache, dims)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(out_full),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(cache[1]), np.asarray(st_final), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(cache[0]), np.asarray(tail), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_gather_equals_dense():
    dims = MoEDims(n_experts=6, n_experts_padded=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0)
    rng = np.random.RandomState(2)
    params = {k: jnp.asarray(rng.randn(*shp) * 0.2, dt)
              for k, (shp, dt) in moe_param_shapes(dims, 2, jnp.float32).items()}
    x = jnp.asarray(rng.randn(64, 16) * 0.5, jnp.float32)
    yg = moe_ffn(params, x, dims, impl="gather")
    yd = moe_ffn(params, x, dims, impl="dense")
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd), rtol=3e-4, atol=3e-4)


def test_moe_padded_experts_never_selected():
    dims = MoEDims(n_experts=6, n_experts_padded=8, top_k=2, d_model=16, d_ff=32)
    rng = np.random.RandomState(3)
    from repro.models.moe import router_probs
    w = jnp.asarray(rng.randn(16, 8), jnp.float32)
    x = jnp.asarray(rng.randn(100, 16), jnp.float32)
    probs = router_probs(x, w, dims)
    assert float(probs[:, 6:].max()) == 0.0


def test_moe_capacity_drops_tokens():
    """At capacity_factor << 1, outputs differ from dense (tokens dropped)."""
    dims = MoEDims(n_experts=4, n_experts_padded=4, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=0.2)
    rng = np.random.RandomState(4)
    params = {k: jnp.asarray(rng.randn(*shp) * 0.2, dt)
              for k, (shp, dt) in moe_param_shapes(dims, 0, jnp.float32).items()}
    x = jnp.asarray(rng.randn(256, 16) * 0.5, jnp.float32)
    yg = moe_ffn(params, x, dims, impl="gather")
    yd = moe_ffn(params, x, dims, impl="dense")
    assert float(jnp.abs(yg - yd).max()) > 1e-4


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_mrope_reduces_to_rope_on_text():
    """When t==h==w positions (text tokens), M-RoPE == standard RoPE."""
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 16, 4, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16)).astype(jnp.int32)
    pos3 = jnp.broadcast_to(pos[..., None], (2, 16, 3))
    a = apply_rope(x, pos)
    b = apply_mrope(x, pos3, sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE inner products depend only on relative positions."""
    rng = np.random.RandomState(6)
    q = jnp.asarray(rng.randn(1, 8, 1, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 8, 1, 16), jnp.float32)
    pos = jnp.arange(8)[None].astype(jnp.int32)
    s1 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, pos), apply_rope(k, pos))
    s2 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, pos + 100), apply_rope(k, pos + 100))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def test_chunked_loss_matches_full_softmax():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=50, loss_chunk=8,
                      dtype="float32", remat=False)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    h = jnp.asarray(rng.randn(2, 24, 16), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 50, (2, 24)), jnp.int32)
    got = float(m.head_loss(params, h, labels))
    logits = h @ np.asarray(m.head_weight(params))
    logz = jax.scipy.special.logsumexp(jnp.asarray(logits), axis=-1)
    tgt = np.take_along_axis(np.asarray(logits), np.asarray(labels)[..., None], axis=-1)[..., 0]
    want = float(jnp.mean(logz - tgt))
    assert abs(got - want) < 1e-4


def test_loss_label_masking():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=50, loss_chunk=8,
                      dtype="float32", remat=False)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    h = jnp.asarray(np.random.RandomState(8).randn(1, 16, 16), jnp.float32)
    labels = jnp.asarray(np.random.RandomState(9).randint(0, 50, (1, 16)), jnp.int32)
    masked = labels.at[:, 8:].set(-1)
    l_full = float(m.head_loss(params, h, labels))
    l_mask = float(m.head_loss(params, h, masked))
    l_first = float(m.head_loss(params, h[:, :8], labels[:, :8]))
    assert abs(l_mask - l_first) < 1e-4
    assert abs(l_mask - l_full) > 1e-6
