"""Serving-path tests: prefill->decode continuation equals full forward, ring
caches bound window memory, serve builders produce working jits, and online
weight-update ingestion shares the training engine's fused vote_update path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.serve.decode import (build_decode_step, build_prefill,
                                build_update_ingest, encode_weight_update)


def _batch(cfg, b, s, seed=0):
    rng = np.random.RandomState(seed)
    out = {
        "inputs": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }
    if cfg.input_kind != "tokens":
        out["inputs"] = jnp.asarray(rng.randn(b, s, cfg.d_model) * 0.3, cfg.activation_dtype)
    if cfg.mrope:
        out["positions3"] = jnp.broadcast_to(out["positions"][..., None], (b, s, 3))
    return out


# rel-error tolerance per arch: attention-only paths are numerically identical
# up to summation order; mamba/windowed-ring paths legitimately differ between
# the chunked-scan (training/prefill) and sequential-recurrence (decode)
# formulations — percent-level after 8 stacked layers (exactness of each
# mechanism in isolation is pinned at ~1e-6 in test_models.py).
_SERVE_TOL = {"qwen2-moe-a2.7b": 3e-3, "gemma3-27b": 5e-2, "jamba-1.5-large-398b": 1.5e-1}


@pytest.mark.parametrize("arch", list(_SERVE_TOL))
def test_prefill_then_decode_equals_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    _, caches = jax.jit(m.prefill)(params, batch)
    nxt = jnp.asarray([[3], [4]], jnp.int32)
    dec = {"inputs": nxt, "positions": jnp.full((b, 1), s, jnp.int32)}
    if cfg.mrope:
        dec["positions3"] = jnp.full((b, 1, 3), s, jnp.int32)
    logits_dec, _ = jax.jit(m.decode_step)(params, caches, dec)

    full = _batch(cfg, b, s + 1)
    full["inputs"] = jnp.concatenate([batch["inputs"], nxt], axis=1)
    h = m.forward_hidden(params, full)
    logits_full = (h[:, -1] @ m.head_weight(params)).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(logits_dec - logits_full)) / jnp.max(jnp.abs(logits_full)))
    assert rel < _SERVE_TOL[arch], (arch, rel)
    # the decision-level invariant holds exactly: same next token
    assert bool(jnp.all(jnp.argmax(logits_dec, -1) == jnp.argmax(logits_full, -1)))


def test_ring_cache_bounds_window_memory():
    """Windowed layers allocate min(window, max_len) slots, not max_len."""
    cfg = get_config("gemma3-27b", smoke=True)  # window=8 in smoke cfg
    m = Model(cfg)
    shapes = m.cache_shapes(batch_size=2, max_len=1024)
    # pattern positions 0..4 are windowed (w=8), position 5 is global
    windowed = shapes["body"][0]["k"].shape
    global_ = shapes["body"][5]["k"].shape
    assert windowed[2] == 8, windowed
    assert global_[2] == 1024, global_


def test_ring_cache_decode_beyond_window():
    """Decoding past the window stays correct (ring overwrite) on a windowed model."""
    from repro.configs.base import LayerSpec, ModelConfig
    cfg = ModelConfig(name="w", family="dense", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      pattern=(LayerSpec(mixer="attn", window=6),), dtype="float32",
                      attn_chunk=8, q_chunk=8, loss_chunk=8, remat=False)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    b, s_total = 1, 20
    rng = np.random.RandomState(2)
    toks = jnp.asarray(rng.randint(0, 64, (b, s_total)), jnp.int32)
    # decode step-by-step through a ring cache of 6 slots
    caches = m.init_cache(b, max_len=s_total)
    logits_steps = []
    for t in range(s_total - 1):
        dec = {"inputs": toks[:, t:t + 1], "positions": jnp.full((b, 1), t, jnp.int32)}
        logits, caches = m.decode_step(params, caches, dec)
        logits_steps.append(logits)
    # full forward reference at the last position
    full = {"inputs": toks[:, :-1],
            "positions": jnp.broadcast_to(jnp.arange(s_total - 1), (b, s_total - 1)).astype(jnp.int32)}
    h = m.forward_hidden(params, full)
    ref = (h[:, -1] @ m.head_weight(params)).astype(jnp.float32)
    err = float(jnp.max(jnp.abs(logits_steps[-1] - ref)))
    assert err < 5e-3, err


def test_serve_builders_run_on_host_mesh():
    cfg = get_config("qwen1.5-4b", smoke=True)
    m = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 8)
    prefill = build_prefill(m, mesh, worker_axes=("data",))
    logits, caches = prefill(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    decode = build_decode_step(m, mesh, worker_axes=("data",))
    dec = {"inputs": jnp.asarray([[1], [2]], jnp.int32),
           "positions": jnp.full((2, 1), 8, jnp.int32)}
    logits2, _ = decode(params, caches, dec)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_online_update_ingest_matches_trainer_server():
    """A serving replica ingesting the packed downlink wire lands on exactly
    the params the trainer's own server_apply produces — bitwise, both wires,
    both backends, including the quorum deadband."""
    from repro.core import engine
    from repro.core.algorithm import CompressionConfig

    cfg = get_config("qwen1.5-4b", smoke=True)
    m = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = m.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    rng = np.random.RandomState(11)
    vote_sums = [jnp.asarray(rng.randint(-4, 5, l.shape), jnp.int32) for l in leaves]
    lr, quorum = 0.05, 2
    comp = CompressionConfig(compressor="sparsign", server="majority_vote")

    # trainer-side oracle: fused vote_update with the deadband
    want = [np.asarray(engine.server_apply(p, v, comp, lr=lr, quorum=quorum)[0])
            for p, v in zip(leaves, vote_sums)]

    other = "interpret" if jax.default_backend() != "tpu" else "pallas"
    for backend in ("jnp", other):
        # packed 2-bit downlink: encoder applies the deadband, replica applies
        packed = jax.tree_util.tree_unflatten(
            treedef, [encode_weight_update(v, quorum=quorum, backend=backend)
                      for v in vote_sums])
        ingest_p = build_update_ingest(m, mesh, lr=lr, wire="packed2bit",
                                       backend=backend, donate=False)
        got_p = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(np.asarray, ingest_p(params, packed)))
        for a, b in zip(got_p, want):
            assert np.array_equal(a, b), backend

        # int wire: raw vote sums, replica applies the deadband
        ingest_i = build_update_ingest(m, mesh, lr=lr, quorum=quorum,
                                       wire="int8", backend=backend, donate=False)
        got_i = jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            np.asarray, ingest_i(params, jax.tree_util.tree_unflatten(treedef, vote_sums))))
        for a, b in zip(got_i, want):
            assert np.array_equal(a, b), backend

    with pytest.raises(ValueError, match="update wire"):
        build_update_ingest(m, mesh, lr=lr, wire="fp32")


def test_scaled_update_ingest_applies_shared_scale():
    """The scaled downlink (TernGrad-style trainers): packed ternary decision
    + one f32 scale per leaf applies p - lr * scale * decision, bitwise equal
    to the trainer's own scaled mean apply."""
    from repro.core import engine
    from repro.core.algorithm import CompressionConfig

    cfg = get_config("qwen1.5-4b", smoke=True)
    m = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = m.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    rng = np.random.RandomState(13)
    decisions = [jnp.asarray(rng.randint(-1, 2, l.shape), jnp.int32) for l in leaves]
    scales = [jnp.float32(0.1 + 0.05 * i) for i in range(len(leaves))]
    lr = 0.05
    comp = CompressionConfig(server="majority_vote")

    # jitted like the ingest step, so XLA's fusion/rounding choices match
    trainer_apply = jax.jit(lambda p, d, s: engine.server_apply(
        p, d, comp, lr=lr, server="mean", n_sel=1.0, scale=s)[0])
    want = [np.asarray(trainer_apply(p, d, s))
            for p, d, s in zip(leaves, decisions, scales)]

    packed = jax.tree_util.tree_unflatten(
        treedef, [encode_weight_update(d) for d in decisions])
    ingest = build_update_ingest(m, mesh, lr=lr, wire="packed2bit", donate=False)
    got = jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        np.asarray,
        ingest(params, packed, jax.tree_util.tree_unflatten(treedef, scales))))
    for a, b in zip(got, want):
        assert np.array_equal(a, b)


def test_packed8_update_ingest_matches_quantized_apply():
    """The 8-bit downlink: encode_weight_update8 quantizes a float server
    delta (qsgd8 levels + one f32 scale per leaf, 1 B/coord) and the replica
    lands on exactly p - lr * scale * levels — bitwise, both backends; the
    scales are mandatory and a quorum is rejected (levels are not votes)."""
    import pytest
    from repro.core import engine
    from repro.kernels import common as kcommon
    from repro.serve.decode import encode_weight_update8
    from repro.core.algorithm import CompressionConfig

    cfg = get_config("qwen1.5-4b", smoke=True)
    m = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = m.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    rng = np.random.RandomState(17)
    deltas = [jnp.asarray(rng.randn(*l.shape), jnp.float32) for l in leaves]
    lr = 0.05
    comp = CompressionConfig(server="majority_vote")

    other = "interpret" if jax.default_backend() != "tpu" else "pallas"
    for backend in ("jnp", other):
        enc = [encode_weight_update8(d, seed=i, backend=backend)
               for i, d in enumerate(deltas)]
        payloads = jax.tree_util.tree_unflatten(treedef, [e[0] for e in enc])
        scales = jax.tree_util.tree_unflatten(treedef, [e[1] for e in enc])
        # trainer-side oracle: the dequantized delta applied via the same
        # jitted mean rule the ingest step runs
        trainer_apply = jax.jit(lambda p, u, s: engine.server_apply(
            p, u, comp, lr=lr, server="mean", n_sel=1.0, scale=s,
            backend=backend)[0])
        want = [np.asarray(trainer_apply(
                    p, kcommon.from_2d(pl8, p.size, p.shape), s))
                for p, (pl8, s) in zip(leaves, enc)]
        ingest = build_update_ingest(m, mesh, lr=lr, wire="packed8",
                                     backend=backend, donate=False)
        got = jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            np.asarray, ingest(params, payloads, scales)))
        for a, b in zip(got, want):
            assert np.array_equal(a, b), backend
        with pytest.raises(ValueError, match="decode scales"):
            ingest(params, payloads)
    with pytest.raises(ValueError, match="not votes"):
        build_update_ingest(m, mesh, lr=lr, wire="packed8", quorum=2)


def test_encoder_prefill_builder():
    cfg = get_config("hubert-xlarge", smoke=True)
    m = Model(cfg)
    mesh = make_host_mesh(1, 1)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 8)
    fwd = build_prefill(m, mesh, worker_axes=("data",))
    loss = fwd(params, batch)
    assert bool(jnp.isfinite(loss))
