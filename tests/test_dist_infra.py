"""Distribution-infrastructure unit tests: HLO collective parser, placement
sanitizer, wire models, logical-axis specs, dry-run helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from benchmarks.bench_collectives import wire_model
from benchmarks.bench_roofline import analytic_cell
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, all_cells
from repro.dist.compat import abstract_mesh
from repro.dist.sharding import logical_to_spec, sanitize_spec
from repro.launch import hlo_stats

HLO_SAMPLE = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%add
  %ag.1 = bf16[64,4096]{1,0} all-gather(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[16,16]{1,0} reduce-scatter(%z), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %unrelated = f32[8]{0} add(%a, %b)
"""


def test_hlo_parser_counts_and_bytes():
    stats = hlo_stats.parse_collectives(HLO_SAMPLE)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "collective-permute": 1}
    # all-reduce: 2*(15/16)*1024*256*4 bytes
    ar = stats.bytes_by_op["all-reduce"]
    assert abs(ar - 2 * 15 / 16 * 1024 * 256 * 4) < 1.0
    # all-gather group of 4: (3/4) * 64*4096*2
    ag = stats.bytes_by_op["all-gather"]
    assert abs(ag - 0.75 * 64 * 4096 * 2) < 1.0
    assert stats.wire_bytes > 0


def test_hlo_parser_group_formats():
    assert hlo_stats._group_size("replica_groups=[32,16]<=[512]", 2) == 16
    assert hlo_stats._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 2) == 4
    assert hlo_stats._group_size("no groups here", 7) == 7


@pytest.fixture(scope="module")
def mesh16():
    # abstract-shaped mesh over 1 device is fine for spec math only
    return abstract_mesh((16, 16), ("data", "model"))


def test_sanitize_spec_nulls_nondividing(mesh16):
    # vocab 50280 not divisible by 16 -> replicated; 8192 is -> kept
    s = sanitize_spec(P("model", None), (50280, 1024), mesh16)
    assert s == P(None, None)
    s2 = sanitize_spec(P("model", None), (8192, 1024), mesh16)
    assert s2 == P("model", None)
    # tuple axes: ('data','model') = 256 must divide
    s3 = sanitize_spec(P(("data", "model")), (512,), mesh16)
    assert s3 == P(("data", "model"))
    s4 = sanitize_spec(P(("data", "model")), (128,), mesh16)
    assert s4 == P(None)


def test_logical_to_spec_rules():
    assert logical_to_spec(("vocab", None)) == P("model", None)
    assert logical_to_spec((None, "heads")) == P(None, "model")
    assert logical_to_spec(("expert", None, "ff")) == P("model", None, "model")


def test_wire_model_orderings():
    n = 10_000_000
    fp32 = wire_model(n, "simple", variant="fp32_dp")["grad_exchange"]
    int8 = wire_model(n, "simple", variant="sparsign_int8")["grad_exchange"]
    assert abs(fp32 / int8 - 4.0) < 0.01
    st = wire_model(n, "streamed", variant="sparsign_int8")
    assert st["fsdp_gather"] > 0 and st["total"] > st["grad_exchange"]


def test_analytic_cell_sanity():
    """Roofline terms positive/finite; decode compute << train compute;
    windowed gemma long-decode cheaper than a hypothetical full-window one."""
    for arch in ("gemma3-27b", "qwen2-moe-a2.7b"):
        tr = analytic_cell(arch, "train_4k", "16x16", "simple")
        de = analytic_cell(arch, "decode_32k", "16x16", "simple")
        for t in (tr, de):
            assert all(np.isfinite(v) and v >= 0 for k, v in t.items() if k.endswith("_s"))
        assert de["compute_s"] < tr["compute_s"] / 100
    g_long = analytic_cell("gemma3-27b", "long_500k", "16x16", "simple")
    assert g_long["memory_s"] < 0.05  # ring caches keep 500k decode cheap


def test_cells_inventory_is_40():
    """10 archs x 4 shapes; skips documented with reasons."""
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rows.extend((arch, s.name, runs, why) for s, runs, why in all_cells(cfg))
    assert len(rows) == 40
    skips = [r for r in rows if not r[2]]
    assert len(skips) == 8
    assert all(r[3] for r in skips), "every skip carries a reason"


def test_shapes_definition():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].seq_len == 32768
