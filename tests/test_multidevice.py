"""Multi-device integration tests, each in a subprocess with 8 forced host
devices (the main pytest process must keep jax at 1 device for the smoke tests).

  check_step_simple      — mesh train step == explicit M-worker oracle (bitwise);
                           EF server; tau=2 local updates.
  check_step_streamed    — streamed(FSDP) == simple (bitwise); EF; shard check.
  check_wires            — all three vote wires bitwise-equal to the vote_psum
                           stream, simple AND streamed, jnp AND interpret.
  check_fault_tolerance  — crash/restart bitwise replay; elastic mesh restore;
                           elastic-participation parity (weighted vote at full
                           participation == legacy, every wire mode, both
                           backends); chaos (50% per-round report dropout on
                           every gather wire); M-invariance of the normalized
                           vote (4- vs 2-worker fleets on identical data).
"""

import pytest

from conftest import run_mdev as _run


@pytest.mark.slow
def test_simple_step_equivalence_and_variants():
    out = _run("check_step_simple.py")
    assert "OK simple-step == 4-worker oracle" in out
    assert "OK engine interpret backend == pre-refactor oracle" in out
    assert "OK EF server" in out
    assert "OK local-update (tau=2)" in out


@pytest.mark.slow
def test_streamed_step_equivalence():
    out = _run("check_step_streamed.py")
    assert "0/" in out and "coords differ" in out
    assert "OK FSDP sharding" in out
    assert "OK streamed EF" in out


@pytest.mark.slow
def test_wire_equivalence_all_modes():
    out = _run("check_wires.py", timeout=2400)
    assert "OK simple-mode wires bitwise-equal (3 wires x 2 backends)" in out
    assert "OK streamed-mode wires bitwise-equal (3 wires x 2 backends)" in out


@pytest.mark.slow
def test_fault_tolerance_and_elastic():
    out = _run("check_fault_tolerance.py")
    assert "OK crash/restart" in out
    assert "OK elastic" in out
    for tag in ("votes/psum", "votes/gather", "pack8/gather", "decoded/psum"):
        assert f"OK elastic parity {tag}" in out
    for tag in ("votes/gather", "pack8/gather", "golomb/gather"):
        assert f"OK chaos {tag}" in out
    assert out.count("OK M-invariance") == 2
