"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import common
from repro.kernels.ef_server.ops import ef_server_op
from repro.kernels.ef_server.ref import ef_scale, ef_server_ref
from repro.kernels.pack2bit.ops import pack2bit_op, unpack2bit_op
from repro.kernels.pack2bit.ref import pack2bit_ref, unpack2bit_ref
from repro.kernels.sparsign.ops import sparsign_op
from repro.kernels.sparsign.ref import sparsign_ref
from repro.kernels.ternary.ops import ternary_compress_op, ternary_pack2bit_op
from repro.kernels.ternary.ref import ternary_compress_ref, ternary_pack2bit_ref
from repro.kernels.ternary.rules import RULES
from repro.kernels.vote_update.ops import vote_update_op
from repro.kernels.vote_update.ref import vote_update_ref

SHAPES = [(64,), (1000,), (7, 333), (2, 3, 129), (513, 511), (1 << 16,)]
DTYPES = ["float32", "bfloat16"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparsign_kernel_matches_ref(shape, dtype):
    g = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    for budget, seed, base in [(0.3, 1, 0), (1.5, 99, 12345), (50.0, 7, 2**20)]:
        a = sparsign_op(g, budget, seed, base)
        b = sparsign_ref(g, budget, seed, base)
        assert a.dtype == jnp.int8 and a.shape == g.shape
        assert np.array_equal(np.asarray(a), np.asarray(b)), (shape, dtype, budget)


@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_sparsign_kernel_property(n, seed):
    g = jnp.asarray(np.random.RandomState(seed % 9973).randn(n), jnp.float32)
    a = sparsign_op(g, 0.8, seed)
    b = sparsign_ref(g, 0.8, seed)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# one representative param per rule: sparsign/noisy_sign take a budget/sigma,
# the stochastic family takes a magnitude normalizer s_t
RULE_PARAMS = [("sparsign", 1.5), ("sign", 0.0), ("noisy_sign", 0.3),
               ("stochastic_ternary", 1.2)]


@pytest.mark.parametrize("shape", [(63,), (1000,), (7, 333), (513, 511)])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rule,param", RULE_PARAMS)
def test_ternary_template_matches_ref(shape, dtype, rule, param):
    """The generic ternary kernel template == the prng-based oracle, bitwise,
    over odd shapes / bf16 / nonzero counter_base — same pin the dedicated
    sparsign kernel carries."""
    g = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    for seed, base in [(1, 0), (99, 12345), (7, 2**20)]:
        a = ternary_compress_op(g, param, seed, base, rule=rule)
        b = ternary_compress_ref(g, param, seed, base, rule=rule)
        assert a.dtype == jnp.int8 and a.shape == g.shape
        assert set(np.unique(np.asarray(a))).issubset({-1, 0, 1})
        assert np.array_equal(np.asarray(a), np.asarray(b)), (shape, dtype, rule, seed)


@pytest.mark.parametrize("shape", [(63,), (7, 333), (513, 511)])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rule,param", RULE_PARAMS)
def test_ternary_fused_pack_matches_two_pass(shape, dtype, rule, param):
    """fused compress->pack2bit == pack2bit_op(compress_op(g)) byte-for-byte.
    noisy_sign is the sharp edge: its rule signs pure noise at zero input, so
    the kernel must zero the canonical-view padding explicitly."""
    g = jnp.asarray(np.random.RandomState(1).randn(*shape), dtype)
    for seed, base in [(3, 0), (11, 4096)]:
        fused = ternary_pack2bit_op(g, param, seed, base, rule=rule)
        two_pass = pack2bit_op(ternary_compress_op(g, param, seed, base, rule=rule))
        ref = ternary_pack2bit_ref(g, param, seed, base, rule=rule)
        assert fused.dtype == jnp.uint8
        assert np.array_equal(np.asarray(fused), np.asarray(two_pass)), (shape, rule)
        assert np.array_equal(np.asarray(fused), np.asarray(ref)), (shape, rule)


def test_ternary_template_sparsign_rule_matches_dedicated_kernel():
    """The template instantiated with the sparsign rule reproduces the
    dedicated sparsign kernel bit-for-bit — one rule table, no drift."""
    g = jnp.asarray(np.random.RandomState(2).randn(1000), jnp.float32)
    a = ternary_compress_op(g, 0.8, 7, 3, rule="sparsign")
    b = sparsign_op(g, 0.8, 7, 3)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert set(RULES) >= {"sparsign", "sign", "noisy_sign", "stochastic_ternary"}


@pytest.mark.parametrize("shape", SHAPES)
def test_pack_unpack_roundtrip(shape):
    t = jnp.asarray(np.random.RandomState(1).randint(-1, 2, size=shape), jnp.int8)
    p = pack2bit_op(t)
    assert p.dtype == jnp.uint8
    u = unpack2bit_op(p, t.size, shape)
    assert np.array_equal(np.asarray(u), np.asarray(t))
    # vs ref on the canonical view
    view, _ = common.to_2d(t.reshape(-1))
    assert np.array_equal(np.asarray(p), np.asarray(pack2bit_ref(view)))
    assert np.array_equal(np.asarray(unpack2bit_ref(pack2bit_ref(view))), np.asarray(view))


def test_pack_density():
    """Wire density: exactly 2 bits per coordinate of the canonical view."""
    t = jnp.asarray(np.random.RandomState(2).randint(-1, 2, size=(100000,)), jnp.int8)
    p = pack2bit_op(t)
    view, _ = common.to_2d(t)
    assert p.size == view.size // 4


@pytest.mark.parametrize("shape", [(512,), (33, 65), (4096,)])
def test_ef_server_fused(shape):
    rng = np.random.RandomState(3)
    d = jnp.asarray(rng.randn(*shape), jnp.float32)
    e = jnp.asarray(rng.randn(*shape), jnp.float32)
    out, ne = ef_server_op(d, e)
    ro, rne = ef_server_ref(d, e, ef_scale(d, e))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ne), np.asarray(rne), rtol=1e-6, atol=1e-6)
    # EF identity: out + new_residual == delta + old_residual (exactly, Eq. 8)
    np.testing.assert_allclose(np.asarray(out + ne), np.asarray(d + e), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("quorum", [1, 3])
def test_vote_update(dtype, quorum):
    rng = np.random.RandomState(4)
    w = jnp.asarray(rng.randn(777), dtype)
    v = jnp.asarray(rng.randint(-5, 6, size=777), jnp.int32)
    a = vote_update_op(w, v, 0.05, quorum=quorum)
    b = vote_update_ref(w, v, 0.05, quorum=quorum)
    assert a.dtype == w.dtype
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_vote_update_semantics():
    w = jnp.zeros((8,), jnp.float32)
    v = jnp.asarray([3, -2, 0, 1, -1, 5, -5, 0], jnp.int32)
    out = np.asarray(vote_update_op(w, v, 1.0))
    assert np.array_equal(out, -np.sign(np.asarray(v)).astype(np.float32))
