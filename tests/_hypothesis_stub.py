"""Deterministic stand-in for `hypothesis`, installed by conftest.py ONLY when
the real package is missing (see requirements-dev.txt — environments that can
pip install get the real engine and never load this file).

Covers exactly the surface this suite uses — @given with keyword strategies,
@settings(max_examples=..., deadline=...), st.integers, st.floats — by running
the test body over a fixed-seed pseudo-random sample of the strategy space.
No shrinking, no database, no health checks: strictly a degraded-but-honest
property check so the tier-1 suite collects and runs on the pinned container.
"""

from __future__ import annotations

import functools
import inspect
import random
import types

_DEFAULT_MAX_EXAMPLES = 15
_STUB_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # draw(rng, example_index) — stateless per run


def _integers(min_value, max_value):
    return _Strategy(lambda rng, i: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    # examples 0 and 1 are the endpoints, the rest uniform — indexed per run
    # so repeated executions of one test see the identical sequence
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


strategies = types.SimpleNamespace(integers=_integers, floats=_floats)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_STUB_SEED)
            for i in range(n):
                draws = {k: s.draw(rng, i) for k, s in kw_strategies.items()}
                fn(*args, **kwargs, **draws)

        # Hide the strategy-filled params from pytest's signature inspection,
        # or it would try to resolve them as fixtures.
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kw_strategies])
        if hasattr(run, "__wrapped__"):
            del run.__wrapped__
        return run
    return deco


HealthCheck = types.SimpleNamespace()  # imported by some suites; unused here
