"""Substrate tests: EF boundedness (Lemma 2), PRNG quality, encoding (Eq. 12),
checkpointing, data pipelines, worker sampling."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import prng
from repro.core.aggregation import alpha_of_scaled_sign, scaled_sign_server
from repro.core.encoding import (baseline_bits_per_round, golomb_bits_per_index,
                                 golomb_bstar, round_bits, ternary_stream_bits)
from repro.core.error_feedback import ef_server_step, init_ef
from repro.data.dirichlet import dirichlet_partition, heterogeneity_stats
from repro.data.synthetic import LMStreamConfig, lm_batch, make_image_dataset, ImageDataConfig
from repro.train import checkpoint as ckpt
from repro.train.sampling import participation_mask, round_seed
from repro.train.state import TrainState


# ---------------------------------------------------------------------------
# Error feedback (Lemma 2)
# ---------------------------------------------------------------------------

def test_ef_residual_bounded():
    """||e_t||^2 stays bounded over many rounds (Lemma 2)."""
    rng = np.random.RandomState(0)
    d = 2048
    state = init_ef(jnp.zeros(d))
    norms = []
    for t in range(200):
        delta = jnp.asarray(np.sign(rng.randn(d)) * rng.rand(d), jnp.float32)
        _, state = ef_server_step(state, delta)
        norms.append(float(jnp.sum(state.residual ** 2)))
    # bounded: the last 100 rounds don't grow
    assert max(norms[100:]) < 4.0 * max(norms[:100]) + 1e-6
    assert np.isfinite(norms[-1])


def test_scaled_sign_is_alpha_approximate():
    """||C(x) - x||^2 <= (1 - alpha) ||x||^2 with alpha = ||x||_1^2/(d ||x||_2^2)."""
    rng = np.random.RandomState(1)
    for _ in range(10):
        x = jnp.asarray(rng.randn(512) * rng.rand(), jnp.float32)
        cx = scaled_sign_server(x)
        alpha = float(alpha_of_scaled_sign(x))
        assert 0.0 < alpha <= 1.0 + 1e-6
        lhs = float(jnp.sum((cx - x) ** 2))
        rhs = (1.0 - alpha) * float(jnp.sum(x ** 2))
        assert lhs <= rhs + 1e-4


# ---------------------------------------------------------------------------
# PRNG quality
# ---------------------------------------------------------------------------

def test_prng_uniformity():
    u = np.asarray(prng.uniform01(123, jnp.arange(200000, dtype=jnp.uint32)))
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(np.mean(u < 0.25) - 0.25) < 0.01
    # serial correlation
    assert abs(np.corrcoef(u[:-1], u[1:])[0, 1]) < 0.01


def test_prng_seed_independence():
    c = jnp.arange(100000, dtype=jnp.uint32)
    u1 = np.asarray(prng.uniform01(1, c))
    u2 = np.asarray(prng.uniform01(2, c))
    assert abs(np.corrcoef(u1, u2)[0, 1]) < 0.01


def test_fold_seed_distinct():
    seeds = {int(prng.fold_seed(42, i, j)) for i in range(20) for j in range(20)}
    assert len(seeds) == 400


# ---------------------------------------------------------------------------
# Encoding (Eq. 12)
# ---------------------------------------------------------------------------

def test_golomb_formula():
    # sparser streams need more bits per index; b* is nonnegative and monotone
    assert golomb_bstar(0.5) >= 0
    assert golomb_bstar(0.01) > golomb_bstar(0.2)
    assert golomb_bits_per_index(0.01) > golomb_bits_per_index(0.1) > golomb_bits_per_index(0.5)


@given(p=st.floats(0.001, 0.6))
@settings(max_examples=30, deadline=None)
def test_golomb_beats_naive_for_sparse(p):
    d = 100000
    nnz = max(1, int(p * d))
    g = ternary_stream_bits(d, nnz, coder="golomb")
    naive = ternary_stream_bits(d, nnz, coder="naive_index")
    assert g <= naive * 1.05


def test_round_bits_downlink_modes():
    d, nnz, m = 10000, 500, 100
    free = round_bits(d, nnz, m, downlink="free")
    sign = round_bits(d, nnz, m, downlink="sign")
    assert sign == free + d


def test_baseline_bits():
    d = 1000
    assert baseline_bits_per_round(d, "sign") == d
    assert baseline_bits_per_round(d, "identity") == 32 * d
    assert baseline_bits_per_round(d, "sparsign", nnz=100) < d  # sparser than 1 bit/coord
    # regression (PR 5): qsgd8 counts its 32-bit decode scale like the wire
    # ledger does (8 bits/coord + one f32 per message), and unknown algorithms
    # stay loud (no startswith("qsgd") catch-all)
    assert baseline_bits_per_round(d, "qsgd8") == 8 * d + 32
    with pytest.raises(ValueError):
        baseline_bits_per_round(d, "qsgd_777")


# ---------------------------------------------------------------------------
# Checkpointing / fault tolerance
# ---------------------------------------------------------------------------

def _tiny_state(seed=0):
    rng = np.random.RandomState(seed)
    return TrainState(
        params={"a": jnp.asarray(rng.randn(4, 8), jnp.float32),
                "b": (jnp.asarray(rng.randn(3), jnp.bfloat16),)},
        ef_residual=None,
        step=jnp.int32(7), seed=jnp.uint32(42))


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    ckpt.save(str(tmp_path), 7, state)
    restored, manifest = ckpt.restore(str(tmp_path), state)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest(tmp_path):
    state = _tiny_state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    state = _tiny_state()
    ckpt.save(str(tmp_path), 1, state)
    other = TrainState(params={"a": state.params["a"]}, ef_residual=None,
                       step=state.step, seed=state.seed)
    with pytest.raises(ckpt.CheckpointMismatchError, match="different model"):
        ckpt.restore(str(tmp_path), other)


def test_checkpoint_fingerprint_catches_shape_and_dtype_drift(tmp_path):
    """Same tree structure, different leaf shape/dtype -> loud mismatch (the
    stale-/tmp-checkpoint footgun: blind resume into another model config)."""
    state = _tiny_state()
    ckpt.save(str(tmp_path), 1, state)
    reshaped = TrainState(
        params={"a": jnp.zeros((8, 4), jnp.float32), "b": state.params["b"]},
        ef_residual=None, step=state.step, seed=state.seed)
    with pytest.raises(ckpt.CheckpointMismatchError):
        ckpt.restore(str(tmp_path), reshaped)
    retyped = TrainState(
        params={"a": state.params["a"].astype(jnp.bfloat16), "b": state.params["b"]},
        ef_residual=None, step=state.step, seed=state.seed)
    with pytest.raises(ckpt.CheckpointMismatchError):
        ckpt.restore(str(tmp_path), retyped)
    # matching state still round-trips, and the manifest carries the print
    restored, manifest = ckpt.restore(str(tmp_path), state)
    assert manifest["fingerprint"] == ckpt.tree_fingerprint(state)


def test_loop_skips_stale_checkpoint_with_warning(tmp_path):
    """train.loop must not blindly resume from a checkpoint another model
    config wrote into the same dir: it warns loudly and starts fresh."""
    from repro.train import loop as loop_lib
    stale = _tiny_state()
    ckpt.save(str(tmp_path), 5, stale)

    fresh = TrainState(params={"w": jnp.zeros((3, 3), jnp.float32)},
                       ef_residual=None, step=jnp.int32(0), seed=jnp.uint32(0))
    calls = []

    def fake_step(state, batch):
        calls.append(int(state.step))
        return TrainState(params=state.params, ef_residual=None,
                          step=state.step + 1, seed=state.seed), {"loss": jnp.float32(0.0)}

    logs = []
    cfg = loop_lib.LoopConfig(total_steps=2, ckpt_dir=str(tmp_path),
                              ckpt_every=0, log_every=1)
    out, history = loop_lib.run(fake_step, fresh, lambda i: {}, cfg,
                                log=logs.append)
    assert calls == [0, 1], calls                      # started fresh, not at 5
    assert any("WARNING" in line for line in logs), logs
    assert int(out.step) == 2


def test_loop_resumes_newest_compatible_past_stale_shadow(tmp_path):
    """A stale high-step checkpoint must not shadow this run's own valid
    checkpoints at lower steps: resume picks the newest COMPATIBLE one."""
    from repro.train import loop as loop_lib
    stale = _tiny_state()
    ckpt.save(str(tmp_path), 500, stale)      # foreign config, highest step

    own = TrainState(params={"w": jnp.ones((2, 2), jnp.float32)},
                     ef_residual=None, step=jnp.int32(30), seed=jnp.uint32(0))
    ckpt.save(str(tmp_path), 30, own)         # this run's real checkpoint

    calls = []

    def fake_step(state, batch):
        calls.append(int(state.step))
        return TrainState(params=state.params, ef_residual=None,
                          step=state.step + 1, seed=state.seed), {"loss": jnp.float32(0.0)}

    logs = []
    like = TrainState(params={"w": jnp.zeros((2, 2), jnp.float32)},
                      ef_residual=None, step=jnp.int32(0), seed=jnp.uint32(0))
    cfg = loop_lib.LoopConfig(total_steps=32, ckpt_dir=str(tmp_path),
                              ckpt_every=0, log_every=1)
    out, _ = loop_lib.run(fake_step, like, lambda i: {}, cfg, log=logs.append)
    assert calls == [30, 31], calls            # resumed at 30, not 0, not 500
    assert any("skipping checkpoint step_00000500" in l for l in logs), logs
    assert float(out.params["w"][0, 0]) == 1.0  # really loaded step-30 payload


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    state = _tiny_state()
    ckpt.save(str(tmp_path), 3, state)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# Data pipelines
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic():
    cfg = LMStreamConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=9)
    a, b = lm_batch(cfg, 5), lm_batch(cfg, 5)
    assert np.array_equal(a["inputs"], b["inputs"])
    c = lm_batch(cfg, 6)
    assert not np.array_equal(a["inputs"], c["inputs"])
    assert a["inputs"].max() < 1000 and a["inputs"].min() >= 0


def test_dirichlet_partition_covers_and_skews():
    x, y, _, _ = make_image_dataset(ImageDataConfig(n_train=2000, n_test=10))
    parts = dirichlet_partition(y, n_workers=20, alpha=0.1, seed=0)
    stats = heterogeneity_stats(y, parts)
    assert stats["mean_label_entropy"] < 0.75 * stats["max_entropy"], "alpha=0.1 must skew"
    parts_iid = dirichlet_partition(y, n_workers=20, alpha=100.0, seed=0)
    stats_iid = heterogeneity_stats(y, parts_iid)
    assert stats_iid["mean_label_entropy"] > stats["mean_label_entropy"]


# ---------------------------------------------------------------------------
# Worker sampling
# ---------------------------------------------------------------------------

def test_participation_rate_and_determinism():
    rs = round_seed(123, 0)
    hits = [bool(participation_mask(rs, 0, w, 0.3)) for w in range(2000)]
    rate = np.mean(hits)
    assert abs(rate - 0.3) < 0.05
    hits2 = [bool(participation_mask(rs, 0, w, 0.3)) for w in range(2000)]
    assert hits == hits2
    assert bool(participation_mask(rs, 0, 5, 1.0)) is True
