"""Backend equivalence for the compression engine.

The contract the engine sells: ``jnp``, ``interpret`` and ``pallas`` are the
same algorithm bit-for-bit (shared counter-based PRNG; the kernels regenerate
it in-register). CI pins ``jnp == interpret`` on CPU; on a real TPU the same
tests pin ``jnp == pallas``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.algorithm import (MAX_LOCAL_STEPS, CompressionConfig,
                                  local_update_message)
from repro.core.budgets import BudgetConfig
from repro.core.compressors import (SCALE_PROTOCOLS, SERVER_DECODES, SPECS,
                                    get_spec)

# odd sizes exercise the canonical-view padding; bf16 the kernel upcast path
SHAPES = [(63,), (1000,), (7, 333)]
DTYPES = ["float32", "bfloat16"]
OTHER = "interpret" if jax.default_backend() != "tpu" else "pallas"

# every compressor whose spec registers a Pallas op — the kernel-vs-jnp
# equivalence matrix IS the registry, no hand-kept list
KERNEL_BACKED = sorted(n for n, s in SPECS.items() if s.pallas_op is not None)


def _cfg(compressor="sparsign", server="majority_vote", value=1.0):
    return CompressionConfig(compressor=compressor,
                             budget=BudgetConfig(kind="fixed", value=value),
                             server=server)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("compressor", KERNEL_BACKED)
def test_compress_leaf_backend_equivalence(shape, dtype, compressor):
    """jnp == kernel for values AND the decode scale (the scale round-trip:
    scaled_sign's L1/d, qsgd_1bit's norms, terngrad's local max)."""
    g = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    for counter_base in (0, 12345):
        a = engine.compress_leaf(g, _cfg(compressor), 9, counter_base, backend="jnp")
        b = engine.compress_leaf(g, _cfg(compressor), 9, counter_base, backend=OTHER)
        assert a.values.dtype == jnp.int8 and b.values.dtype == jnp.int8
        assert a.values.shape == g.shape
        assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
        assert np.array_equal(np.asarray(a.scale), np.asarray(b.scale))


def test_spec_registry_is_total_and_wellformed():
    """Every registered compressor has a complete, self-consistent spec row."""
    from repro.core.compressors import WIRE_FORMATS
    for name, spec in SPECS.items():
        assert spec.name == name
        assert callable(spec.api) and callable(spec.values)
        assert spec.scale_protocol in SCALE_PROTOCOLS
        assert spec.server_decode in SERVER_DECODES
        assert spec.wire_format in WIRE_FORMATS
        assert (spec.local_scale is None) == (spec.scale_protocol == "none")
        # wire_format is the declarative negotiation key: the ternary
        # compressors ride the 2-bit packed wire or its entropy-coded golomb
        # sibling; everything else is pack8/float
        assert (spec.wire_format in ("pack2", "golomb")) == spec.is_ternary
        if spec.fused_pack_op is not None:
            assert spec.wire_format != "float" and spec.pallas_op is not None
        # ternary <-> CompressionConfig.is_ternary agrees with the table
        assert _cfg(name).is_ternary == spec.is_ternary
    assert SPECS["qsgd8"].wire_format == "pack8"
    assert SPECS["identity"].wire_format == "float"
    assert SPECS["sparsign_golomb"].wire_format == "golomb"
    with pytest.raises(KeyError, match="unknown compressor"):
        get_spec("bogus")


def test_wire_mode_negotiation():
    """(compressor, server, vote_impl) -> wire mode is a pure spec lookup."""
    assert engine.wire_mode(_cfg("sparsign")) == "votes"
    assert engine.wire_mode(_cfg("noisy_sign", server="scaled_sign_ef")) == "votes"
    # shared-scale ternary + mean server: integer votes + ONE scalar
    assert engine.wire_mode(_cfg("terngrad", server="mean")) == "scaled_votes"
    assert engine.wire_mode(_cfg("sign", server="mean")) == "scaled_votes"
    # per-worker scales on ternary wires stay on the float wire
    assert engine.wire_mode(_cfg("qsgd_1bit_l2", server="mean")) == "decoded"
    assert engine.wire_mode(_cfg("scaled_sign", server="mean")) == "decoded"
    assert engine.wire_mode(_cfg("identity", server="mean")) == "decoded"
    # pack8 payloads take the 8-bit gather when the gather wire is selected,
    # decoded psum otherwise (levels cannot be reduced on the fabric)
    for server in ("mean", "majority_vote"):
        assert engine.wire_mode(_cfg("qsgd8", server=server)) == "decoded"
        assert engine.wire_mode(_cfg("qsgd8", server=server),
                                vote_impl="allgather_packed") == "pack8"
        assert engine.wire_mode(_cfg("qsgd8", server=server),
                                vote_impl="hier") == "decoded"
    # the gather impl does not perturb the ternary/float rows
    assert engine.wire_mode(_cfg("sparsign"),
                            vote_impl="allgather_packed") == "votes"
    assert engine.wire_mode(_cfg("identity", server="mean"),
                            vote_impl="allgather_packed") == "decoded"


def test_compress_leaf_shared_linf_mapped_context_is_loud():
    """Regression (PR 5): inside a mapped (multi-worker) context a shared_max
    compressor without shared_linf= must raise, not silently degrade to the
    per-worker local norm — that degrade IS the TernGrad drift PR 4 killed.
    Outside a mesh the single-worker degrade stays available (public API)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.launch.mesh import make_host_mesh

    g = jnp.asarray(np.random.RandomState(11).randn(64), jnp.float32)
    # outside any mapped context: degrades to the local L-inf, loudly documented
    msg = engine.compress_leaf(g, _cfg("terngrad"), 3, backend="jnp")
    assert float(msg.scale) == float(jnp.max(jnp.abs(g)))

    mesh = make_host_mesh(1, 1)

    def body(x):
        return engine.compress_leaf(x, _cfg("terngrad"), 3, backend="jnp").values

    mapped = compat.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                              axis_names={"data"}, check_vma=False)
    with pytest.raises(ValueError, match="shared_linf"):
        with compat.set_mesh(mesh):
            jax.jit(mapped)(g)

    # supplying shared_linf inside the same mapped context is fine
    def body_ok(x):
        from repro.dist import collectives
        shared = collectives.worker_shared_linf(x, ("data",))
        return engine.compress_leaf(x, _cfg("terngrad"), 3, backend="jnp",
                                    shared_linf=shared).values

    mapped_ok = compat.shard_map(body_ok, mesh=mesh, in_specs=(P(),),
                                 out_specs=P(), axis_names={"data"},
                                 check_vma=False)
    with compat.set_mesh(mesh):
        out = jax.jit(mapped_ok)(g)
    assert out.shape == g.shape


def test_needs_shared_linf():
    assert engine.needs_shared_linf(_cfg("terngrad", server="mean"))
    assert engine.needs_shared_linf(_cfg("terngrad"))   # any server: Q needs s_t
    assert not engine.needs_shared_linf(_cfg("sparsign"))
    linf_budget = CompressionConfig(budget=BudgetConfig(kind="linf_share"))
    assert engine.needs_shared_linf(linf_budget)


def test_terngrad_shared_linf_scale_roundtrip():
    """shared_linf drives both the Bernoulli probabilities and the decode
    scale, identically on both backends (the Appendix B protocol)."""
    g = jnp.asarray(np.random.RandomState(3).randn(513), jnp.float32)
    shared = jnp.float32(2.5 * float(jnp.max(jnp.abs(g))))
    msgs = {}
    for backend in ("jnp", OTHER):
        local = engine.compress_leaf(g, _cfg("terngrad"), 5, backend=backend)
        m = engine.compress_leaf(g, _cfg("terngrad"), 5, backend=backend,
                                 shared_linf=shared)
        assert float(m.scale) == float(shared)
        assert float(local.scale) == float(jnp.max(jnp.abs(g)))
        # a larger normalizer keeps fewer coordinates on average
        assert float(jnp.sum(jnp.abs(m.values))) <= float(jnp.sum(jnp.abs(local.values)))
        msgs[backend] = m
    a, b = msgs.values()
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))


def test_broadcast_quorum():
    tree = {"embed": jnp.zeros(4), "blocks": {"w": jnp.zeros(2), "b": jnp.zeros(2)}}
    # scalar broadcast
    q = engine.broadcast_quorum(3, tree)
    assert jax.tree_util.tree_leaves(q) == [3, 3, 3]
    # prefix tree: one int per top-level key fans out over the subtree
    q = engine.broadcast_quorum({"embed": 7, "blocks": 1}, tree)
    assert q["embed"] == 7 and q["blocks"] == {"w": 1, "b": 1}
    # full tree also accepted
    q = engine.broadcast_quorum({"embed": 2, "blocks": {"w": 4, "b": 5}}, tree)
    assert q["blocks"]["w"] == 4 and q["blocks"]["b"] == 5
    # validation: bad prefix / non-int / < 1 fail loudly at build time
    with pytest.raises(ValueError, match="prefix"):
        engine.broadcast_quorum({"embed": 1}, tree)
    with pytest.raises(ValueError, match="ints >= 1"):
        engine.broadcast_quorum({"embed": 0, "blocks": 1}, tree)
    with pytest.raises(ValueError, match="ints >= 1"):
        engine.broadcast_quorum({"embed": 1.5, "blocks": 1}, tree)
    with pytest.raises(ValueError, match="ints >= 1"):
        engine.broadcast_quorum(0, tree)


@pytest.mark.parametrize("server", ["majority_vote", "scaled_sign_ef", "mean"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_server_apply_backend_equivalence(server, dtype):
    rng = np.random.RandomState(1)
    p = jnp.asarray(rng.randn(777), dtype)
    vote_sum = jnp.asarray(rng.randint(-5, 6, 777), jnp.int32)
    ef = jnp.asarray(rng.randn(777), jnp.float32)
    kw = dict(lr=0.05, ef=ef, n_sel=jnp.float32(4.0))
    a_p, a_ef = engine.server_apply(p, vote_sum, _cfg(server=server), backend="jnp", **kw)
    b_p, b_ef = engine.server_apply(p, vote_sum, _cfg(server=server), backend=OTHER, **kw)
    assert a_p.dtype == p.dtype and b_p.dtype == p.dtype
    assert np.array_equal(np.asarray(a_p), np.asarray(b_p))
    assert np.array_equal(np.asarray(a_ef), np.asarray(b_ef))


@pytest.mark.parametrize("backend", ["jnp", OTHER])
def test_server_apply_sharded_scale_matches_unsharded(backend):
    """streamed-mode contract: per-shard server_apply with an l1_reduce over the
    shards == one whole-leaf server_apply, for the EF server. The non-jnp case
    exercises ef_server_op's external-scale parameter on partial shards."""
    rng = np.random.RandomState(2)
    n, k = 1024, 4
    p = jnp.asarray(rng.randn(n), jnp.float32)
    votes = jnp.asarray(rng.randint(-3, 4, n), jnp.int32)
    ef = jnp.asarray(rng.randn(n), jnp.float32)
    cfg = _cfg(server="scaled_sign_ef")
    whole_p, whole_ef = engine.server_apply(p, votes, cfg, lr=0.1, ef=ef,
                                            n_sel=2.0, backend="jnp")
    # the cross-shard-reduced L1 the streamed trainer would psum (computed here
    # with the same whole-leaf reduction so the comparison is bitwise)
    total_l1 = jnp.sum(jnp.abs(votes.astype(jnp.float32) / 2.0 + ef))
    got_p, got_ef = [], []
    for j in range(k):
        sl = slice(j * (n // k), (j + 1) * (n // k))
        sp, se = engine.server_apply(
            p[sl], votes[sl], cfg, lr=0.1, ef=ef[sl], n_sel=2.0,
            leaf_size=n, l1_reduce=lambda part: total_l1, backend=backend)
        got_p.append(sp)
        got_ef.append(se)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(got_p)), np.asarray(whole_p))
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(got_ef)), np.asarray(whole_ef))


def test_server_apply_mean_scale():
    """The scaled_votes decode: mean rule with a shared scale == decoding the
    votes by hand. scale=None stays bitwise-identical to the legacy path."""
    rng = np.random.RandomState(8)
    p = jnp.asarray(rng.randn(257), jnp.float32)
    votes = jnp.asarray(rng.randint(-3, 4, 257), jnp.int32)
    scale = jnp.float32(0.37)
    got, _ = engine.server_apply(p, votes, _cfg("terngrad", server="mean"),
                                 lr=0.1, n_sel=4.0, scale=scale, backend="jnp")
    want = p - jnp.float32(0.1) * (votes.astype(jnp.float32) / 4.0 * scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    plain, _ = engine.server_apply(p, votes, _cfg(server="mean"), lr=0.1,
                                   n_sel=4.0, backend="jnp")
    one, _ = engine.server_apply(p, votes, _cfg(server="mean"), lr=0.1,
                                 n_sel=4.0, scale=jnp.float32(1.0), backend="jnp")
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(one))


def test_backend_resolution(monkeypatch):
    monkeypatch.delenv(engine.ENV_VAR, raising=False)
    auto = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert engine.resolve_backend() == auto
    assert engine.resolve_backend("auto") == auto
    monkeypatch.setenv(engine.ENV_VAR, "interpret")
    assert engine.resolve_backend() == "interpret"
    assert engine.resolve_backend("jnp") == "jnp"  # explicit beats env
    monkeypatch.setenv(engine.ENV_VAR, "nope")
    with pytest.raises(ValueError):
        engine.resolve_backend()


def test_env_var_drives_dispatch(monkeypatch):
    """The env-var path end-to-end: backend=None + $REPRO_KERNEL_BACKEND must
    actually steer dispatch (kernel vs reference) and stay bitwise-equal."""
    g = jnp.asarray(np.random.RandomState(5).randn(513), jnp.float32)
    monkeypatch.setenv(engine.ENV_VAR, "jnp")
    a = engine.compress_leaf(g, _cfg(), 3, 7)
    monkeypatch.setenv(engine.ENV_VAR, OTHER)
    b = engine.compress_leaf(g, _cfg(), 3, 7)
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values))
    p = jnp.asarray(np.random.RandomState(6).randn(513), jnp.float32)
    v = jnp.asarray(np.random.RandomState(7).randint(-3, 4, 513), jnp.int8)
    pb, _ = engine.server_apply(p, v, _cfg(), lr=0.1)
    monkeypatch.setenv(engine.ENV_VAR, "jnp")
    pa, _ = engine.server_apply(p, v, _cfg(), lr=0.1)
    assert np.array_equal(np.asarray(pa), np.asarray(pb))


def test_vote_server_predicates():
    assert engine.is_vote_server(_cfg(server="majority_vote"))
    assert engine.is_vote_server(_cfg(server="scaled_sign_ef"))
    assert not engine.is_vote_server(_cfg(server="mean"))
    assert engine.needs_server_ef("scaled_sign_ef")
    assert not engine.needs_server_ef("majority_vote")


def test_unknown_server_raises():
    with pytest.raises(ValueError, match="server rule"):
        engine.server_apply(jnp.zeros(8), jnp.zeros(8, jnp.int32),
                            _cfg(server="bogus"), lr=0.1)


def test_local_step_config_budget_fallback():
    cfg = _cfg(value=3.0)
    assert engine.local_budget_value(cfg) == 3.0            # fixed B_g doubles as B_l
    cfg2 = CompressionConfig(budget=BudgetConfig(value=3.0), local_budget=10.0)
    assert engine.local_budget_value(cfg2) == 10.0
    lc = engine.local_step_config(cfg2)
    assert lc.compressor == "sparsign" and lc.budget.kind == "fixed"
    assert lc.budget.value == 10.0 and lc.local_steps == 1
    # BudgetConfig.local_value sits between the two
    cfg3 = CompressionConfig(budget=BudgetConfig(value=3.0, local_value=7.0))
    assert engine.local_budget_value(cfg3) == 7.0
    # non-fixed budget kinds don't leak their value (an nnz fraction) into B_l
    cfg4 = CompressionConfig(budget=BudgetConfig(kind="target_sparsity", value=0.01))
    assert engine.local_budget_value(cfg4) == 1.0


def test_tau_overflow_guard():
    with pytest.raises(ValueError, match="local_steps"):
        CompressionConfig(local_steps=0)
    with pytest.raises(ValueError, match="local_steps"):
        CompressionConfig(local_steps=MAX_LOCAL_STEPS + 1)


def test_local_update_accumulator_is_int32():
    """Regression for the int8 accumulator: with tau=200 and a saturating local
    budget every inner step votes +1, so the accumulated message must be
    exactly +tau per coordinate (int8 would have wrapped at 128)."""
    tau = 200
    cfg = CompressionConfig(compressor="identity", local_budget=1e9, local_steps=tau)
    w0 = jnp.ones((64,), jnp.float32)
    grad_fn = lambda w, c: jnp.ones_like(w)   # constant positive gradient
    msg = local_update_message(w0, grad_fn, cfg, eta_l=0.0, seed=3)
    assert np.all(np.asarray(msg.values) == float(tau)), np.asarray(msg.values)[:4]
