"""Elastic participation: weighted, participation-normalized voting.

Blocking tier-1 coverage (single device): the weighted vote->update kernel
bitwise against its oracle (odd shapes, bf16, and the weights == 1 legacy
identity), ParticipationSpec build-time validation, the full-participation ==
legacy bitwise pins for all four wire modes at M = 1, the masked shared-linf,
the elastic wire-billing identities, and the masked-payload-zero analysis
rule. The multi-worker chaos harness (50% per-round dropout on every gather
wire) and the M-invariance pin run in tests/mdev/check_fault_tolerance.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.dist import collectives
from repro.dist.collectives import ParticipationSpec
from repro.kernels.vote_update.ops import vote_update_op, weighted_vote_update_op
from repro.kernels.vote_update.ref import vote_update_ref, weighted_vote_update_ref

SHAPES = [(63,), (1000,), (7, 333), (513, 511)]
DTYPES = ["float32", "bfloat16"]


def _weighted_votes(shape, m=5, seed=0, uniform=False):
    """(wvotes, wtot) for m workers of random ternary votes and weights."""
    rng = np.random.RandomState(seed)
    votes = rng.randint(-1, 2, (m,) + shape).astype(np.float32)
    w = np.ones(m, np.float32) if uniform else rng.uniform(0.5, 2.0, m).astype(np.float32)
    wv = jnp.asarray(np.tensordot(w, votes, axes=(0, 0)), jnp.float32)
    return wv, jnp.float32(w.sum())


# ---------------------------------------------------------------------------
# weighted vote->update kernel == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_weighted_vote_update_matches_ref(shape, dtype):
    w = jnp.asarray(np.random.RandomState(1).randn(*shape), dtype)
    wv, wtot = _weighted_votes(shape)
    for q_frac in (0.25, 0.5, 1.0):
        got = weighted_vote_update_op(w, wv, wtot, 0.05, q_frac=q_frac)
        want = weighted_vote_update_ref(w, wv, wtot, 0.05, q_frac)
        assert got.dtype == w.dtype
        assert np.array_equal(np.asarray(got), np.asarray(want)), (shape, dtype, q_frac)


def test_weighted_vote_update_per_coordinate_wtot():
    """wtot may vary per coordinate (per-leaf quorum trees under elastic
    participation); the kernel must apply the deadband pointwise."""
    shape = (33, 65)
    w = jnp.asarray(np.random.RandomState(2).randn(*shape), jnp.float32)
    wv, _ = _weighted_votes(shape, seed=3)
    wtot = jnp.asarray(np.random.RandomState(4).uniform(1.0, 5.0, shape), jnp.float32)
    got = weighted_vote_update_op(w, wv, wtot, 0.1, q_frac=0.5)
    want = weighted_vote_update_ref(w, wv, wtot, 0.1, 0.5)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("quorum", [1, 2, 3, 4])
def test_weighted_vote_update_weights_one_is_legacy(quorum):
    """Uniform weights + full participation recover the integer-quorum kernel
    BITWISE: f32 sums of ternary votes are exact integers and q_frac * M
    reproduces the integer threshold exactly on a power-of-two fleet."""
    m, shape = 4, (129,)
    w = jnp.asarray(np.random.RandomState(5).randn(*shape), jnp.float32)
    votes = np.random.RandomState(6).randint(-m, m + 1, shape)
    legacy = vote_update_op(w, jnp.asarray(votes, jnp.int32), 0.05, quorum=quorum)
    elastic = weighted_vote_update_op(w, jnp.asarray(votes, jnp.float32),
                                      jnp.float32(m), 0.05, q_frac=quorum / m)
    assert np.array_equal(np.asarray(legacy), np.asarray(elastic))
    assert np.array_equal(
        np.asarray(vote_update_ref(w, jnp.asarray(votes, jnp.int32), 0.05, quorum)),
        np.asarray(weighted_vote_update_ref(w, jnp.asarray(votes, jnp.float32),
                                            jnp.float32(m), 0.05, quorum / m)))


# ---------------------------------------------------------------------------
# ParticipationSpec: loud build-time validation
# ---------------------------------------------------------------------------

def test_participation_spec_validation():
    ParticipationSpec(q_frac=1.0)                       # inclusive upper edge
    ParticipationSpec(q_frac=0.25, weights=(1.0, 2.0), dropout=0.5)
    for bad_q in (0.0, -0.5, 1.5, 2):
        with pytest.raises(ValueError, match="quorum fraction"):
            ParticipationSpec(q_frac=bad_q)
    for bad_w in ((0.0, 1.0), (-1.0,), (float("inf"), 1.0), ()):
        with pytest.raises(ValueError, match="weights"):
            ParticipationSpec(weights=bad_w)
    for bad_d in (1.0, -0.1):
        with pytest.raises(ValueError, match="dropout"):
            ParticipationSpec(dropout=bad_d)


def test_participation_spec_resolve_and_weights():
    spec = ParticipationSpec()
    assert spec.is_uniform
    assert spec.resolve_q_frac(2, 8) == 0.25            # legacy quorum / M
    assert ParticipationSpec(q_frac=0.75).resolve_q_frac(2, 8) == 0.75
    for bad_quorum in (0, 9):
        with pytest.raises(ValueError, match="quorum fraction"):
            spec.resolve_q_frac(bad_quorum, 8)
    w = ParticipationSpec(weights=(1.5, 0.5)).weights_array(2)
    assert np.array_equal(np.asarray(w), [1.5, 0.5])
    with pytest.raises(ValueError, match="workers"):
        ParticipationSpec(weights=(1.0, 1.0)).weights_array(3)
    assert np.array_equal(np.asarray(spec.weights_array(3)), [1.0, 1.0, 1.0])


def test_participation_rejects_ef_server_at_build():
    """scaled_sign_ef keeps a full-fleet-calibrated residual; normalizing it
    to a shifting reporting set would corrupt it — must fail at step build."""
    with pytest.raises(ValueError, match="scaled_sign_ef"):
        engine.check_participation_server("scaled_sign_ef", "sparsign")
    engine.check_participation_server("majority_vote", "sparsign")
    engine.check_participation_server("mean", "qsgd8")


def test_make_vote_wire_participation_type_is_loud():
    with pytest.raises(TypeError, match="ParticipationSpec"):
        collectives.make_vote_wire("psum", ("data",), participation={"q_frac": 0.5})


# ---------------------------------------------------------------------------
# masked shared-linf: non-reporting workers are excluded from the max
# ---------------------------------------------------------------------------

def test_worker_shared_linf_mask_excludes_nonreporting():
    gs = jnp.asarray([[1.0, -2.0], [10.0, 3.0], [-4.0, 0.5]])
    mask = jnp.asarray([True, False, True])             # drop the |10| holder
    full = jax.vmap(lambda g: collectives.worker_shared_linf(g, ("w",)),
                    axis_name="w")(gs)
    masked = jax.vmap(lambda g, m: collectives.worker_shared_linf(g, ("w",), mask=m),
                      axis_name="w")(gs, mask)
    assert np.all(np.asarray(full) == 10.0)
    assert np.all(np.asarray(masked) == 4.0)
    none = jax.vmap(lambda g, m: collectives.worker_shared_linf(g, ("w",), mask=m),
                    axis_name="w")(gs, jnp.zeros(3, bool))
    assert np.all(np.asarray(none) == 0.0)              # empty round: no scale


# ---------------------------------------------------------------------------
# elastic wire billing identities
# ---------------------------------------------------------------------------

def test_elastic_wire_billing_identities():
    from repro.analysis import drivers
    m, n = 8, 4096
    # psum family: the participation count rides as a second full-width f32 psum
    elastic = drivers.mode_wire("votes", m, elastic=True)
    assert elastic.wire_bytes(n) == 2.0 * collectives.decoded_wire_bytes(n, m)
    assert drivers.mode_wire("votes", m).wire_bytes(n) < elastic.wire_bytes(n)
    # ternary gather: one (1,) f32 weight per peer rides the gather as a scalar
    gl, ge = (drivers.mode_wire("golomb", m), drivers.mode_wire("golomb", m, elastic=True))
    assert gl.weight_bytes() == 0.0 and ge.weight_bytes() == (m - 1) * 4.0
    # pack8: the per-leaf side channel widens from (scale,) to (scale*w, w)
    p8l, p8e = (drivers.mode_wire("pack8", m), drivers.mode_wire("pack8", m, elastic=True))
    assert p8l.scalar_bytes() == (m - 1) * 4.0
    assert p8e.scalar_bytes() == (m - 1) * 8.0


# ---------------------------------------------------------------------------
# full participation == legacy, all four wire modes, M = 1
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.model import Model
    cfg = ModelConfig(name="part-tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      pattern=(LayerSpec(mixer="attn"),), dtype="float32",
                      attn_chunk=8, q_chunk=8, loss_chunk=8, remat=False)
    return Model(cfg)


def _tiny_batch(vocab, b=2, s=8, seed=0):
    rng = np.random.RandomState(seed)
    return {
        "inputs": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, vocab, (b, s)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32),
    }


def _one_step(model, params, batch, mesh, comp, **cfg_kw):
    from repro.dist import compat
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_simple import TrainStepConfig, build_train_step
    scfg = TrainStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                           worker_axes=("data",), donate=False, **cfg_kw)
    step = build_train_step(model, scfg, mesh)
    state = init_state(params, server=comp.server, seed=7)
    with compat.set_mesh(mesh):
        out, metrics = step(state, batch)
    return jax.tree_util.tree_map(np.asarray, out.params), metrics


@pytest.mark.parametrize("mode,compressor,server,vote_impl", [
    ("votes", "sparsign", "majority_vote", "psum"),
    ("votes", "sparsign", "majority_vote", "allgather_packed"),
    ("scaled_votes", "terngrad", "mean", "psum"),
    ("pack8", "qsgd8", "mean", "allgather_packed"),
    ("decoded", "qsgd8", "mean", "psum"),
])
def test_elastic_full_participation_bitwise_equals_legacy(mode, compressor,
                                                          server, vote_impl):
    """ParticipationSpec with uniform weights, zero dropout and q_frac ==
    quorum/M must be BITWISE the legacy fixed-quorum round on every wire
    mode (the tentpole's no-regression pin; the 8-worker version runs in
    tests/mdev/check_fault_tolerance.py)."""
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(model.cfg.vocab_size)
    comp = CompressionConfig(compressor=compressor,
                             budget=BudgetConfig(kind="fixed", value=1.0),
                             server=server)
    legacy, _ = _one_step(model, params, batch, mesh, comp,
                          vote_impl=vote_impl, quorum=1)
    elastic, metrics = _one_step(model, params, batch, mesh, comp,
                                 vote_impl=vote_impl, quorum=1,
                                 participation=ParticipationSpec(q_frac=1.0))
    moved = any(not np.array_equal(a, np.asarray(b)) for a, b in zip(
        jax.tree_util.tree_leaves(legacy), jax.tree_util.tree_leaves(params)))
    assert moved, "the step must actually update params"
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(legacy)[0],
            jax.tree_util.tree_flatten_with_path(elastic)[0]):
        assert np.array_equal(a, b), (mode, jax.tree_util.keystr(ka))
    assert float(metrics["participated"]) == 1.0


# ---------------------------------------------------------------------------
# masked-payload-zero: the analysis rule actually blocks
# ---------------------------------------------------------------------------

def _gather_fn(masked: bool):
    from repro.dist import compat
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))

    def inner(x, m):
        msg = x.astype(jnp.int8)
        if masked:
            msg = jnp.where(m, msg, jnp.zeros_like(msg))
        return jax.lax.all_gather(msg, "data")

    def fn(x, m):
        return compat.shard_map(inner, mesh=mesh, in_specs=(P("data"), P()),
                                out_specs=P(None), check_vma=False)(x, m)

    return mesh, fn


def test_masked_payload_zero_rule_blocks_unmasked_gather():
    """An integer payload gathered without a participation gate (select_n in
    its producer chain) must produce exactly one blocking finding; the
    jnp.where-masked twin must pass clean."""
    from repro.analysis.jaxpr_audit import MaskedPayloadZero
    from repro.dist import compat
    x = jnp.ones((8, 128), jnp.float32)
    m = jnp.bool_(True)
    rule = MaskedPayloadZero()
    mesh, bad = _gather_fn(masked=False)
    with compat.set_mesh(mesh):
        findings = rule.check("unmasked", bad, x, m)
    assert len(findings) == 1 and "no participation mask" in findings[0].message
    mesh, good = _gather_fn(masked=True)
    with compat.set_mesh(mesh):
        assert rule.check("masked", good, x, m) == []
