"""Executable theory: Theorem 1 bound vs Monte-Carlo, Corollary 1, Thm 2 kappa."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theory


def _hetero_u(m=100, n_neg=80, seed=0):
    rng = np.random.RandomState(seed)
    neg = -rng.uniform(0.005, 0.015, n_neg)
    pos = rng.uniform(0.05, 0.15, m - n_neg)
    u = np.concatenate([neg, pos])
    rng.shuffle(u)
    return jnp.asarray(u, jnp.float32)


def test_theorem1_bound_holds():
    """MC wrong-aggregation probability <= the Thm 1 closed form."""
    u = _hetero_u()
    for budget in (0.5, 2.0, 5.0):
        p_bar, q_bar = theory.sparsign_pq(u, budget)
        assert float(q_bar) > float(p_bar), "magnitude-aware voting must favor truth"
        bound = float(theory.wrong_aggregation_bound(p_bar, q_bar, u.shape[0]))
        mc = float(theory.monte_carlo_wrong_aggregation(
            jax.random.PRNGKey(0), u, budget, n_trials=4000))
        assert mc <= bound + 0.02, (budget, mc, bound)


def test_theorem1_bound_nontrivial():
    """For reasonable budgets the bound itself is < 1/2 at M=100 (Remark 1)."""
    u = _hetero_u()
    p_bar, q_bar = theory.sparsign_pq(u, 5.0)
    assert float(theory.wrong_aggregation_bound(p_bar, q_bar, 100)) < 0.5


def test_deterministic_sign_fails():
    """With 80/100 wrong signs, deterministic sign has p_bar > q_bar: the Thm 1
    premise fails, and empirically the vote is (nearly) always wrong."""
    u = _hetero_u()
    p_bar, q_bar = theory.deterministic_sign_pq(u)
    assert float(p_bar) > float(q_bar)

    # direct: majority of signs is wrong
    s = float(jnp.sign(jnp.mean(u)))
    wrong_heads = float(jnp.mean((jnp.sign(u) != s).astype(jnp.float32)))
    assert wrong_heads > 0.5


def test_worker_sampling_scales_pq():
    """Cor. 1: p_select multiplies both p_bar and q_bar; bound worsens as p_s drops."""
    u = _hetero_u()
    p1, q1 = theory.sparsign_pq(u, 1.0, p_select=1.0)
    p2, q2 = theory.sparsign_pq(u, 1.0, p_select=0.5)
    assert np.isclose(float(p2), 0.5 * float(p1), rtol=1e-5)
    assert np.isclose(float(q2), 0.5 * float(q1), rtol=1e-5)
    b1 = float(theory.wrong_aggregation_bound(p1, q1, 100))
    b2 = float(theory.wrong_aggregation_bound(p2, q2, 100))
    assert b2 >= b1  # fewer expected voters => weaker guarantee (Remark 3)


def test_kappa_below_half_and_monotone_in_m():
    u = _hetero_u()
    k100 = float(theory.kappa(u, budget=5.0))
    assert k100 < 0.5
    k10 = float(theory.kappa(u[:10], budget=5.0))
    # kappa -> 0 as M grows (Remark 5)
    assert k100 <= k10 + 1e-6
