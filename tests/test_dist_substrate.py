"""Substrate tests for repro.dist beyond the seed suite: sanitize_spec edge
cases, whole-tree placement builders, and the vote-collective equivalence
(subprocess-forced 8-device host mesh, pattern of tests/mdev/)."""

import jax
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from conftest import run_mdev

from repro.dist.compat import abstract_mesh
from repro.dist.sharding import (ACT_RULES_SERVE, ACT_RULES_TRAIN, TP_RULES,
                                 cache_shardings_tree, logical_to_spec,
                                 sanitize_spec, tp_param_shardings)

@pytest.fixture(scope="module")
def mesh16():
    return abstract_mesh((16, 16), ("data", "model"))


# ---------------------------------------------------------------------------
# sanitize_spec edge cases
# ---------------------------------------------------------------------------

def test_sanitize_zero_dim_replicates(mesh16):
    assert sanitize_spec(P("model"), (0,), mesh16) == P(None)


def test_sanitize_size_one_axis_kept():
    m = abstract_mesh((1, 16), ("data", "model"))
    # a size-1 mesh axis divides everything: placement kept (it's a no-op)
    assert sanitize_spec(P("data", "model"), (7, 32), m) == P("data", "model")


def test_sanitize_repeated_mesh_axis_last_wins(mesh16):
    # 'model' claimed by dims 0 and 2 (the raw expert x .. x ff spec): the
    # LAST occurrence keeps it, matching hint()'s convention
    s = sanitize_spec(P("model", None, "model"), (64, 32, 128), mesh16)
    assert s == P(None, None, "model")
    # ...unless the last one fails divisibility — then the earlier survives
    s2 = sanitize_spec(P("model", None, "model"), (64, 32, 100), mesh16)
    assert s2 == P("model", None, None)


def test_sanitize_repeat_inside_tuple_nulls_dim(mesh16):
    assert sanitize_spec(P(("data", "data")), (512,), mesh16) == P(None)


def test_sanitize_tuple_scalar_overlap(mesh16):
    # 'model' inside a tuple on dim 0 and scalar on dim 1: last wins, the
    # whole earlier tuple entry is dropped (partial placements never survive)
    s = sanitize_spec(P(("data", "model"), "model"), (256, 64), mesh16)
    assert s == P(None, "model")


def test_sanitize_spec_shorter_than_dims(mesh16):
    assert sanitize_spec(P("model"), (32, 64, 128), mesh16) == P("model", None, None)


# ---------------------------------------------------------------------------
# rule tables / logical mapping
# ---------------------------------------------------------------------------

def test_rule_tables_cover_model_logical_axes():
    for name in ("vocab", "heads", "ff", "expert"):
        assert TP_RULES[name] == "model"
        assert ACT_RULES_TRAIN[name] == "model"
        assert ACT_RULES_SERVE[name] == "model"
    assert ACT_RULES_TRAIN["batch"] == "data"


def test_logical_to_spec_custom_rules():
    assert logical_to_spec(("batch", "seq"), ACT_RULES_SERVE) == P("data", None)


# ---------------------------------------------------------------------------
# whole-tree placement builders (1x1 host mesh: spec math, no multi-device)
# ---------------------------------------------------------------------------

def test_tp_param_shardings_tree(host_mesh11):
    from repro.configs.registry import get_config
    from repro.models.model import Model
    model = Model(get_config("qwen1.5-4b", smoke=True))
    sh = tp_param_shardings(model, host_mesh11)
    shapes = model.param_shapes()
    flat_sh = jax.tree_util.tree_leaves(sh)
    assert flat_sh and all(isinstance(s, NamedSharding) for s in flat_sh)
    assert (jax.tree_util.tree_structure(sh)
            == jax.tree_util.tree_structure(jax.tree_util.tree_map(lambda _: 0, shapes)))
    # embed is vocab x d_model -> P('model', None) sanitized against real dims
    assert sh["embed"].spec[0] in ("model", None)


def test_cache_shardings_tree_layouts(host_mesh11):
    from repro.configs.registry import get_config
    from repro.models.model import Model
    model = Model(get_config("gemma3-27b", smoke=True))
    shapes = model.cache_shapes(batch_size=2, max_len=64)
    sh = cache_shardings_tree(shapes, host_mesh11, worker_axes=("data",))
    k = sh["body"][0]["k"]
    # stacked (r, b, w, kvh, hd): batch axis (1) carries the worker axis
    assert k.spec[1] in ("data", None) and len(k.spec) <= 5
    sh_seq = cache_shardings_tree(shapes, host_mesh11, worker_axes=("data",),
                                  shard_seq=True)
    k2 = sh_seq["body"][0]["k"].spec
    # shard_seq: batch replicated, the cache-depth axis takes the workers
    assert (len(k2) < 2 or k2[1] is None)
    assert (jax.tree_util.tree_structure(sh)
            == jax.tree_util.tree_structure(jax.tree_util.tree_map(lambda _: 0, shapes)))


@pytest.fixture(scope="module")
def host_mesh11():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1, 1)


# ---------------------------------------------------------------------------
# vote-collective equivalence (8-device subprocess)
# ---------------------------------------------------------------------------

def test_vote_collective_equivalence_8dev():
    out = run_mdev("check_collectives.py", timeout=600)
    assert "OK vote_psum == vote_allgather_packed == oracle" in out
    assert "OK vote_psum_hier == vote_psum == packed" in out
