"""The Golomb/RLE entropy-coded uplink wire: byte-format roundtrip, fused
kernel == reference bitwise, decode-sum oracle, capacity overflow semantics,
GolombWire ledger pins, and the Eq. 12 coder edge cases.

Blocking tier-1 coverage (single device); the multi-worker bitwise wire
equivalence (int8-psum oracle vs golomb gather, both train modes) runs in
tests/mdev/check_wires.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import budgets, encoding, engine
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.dist import collectives
from repro.kernels import common
from repro.kernels.golomb import ref as golomb_ref
from repro.kernels.golomb.ops import (golomb_pack_op, sparsign_golomb_op,
                                      ungolomb_sum_op)
from repro.kernels.golomb.ref import (HEADER_BYTES, ROW_BYTES,
                                      golomb_decode_ref, golomb_encode_ref,
                                      golomb_nbytes, golomb_rows, rice_b,
                                      ungolomb_sum_ref)
from repro.kernels.sparsign.ops import sparsign_op

SHAPES = [(63,), (1000,), (7, 333), (513, 511)]
OTHER = "interpret" if jax.default_backend() != "tpu" else "pallas"


def _ternary(shape, density, seed):
    """Random ternary message at ~``density`` nonzero fraction."""
    rng = np.random.RandomState(seed)
    t = rng.choice(np.array([-1, 0, 1], np.int8), size=shape,
                   p=[density / 2, 1.0 - density, density / 2])
    return jnp.asarray(t, jnp.int8)


def _headers(payload):
    """(shipped, dropped) uint32 LE counters off the raw payload bytes."""
    flat = np.asarray(payload).reshape(-1)
    return (int.from_bytes(flat[:4].tobytes(), "little"),
            int.from_bytes(flat[4:8].tobytes(), "little"))


# ---------------------------------------------------------------------------
# byte-format roundtrip (the reference coder IS the format definition)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("p", [0.01, 0.05, 0.2])
def test_roundtrip_property(shape, p):
    t = _ternary(shape, p, seed=hash((shape, p)) % (1 << 31))
    payload = golomb_encode_ref(t, p=p)
    n = int(t.size)
    assert payload.dtype == jnp.uint8
    assert payload.shape == (golomb_rows(n, p), ROW_BYTES)
    shipped, dropped = _headers(payload)
    assert shipped == int(jnp.sum(jnp.abs(t.astype(jnp.int32))))
    assert dropped == 0, "six-sigma capacity must not truncate at plan density"
    back = golomb_decode_ref(payload, n, t.shape, p=p)
    assert np.array_equal(np.asarray(back), np.asarray(t))


def test_roundtrip_extremes():
    p, n = 0.05, 1000
    # all-zero message: zero headers, zero decode (a masked worker's stream)
    zero = golomb_encode_ref(jnp.zeros((n,), jnp.int8), p=p)
    assert _headers(zero) == (0, 0)
    assert not np.asarray(zero).any()
    assert not np.asarray(golomb_decode_ref(zero, n, (n,), p=p)).any()
    # single maximal run: one nonzero at the last coordinate (gap = n-1, the
    # largest unary spill a single code can pay)
    t = jnp.zeros((n,), jnp.int8).at[n - 1].set(-1)
    payload = golomb_encode_ref(t, p=p)
    assert _headers(payload) == (1, 0)
    assert np.array_equal(np.asarray(golomb_decode_ref(payload, n, (n,), p=p)),
                          np.asarray(t))
    # padded vs unpadded inputs code identically (trailing zeros emit nothing):
    # the canonical-view encode of the same stream carries the same codes in a
    # wider capacity buffer, and roundtrips to the padded view
    view, _ = common.to_2d(t)
    wide = golomb_encode_ref(view, p=p)
    assert _headers(wide) == (1, 0)
    assert np.array_equal(
        np.asarray(golomb_decode_ref(wide, int(view.size), view.shape, p=p)),
        np.asarray(view))


def test_overflow_truncates_prefix_and_counts_dropped():
    """A message denser than plan truncates at bit capacity: the header says
    how many codes shipped and how many dropped, and the shipped codes are a
    PREFIX of the nonzeros in ascending coordinate order — a decoder never
    sees a torn code."""
    p, n = 0.05, 1000
    t = jnp.ones((n,), jnp.int8)   # every coordinate nonzero: gap 0 per code
    payload = golomb_encode_ref(t, p=p)
    shipped, dropped = _headers(payload)
    assert shipped + dropped == n and dropped > 0
    # all-ones stream: every code is exactly 2 + b bits, so the bit capacity
    # pins the shipped count from first principles
    bits = (golomb_rows(n, p) * ROW_BYTES - HEADER_BYTES) * 8
    assert shipped == bits // (2 + rice_b(p))
    # prefix decode: the first ``shipped`` coordinates, nothing else
    want = np.zeros(n, np.int8)
    want[:shipped] = 1
    assert np.array_equal(
        np.asarray(golomb_decode_ref(payload, n, (n,), p=p)), want)


def test_capacity_loses_to_pack2_is_a_build_error():
    """Above ~35% density the coded capacity cannot beat the flat 2-bit wire:
    golomb_rows must refuse at BUILD time (directing to pack2), never emit a
    payload that silently costs more than the format it claims to beat."""
    with pytest.raises(ValueError, match="does not beat"):
        golomb_rows(1 << 16, 0.5)
    # and the viable regime's ledger really is sub-pack2
    n = 1 << 16
    assert golomb_nbytes(n, 0.05) < collectives.packed_nbytes(n)


# ---------------------------------------------------------------------------
# fused kernel == two-pass chain == reference, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fused_golomb_uplink_matches_two_pass(shape, dtype):
    g = jnp.asarray(np.random.RandomState(0).randn(*shape), dtype)
    p = 0.05
    # budget ~0.06 keeps realized nnz near the 5% plan; budget 1.5 overflows
    # capacity on purpose — truncation must be bitwise-identical across paths
    for budget, seed, base in [(0.06, 1, 0), (0.06, 99, 12345), (1.5, 7, 2**20)]:
        fused = sparsign_golomb_op(g, budget, seed, base, p=p, interpret=True)
        t = sparsign_op(g, budget, seed, base)
        two_pass = golomb_pack_op(t, p=p, interpret=True)
        ref = golomb_encode_ref(t, p=p)
        assert fused.dtype == jnp.uint8
        assert fused.shape == (golomb_rows(int(g.size), p), ROW_BYTES)
        assert np.array_equal(np.asarray(fused), np.asarray(two_pass)), \
            (shape, dtype, budget)
        assert np.array_equal(np.asarray(fused), np.asarray(ref)), \
            (shape, dtype, budget)


def test_fused_golomb_no_int8_hbm_intermediate():
    """The point of the fusion: gradient -> coded wire bytes with no int8
    ternary tensor at the HBM level; the two-pass chain necessarily has one.
    The pin is the spec's declarative hbm_limits rule, not a hand count."""
    from repro.analysis.jaxpr_audit import check_fused_uplink
    from repro.core.compressors import get_spec
    g = jnp.asarray(np.random.RandomState(1).randn(4096), jnp.float32)
    assert check_fused_uplink(get_spec("sparsign_golomb"), g, param=0.06) == []
    two_pass = common.int8_hbm_elems(
        lambda x: golomb_pack_op(sparsign_op(x, 0.06, 7), p=0.05,
                                 interpret=True), g)
    assert two_pass >= g.size


# ---------------------------------------------------------------------------
# fused decode-sum (the gather wire's downlink side)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("n", [63, 1000])
def test_ungolomb_sum_matches_sequential_oracle(m, n):
    """Fused decode-sum == reference == eager numpy accumulation in strict
    worker (gather) order — the association the wire contract pins."""
    p = 0.05
    votes = [_ternary((n,), p, seed=100 + i) for i in range(m)]
    gathered = jnp.stack([golomb_encode_ref(v, p=p) for v in votes])
    got = ungolomb_sum_op(gathered, n, (n,), p=p, interpret=True)
    want = ungolomb_sum_ref(gathered, n, (n,), p=p)
    oracle = sum(np.asarray(v, np.int32) for v in votes)
    assert got.dtype == jnp.int32
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got), oracle)


# ---------------------------------------------------------------------------
# GolombWire: headers, masking, ledger, validation
# ---------------------------------------------------------------------------

def test_golomb_wire_nnz_dropped_and_mask():
    p, n = 0.05, 1000
    wire = collectives.GolombWire(axes=("data",), n_workers=4, p=p)
    assert wire.native_format == "golomb" and wire.wants_packed
    t = _ternary((n,), p, seed=3)
    payload = golomb_encode_ref(t, p=p)
    assert float(wire.message_nnz(payload)) == float(jnp.sum(jnp.abs(
        t.astype(jnp.int32))))
    assert float(wire.message_dropped(payload)) == 0.0
    # overflow telemetry reads the second header counter
    dense = golomb_encode_ref(jnp.ones((n,), jnp.int8), p=p)
    shipped, dropped = _headers(dense)
    assert float(wire.message_nnz(dense)) == shipped
    assert float(wire.message_dropped(dense)) == dropped
    # masking zeroes the whole stream; a zero stream decodes to zero votes
    masked = wire.mask_message(payload, jnp.bool_(False))
    assert float(wire.message_nnz(masked)) == 0.0
    assert not np.asarray(golomb_decode_ref(masked, n, (n,), p=p)).any()
    assert np.array_equal(np.asarray(wire.mask_message(payload, jnp.bool_(True))),
                          np.asarray(payload))
    # integer vote streams reject an in-exchange decode scale loudly
    with pytest.raises(ValueError, match="pack8-wire concept"):
        wire.exchange(payload, n, (n,), scale=jnp.float32(1.0))
    with pytest.raises(ValueError, match="pack8-wire concept"):
        wire.exchange_bucket(payload, None, scale=jnp.float32(1.0))


def test_golomb_wire_ledger_matches_real_payload_nbytes():
    """The ledger bills exactly the capacity-padded buffer the fixed-shape
    gather ships — (M-1) x real payload nbytes, padding tax included."""
    p, m = 0.05, 16
    wire = collectives.GolombWire(axes=("data",), n_workers=m, p=p)
    for n in (63, 1000, 1 << 18):
        payload = golomb_pack_op(_ternary((n,), p, seed=n), p=p, interpret=True)
        assert wire.wire_bytes(n) == (m - 1) * payload.nbytes
        assert wire.payload_rows(n) == golomb_rows(n, p) == payload.shape[0]
    # bucket slots are capacity ROWS, not coordinate rows: the bucket ledger
    # takes the plan's row count directly
    assert wire.bucket_payload_bytes(12345, rows=7) == (m - 1) * 7 * ROW_BYTES
    with pytest.raises(AssertionError, match="row count"):
        wire.bucket_payload_bytes(12345)
    # uplink_ledger routes through the same accounting (votes mode, no scale)
    assert collectives.uplink_ledger("votes", wire, 1000) == wire.wire_bytes(1000)


def test_make_vote_wire_golomb_validation():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    wire = collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                      wire_format="golomb", golomb_p=0.03)
    assert isinstance(wire, collectives.GolombWire) and wire.p == 0.03
    # the coded stream cannot ride a fabric reduction
    for impl in ("psum", "hier"):
        with pytest.raises(ValueError, match="allgather_packed"):
            collectives.make_vote_wire(impl, ("pod", "data"), mesh,
                                       wire_format="golomb", golomb_p=0.03)
    # capacity needs a plan fraction, and a sane one
    with pytest.raises(ValueError, match="golomb_p"):
        collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                   wire_format="golomb")
    with pytest.raises(ValueError, match=r"in \(0,1\)"):
        collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                   wire_format="golomb", golomb_p=1.5)


# ---------------------------------------------------------------------------
# engine negotiation + wire-native messages
# ---------------------------------------------------------------------------

def _cfg_golomb(value=0.05):
    return CompressionConfig(compressor="sparsign_golomb",
                             budget=BudgetConfig(kind="target_sparsity",
                                                 value=value),
                             server="majority_vote")


def test_wire_payload_format_negotiation():
    """golomb is a payload FORMAT, not a wire mode: the spec rides the votes
    mode, and only the gather impl speaks the coded stream — psum/hier fall
    back to plain int8 votes (bitwise-identical votes, flat bytes)."""
    cfg = _cfg_golomb()
    assert engine.wire_mode(cfg) == "votes"
    assert engine.wire_payload_format(cfg, "votes",
                                      vote_impl="allgather_packed") == "golomb"
    for impl in ("psum", "hier", None):
        assert engine.wire_payload_format(cfg, "votes", vote_impl=impl) == "pack2"
    plain = CompressionConfig(compressor="sparsign",
                              budget=BudgetConfig(kind="fixed", value=2.0),
                              server="majority_vote")
    assert engine.wire_payload_format(plain, "votes",
                                      vote_impl="allgather_packed") == "pack2"


def test_resolve_golomb_p():
    assert engine.resolve_golomb_p(_cfg_golomb(0.07)) == 0.07
    # an explicit step-config setting wins over the budget's target
    assert engine.resolve_golomb_p(_cfg_golomb(0.07), 0.02) == 0.02
    fixed = CompressionConfig(compressor="sparsign_golomb",
                              budget=BudgetConfig(kind="fixed", value=1.0),
                              server="majority_vote")
    with pytest.raises(ValueError, match="plan-time nonzero fraction"):
        engine.resolve_golomb_p(fixed)
    with pytest.raises(ValueError, match=r"in \(0,1\)"):
        engine.resolve_golomb_p(fixed, 0.0)


@pytest.mark.parametrize("backend", ["jnp", OTHER])
def test_compress_leaf_golomb_wire_native(backend):
    """compress_leaf(wire=GolombWire) ships the coded byte stream of the SAME
    ternary message the plain path emits, on every backend (fused kernel vs
    two-pass vs jnp reference)."""
    wire = collectives.GolombWire(axes=("data",), n_workers=4, p=0.05)
    g = jnp.asarray(np.random.RandomState(4).randn(7, 333), jnp.float32)
    msg_int8 = engine.compress_leaf(g, _cfg_golomb(), 9, 123, backend=backend)
    msg_coded = engine.compress_leaf(g, _cfg_golomb(), 9, 123, backend=backend,
                                     wire=wire)
    assert msg_int8.values.dtype == jnp.int8
    assert msg_coded.values.dtype == jnp.uint8
    want = golomb_encode_ref(msg_int8.values, p=wire.p)
    assert np.array_equal(np.asarray(msg_coded.values), np.asarray(want))
    assert np.array_equal(np.asarray(msg_coded.scale), np.asarray(msg_int8.scale))


def test_compress_leaf_golomb_wire_format_mismatch_is_loud():
    g = jnp.zeros((8,), jnp.float32)
    pack2 = collectives.PackedVoteWire(axes=("data",), n_workers=4)
    with pytest.raises(ValueError, match="wire format"):
        engine.compress_leaf(g, _cfg_golomb(), 0, wire=pack2)
    gw = collectives.GolombWire(axes=("data",), n_workers=4, p=0.05)
    plain = CompressionConfig(compressor="sparsign",
                              budget=BudgetConfig(kind="fixed", value=1.0),
                              server="majority_vote")
    with pytest.raises(ValueError, match="wire format"):
        engine.compress_leaf(g, plain, 0, wire=gw)


# ---------------------------------------------------------------------------
# Eq. 12 coder edge cases (satellite bugfixes) + the capacity budget solver
# ---------------------------------------------------------------------------

def test_golomb_bstar_extreme_p():
    """p ~< 1e-17 used to ZeroDivisionError (log(1-p) underflow) and p -> 1
    used to raise on floor(-inf); both are valid regimes with well-defined
    parameters."""
    assert encoding.golomb_bstar(1e-18) >= 1
    assert encoding.golomb_bstar(0.999) == 0
    # b* is monotone non-increasing in p across the whole range
    bs = [encoding.golomb_bstar(p) for p in
          (1e-18, 1e-9, 1e-4, 0.01, 0.05, 0.2, 0.5, 0.9, 0.999)]
    assert bs == sorted(bs, reverse=True)
    for bad in (0.0, 1.0, -0.1, 2.0):
        with pytest.raises(ValueError, match=r"in \(0,1\)"):
            encoding.golomb_bstar(bad)


def test_golomb_bits_per_index_extreme_p():
    """The Eq. 12 average is finite and sane at both ends (the direct
    1-(1-p)^k form rounds to 0 at tiny p -> ZeroDivisionError)."""
    import math
    tiny = encoding.golomb_bits_per_index(1e-18)
    assert math.isfinite(tiny) and tiny > 1.0
    # near-dense: b*=0 so the average approaches 1/p(stop) + 0 remainder ~ 1
    assert encoding.golomb_bits_per_index(0.999) == pytest.approx(1.001, rel=1e-2)
    # and the paper-regime value stays below the flat 2-bit format's 2 b/coord
    # budget per coordinate when multiplied out: p*(bbar+1) < 2 at p=0.05
    bbar = encoding.golomb_bits_per_index(0.05)
    assert 0.05 * (bbar + 1.0) < 2.0


def test_ternary_stream_bits_zero_nnz_consistency():
    """nnz=0 is a real message (an all-zero round): sparse coders ship nothing
    but dense coders still pay their flat d-proportional cost — the old
    blanket ``return 0.0`` zeroed those too."""
    import math
    d = 4096
    assert encoding.ternary_stream_bits(d, 0, coder="golomb") == 0.0
    assert encoding.ternary_stream_bits(d, 0, coder="naive_index") == 0.0
    assert encoding.ternary_stream_bits(d, 0, coder="dense") == d * math.log2(3.0)
    assert encoding.ternary_stream_bits(d, 0, coder="packed2bit") == 2.0 * d
    with pytest.raises(ValueError, match="unknown coder"):
        encoding.ternary_stream_bits(d, 10, coder="huffman")


def test_budget_bisection_heavy_tail_hits_target():
    """Regression: with a heavy-tailed gradient (min nonzero |g| ~ 1e-11 so
    the bracket top is ~1e10), the old LINEAR bisection left a final interval
    of width ~26 around a solution of order 1 and overshot a 5% target to
    ~17% realized sparsity — which overflowed the golomb wire's plan capacity.
    Geometric bisection resolves the whole bracket."""
    rng = np.random.RandomState(11)
    g = np.abs(rng.randn(1 << 16)).astype(np.float32)
    g[:8] = 3.5e-11
    target = 0.05
    b = budgets.solve_budget_for_sparsity(jnp.asarray(g), target)
    realized = float(budgets.expected_sparsity(jnp.asarray(g), b))
    assert abs(realized - target) < 5e-4, (realized, float(b))
    # benign gradients still resolve (the pre-existing contract)
    g2 = np.abs(np.random.RandomState(12).randn(1 << 14)).astype(np.float32)
    b2 = budgets.solve_budget_for_sparsity(jnp.asarray(g2), 0.25)
    assert abs(float(budgets.expected_sparsity(jnp.asarray(g2), b2)) - 0.25) < 5e-4
