"""FL simulation tests: the paper's §6 claims at test scale."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import ImageDataConfig, make_image_dataset
from repro.fl.models import mlp_fashion
from repro.fl.rosenbrock import make_heterogeneity, run as run_rosen
from repro.fl.simulation import FLConfig, run_fl, stack_partitions


def test_rosenbrock_paper_claims():
    """Fig 1: sign wrong-agg ~ 1 & no progress; sparsign < 1/2 & converges."""
    r_sign = run_rosen("sign", rounds=120, n_sel=100, lr=1e-3)
    r_sp = run_rosen("sparsign", budget=0.01, rounds=120, n_sel=100, lr=1e-3)
    assert r_sign.wrong_agg.mean() > 0.9
    assert r_sp.wrong_agg.mean() < 0.5
    assert r_sp.values[-1] < r_sp.values[0]
    assert r_sp.values[-1] < r_sign.values[-1]


def test_rosenbrock_worker_sampling_monotone():
    """Fig 2 / Remark 3: more sampled workers -> lower wrong-aggregation."""
    wrongs = [run_rosen("sparsign", budget=0.01, rounds=80, n_sel=ns, lr=2e-4).wrong_agg.mean()
              for ns in (5, 50)]
    assert wrongs[1] < wrongs[0]


def test_heterogeneity_construction():
    v = make_heterogeneity(100, 80, seed=3)
    assert np.isclose(v.sum(), 1.0)
    assert (v < 0).sum() == 80


@pytest.fixture(scope="module")
def fashion_setup():
    x, y, xt, yt = make_image_dataset(ImageDataConfig(n_train=3000, n_test=600, seed=0))
    parts = dirichlet_partition(y, n_workers=20, alpha=0.1, seed=0)
    xp, yp = stack_partitions(x, y, parts)
    v0, apply_fn = mlp_fashion(jax.random.PRNGKey(0))
    return xp, yp, xt, yt, v0, apply_fn


def _run(fashion_setup, comp, rounds=40, participation=1.0, tau=1, local_lr=0.05,
         eval_every=None):
    xp, yp, xt, yt, v0, apply_fn = fashion_setup
    cfg = FLConfig(n_workers=20, rounds=rounds, participation=participation,
                   batch_size=64, lr=0.05, local_lr=local_lr, comp=comp,
                   seed=0, eval_every=eval_every or rounds)
    return run_fl(v0, apply_fn, cfg, xp, yp, xt, yt)


def test_ef_sparsign_learns_under_heterogeneity(fashion_setup):
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=5.0),
                             server="scaled_sign_ef")
    res = _run(fashion_setup, comp, rounds=60)
    assert res["final_acc"] > 0.55, res  # 10 classes, chance = 0.1; reaches ~1.0


def test_sparsign_stable_where_sign_oscillates(fashion_setup):
    """The paper's §6.2 mechanism at test scale: under Dir(0.1) heterogeneity
    EF-SPARSIGNSGD's accuracy curve is (near-)monotone while deterministic
    signSGD, lacking magnitude information, is unstable (non-monotone with a
    large drawdown) — the training-dynamics face of the Fig. 1 divergence."""
    import numpy as np
    sp = _run(fashion_setup, CompressionConfig(
        compressor="sparsign", budget=BudgetConfig(value=5.0),
        server="scaled_sign_ef"), rounds=60, eval_every=10)
    sg = _run(fashion_setup, CompressionConfig(compressor="sign",
              server="majority_vote"), rounds=60, eval_every=10)
    sp_curve = np.array([a for _, a in sp["acc"]])
    sg_curve = np.array([a for _, a in sg["acc"]])
    sp_drawdown = float(np.max(np.maximum.accumulate(sp_curve) - sp_curve))
    sg_drawdown = float(np.max(np.maximum.accumulate(sg_curve) - sg_curve))
    assert sp_drawdown <= 0.05, f"sparsign should be stable, drawdown={sp_drawdown}"
    assert sg_drawdown > sp_drawdown, (sg_drawdown, sp_drawdown)
    assert sp["final_acc"] > 0.55


def test_partial_participation_runs(fashion_setup):
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=1.0),
                             server="scaled_sign_ef")
    res = _run(fashion_setup, comp, participation=0.25)
    assert np.isfinite(res["final_acc"]) and res["final_acc"] > 0.2


def test_local_updates_run(fashion_setup):
    comp = CompressionConfig(compressor="sparsign", budget=BudgetConfig(value=1.0),
                             server="scaled_sign_ef", local_steps=3, local_budget=10.0)
    res = _run(fashion_setup, comp, rounds=20, local_lr=0.02)
    assert np.isfinite(res["final_acc"]) and res["final_acc"] > 0.2


def test_bits_accounting_orders_methods(fashion_setup):
    """sparsign's Golomb-coded uplink must be below 1 bit/coord (sign's cost)."""
    sp = _run(fashion_setup, CompressionConfig(
        compressor="sparsign", budget=BudgetConfig(value=1.0), server="scaled_sign_ef"),
        rounds=10)
    assert sp["uplink_bits_per_round"] < sp["d"] * 20  # 20 workers x 1 bit/coord
