"""Test-session guards.

The dry-run forces 512 host devices via XLA_FLAGS — that env var must NEVER be
set here: smoke tests and benches are written for the default 1-device CPU
client, and multi-device suites spawn their own subprocesses with their own
flags (tests/mdev/*).
"""

import os

# Fail fast if a stray XLA_FLAGS from a dry-run shell would skew every test.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" in _flags:
    raise RuntimeError(
        "XLA_FLAGS forces a host device count; unset it before running pytest "
        "(the multi-device tests manage their own subprocess flags)")
