"""Test-session guards.

The dry-run forces 512 host devices via XLA_FLAGS — that env var must NEVER be
set here: smoke tests and benches are written for the default 1-device CPU
client, and multi-device suites spawn their own subprocesses with their own
flags (tests/mdev/*).

If `hypothesis` is not installed (the pinned container has no network), a
deterministic stub (tests/_hypothesis_stub.py) is registered so the property
tests still collect and run over a fixed sample. CI installs the real engine
from requirements-dev.txt and never hits the stub.
"""

import importlib.util
import os
import pathlib
import subprocess
import sys

# Fail fast if a stray XLA_FLAGS from a dry-run shell would skew every test.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" in _flags:
    raise RuntimeError(
        "XLA_FLAGS forces a host device count; unset it before running pytest "
        "(the multi-device tests manage their own subprocess flags)")

MDEV_DIR = pathlib.Path(__file__).parent / "mdev"
SRC_DIR = str(pathlib.Path(__file__).parents[1] / "src")


def run_mdev(script: str, timeout: int = 1200) -> str:
    """Run a tests/mdev/ check in a subprocess (own XLA_FLAGS / device count)
    and return its stdout; asserts a zero exit."""
    proc = subprocess.run(
        [sys.executable, str(MDEV_DIR / script)],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": SRC_DIR,
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root")},
    )
    assert proc.returncode == 0, \
        f"{script} failed:\n{proc.stdout[-3000:]}\n{proc.stderr[-3000:]}"
    return proc.stdout


try:
    import hypothesis  # noqa: F401  — prefer the real engine when present
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_stub.py")
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies
