"""The ring-pipelined payload gather (``ring_chunk_rows``): chunk framing,
build-time validation, the gather-HBM/ledger math, and the decode-equivalence
pins that hold without a multi-device mesh (chunked decode == whole decode on
gathered arrays; the M=1 degenerate ring bitwise-equals the monolithic wire
and the psum oracle end-to-end). The multi-worker ring-vs-monolithic sweep
(8 devices, both train modes, both backends) runs in tests/mdev/check_wires.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.algorithm import CompressionConfig
from repro.core.budgets import BudgetConfig
from repro.dist import bucketing, collectives, compat
from repro.kernels import common
from repro.kernels.pack2bit.ops import pack2bit_op
from repro.kernels.pack8.ops import qsgd8_pack8_op

OTHER = "interpret" if jax.default_backend() != "tpu" else "pallas"


# ---------------------------------------------------------------------------
# chunk framing (static plan-time helpers)
# ---------------------------------------------------------------------------

def test_ring_perm_cycle():
    assert collectives.ring_perm(4) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    # M=1 degenerates to the (trace-legal) identity; the hop loop never runs
    assert collectives.ring_perm(1) == [(0, 0)]


def test_ring_chunk_spans():
    spans = collectives._ring_chunk_spans
    assert spans(96, None) == ((0, 96),)            # monolithic: one chunk
    assert spans(96, 96) == ((0, 96),)
    assert spans(96, 32) == ((0, 32), (32, 32), (64, 32))
    assert spans(70, 32) == ((0, 32), (32, 32), (64, 6))   # short tail
    assert spans(8, 32) == ((0, 8),)                # payload smaller than chunk
    # spans tile the payload exactly, in row order
    for total, chunk in [(97, 32), (1, 32), (320, 64)]:
        s = spans(total, chunk)
        assert s[0][0] == 0 and sum(nr for _, nr in s) == total
        for (a, na), (b, _) in zip(s, s[1:]):
            assert a + na == b


def _pack8_plan(sizes, bucket_bytes=None):
    return bucketing.build_bucket_plan(
        [jax.ShapeDtypeStruct((n,), jnp.float32) for n in sizes],
        "pack8", bucket_bytes=bucket_bytes)


def test_slot_groups_and_chunk_segments():
    plan = _pack8_plan([1000, 513, 4096, 70000])
    (b,) = plan.buckets
    # groups partition the slots in order, each group under the cap unless a
    # single slot alone exceeds it (then it rides the ring as one oversized
    # chunk)
    for cap in (32, 64, 128):
        groups = collectives._slot_groups(b.slots, cap)
        flat = [s for g in groups for s in g]
        assert flat == list(b.slots)
        for g in groups:
            rows = sum(s.rows for s in g)
            assert rows <= cap or len(g) == 1
    assert collectives._slot_groups(b.slots, None) == (tuple(b.slots),)
    # chunk/slot intersection segments: cover each chunk's slot rows exactly
    for r0, nr in collectives._ring_chunk_spans(b.rows, 32):
        segs = collectives._chunk_segments(b.slots, r0, nr)
        covered = sum(seg_rows for _, _, _, seg_rows in segs)
        in_slots = sum(max(0, min(r0 + nr, s.row_start + s.rows)
                           - max(r0, s.row_start)) for s in b.slots)
        assert covered == in_slots
        for i, s, a, seg_rows in segs:
            assert b.slots[i] is s
            assert s.row_start <= a and a + seg_rows <= s.row_start + s.rows


# ---------------------------------------------------------------------------
# build-time validation
# ---------------------------------------------------------------------------

def test_make_vote_wire_ring_validation():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    # a ring request on a fabric-reduction wire is a loud contradiction
    with pytest.raises(ValueError, match="gather-wire concept"):
        collectives.make_vote_wire("psum", ("data",), mesh, ring_chunk_rows=32)
    with pytest.raises(ValueError, match="gather-wire concept"):
        collectives.make_vote_wire("hier", ("data", "model"), mesh,
                                   ring_chunk_rows=32)
    # chunk size must keep every chunk a valid kernel grid
    for bad in (31, 0, -32, 33):
        with pytest.raises(ValueError, match="sublane"):
            collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                       ring_chunk_rows=bad)
    wire = collectives.make_vote_wire("allgather_packed", ("data",), mesh,
                                      ring_chunk_rows=64)
    assert isinstance(wire, collectives.PackedVoteWire)
    assert wire.ring_chunk_rows == 64
    for fmt, cls in (("pack8", collectives.Pack8Wire),
                     ("golomb", collectives.GolombWire)):
        w = collectives.make_vote_wire(
            "allgather_packed", ("data",), mesh, wire_format=fmt,
            golomb_p=(0.05 if fmt == "golomb" else None), ring_chunk_rows=64)
        assert isinstance(w, cls) and w.ring_chunk_rows == 64


def test_resolve_ring_chunk_rows():
    assert engine.resolve_ring_chunk_rows(None, "psum") is None
    assert engine.resolve_ring_chunk_rows(None, "allgather_packed") is None
    assert engine.resolve_ring_chunk_rows(256, "allgather_packed") == 256
    with pytest.raises(ValueError, match="allgather_packed"):
        engine.resolve_ring_chunk_rows(256, "psum")
    with pytest.raises(ValueError, match="sublane"):
        engine.resolve_ring_chunk_rows(48, "allgather_packed")


# ---------------------------------------------------------------------------
# ledger math: ring chunks, gather-HBM residency, uplink bytes
# ---------------------------------------------------------------------------

def test_pack2_ring_ledger_math():
    m = 16
    n = 96 * common.LANES                 # exactly 96 canonical rows
    mono = collectives.PackedVoteWire(axes=("data",), n_workers=m)
    ring = collectives.PackedVoteWire(axes=("data",), n_workers=m,
                                      ring_chunk_rows=32)
    row_b = common.LANES // 4
    assert mono.ring_chunks(n) == 1 and ring.ring_chunks(n) == 3
    assert mono.gather_hbm_bytes(n) == m * 96 * row_b
    assert ring.gather_hbm_bytes(n) == 2 * 32 * row_b
    # total fabric bytes are ring-invariant: every chunk visits every worker
    assert mono.wire_bytes(n) == ring.wire_bytes(n)
    assert (collectives.uplink_ledger("votes", mono, n)
            == collectives.uplink_ledger("votes", ring, n))


def test_pack8_ring_ledger_math():
    m = 16
    n = 96 * common.LANES
    mono = collectives.Pack8Wire(axes=("data",), n_workers=m)
    ring = collectives.Pack8Wire(axes=("data",), n_workers=m,
                                 ring_chunk_rows=32)
    assert ring.ring_chunks(n) == 3
    assert mono.gather_hbm_bytes(n) == m * 96 * common.LANES
    assert ring.gather_hbm_bytes(n) == 2 * 32 * common.LANES
    # the chunked ring re-ships the decode scale once per chunk
    assert (collectives.uplink_ledger("pack8", ring, n)
            == mono.wire_bytes(n) + 3 * mono.scalar_bytes())
    assert (collectives.uplink_ledger("pack8", mono, n)
            == mono.wire_bytes(n) + mono.scalar_bytes())
    # bucketed variant: the (n_slots,) scale vector re-ships per chunk too
    pay_m, sc_m = collectives.uplink_ledger_bucket("pack8", mono, n, 4)
    pay_r, sc_r = collectives.uplink_ledger_bucket("pack8", ring, n, 4,
                                                   ring_chunks=3)
    assert pay_r - pay_m == 2 * (m - 1) * 4 * 4 and sc_m == sc_r == 0.0


def test_golomb_ring_ledger_math():
    from repro.kernels.golomb.ref import ROW_BYTES, golomb_rows
    m = 16
    n = 1 << 20
    mono = collectives.GolombWire(axes=("data",), n_workers=m, p=0.05)
    ring = collectives.GolombWire(axes=("data",), n_workers=m, p=0.05,
                                  ring_chunk_rows=256)
    rows = golomb_rows(n, 0.05)
    # a per-leaf coded stream is one self-describing chunk regardless of size
    assert ring.ring_chunks(n) == 1
    assert mono.gather_hbm_bytes(n) == m * rows * ROW_BYTES
    assert ring.gather_hbm_bytes(n) == 2 * rows * ROW_BYTES
    assert mono.gather_hbm_bytes(n) == (m / 2) * ring.gather_hbm_bytes(n)
    assert mono.wire_bytes(n) == ring.wire_bytes(n)


def test_psum_wires_have_no_gather_hbm():
    for w in (collectives.VoteWire(axes=("data",), n_workers=16),
              collectives.HierVoteWire(axes=("pod", "data"), n_workers=16,
                                       inner_size=8, outer_size=2)):
        assert w.gather_hbm_bytes(1 << 20) == 0.0
        assert w.ring_chunks(1 << 20) == 1


def test_plan_gather_hbm_bytes():
    plan = _pack8_plan([1000, 513, 4096, 70000])
    mono = collectives.Pack8Wire(axes=("data",), n_workers=16)
    ring = collectives.Pack8Wire(axes=("data",), n_workers=16,
                                 ring_chunk_rows=32)
    got_m = bucketing.plan_gather_hbm_bytes("pack8", mono, plan)
    got_r = bucketing.plan_gather_hbm_bytes("pack8", ring, plan)
    assert got_m == max(mono.bucket_gather_hbm_bytes(b) for b in plan.buckets)
    assert got_r == max(ring.bucket_gather_hbm_bytes(b) for b in plan.buckets)
    assert got_r < got_m
    # the decoded-float path bypasses the wire: no gathered tensor, ever
    assert bucketing.plan_gather_hbm_bytes("decoded", mono, plan) == 0.0


# ---------------------------------------------------------------------------
# chunked decode == whole decode (gathered arrays, no mesh)
# ---------------------------------------------------------------------------

def test_pack2_chunked_decode_matches_whole():
    """The framing invariant the pack2 ring rides on: canonical rows decode
    independently, so decoding a gathered payload span-by-span (per worker,
    summed in any order — int32 adds commute) equals the whole-payload fused
    decode at every coordinate."""
    m, n = 4, 40000                      # 79 rows -> padded to 96 -> 3 chunks
    rng = np.random.RandomState(0)
    payloads = [pack2bit_op(jnp.asarray(rng.randint(-1, 2, n), jnp.int8))
                for _ in range(m)]
    gathered = jnp.stack(payloads)
    rows = gathered.shape[1]
    whole = np.asarray(collectives._packed_decode_sum(
        gathered, rows * common.LANES, (rows * common.LANES,), backend=None))
    parts = []
    for r0, nr in collectives._ring_chunk_spans(rows, 32):
        acc = np.zeros(nr * common.LANES, np.int32)
        for w in range(m):   # reversed worker order: ring arrival at rank 0
            chunk = gathered[m - 1 - w, r0:r0 + nr][None]
            acc += np.asarray(collectives._packed_decode_sum(
                chunk, nr * common.LANES, (nr * common.LANES,), backend=None))
        parts.append(acc)
    assert np.array_equal(np.concatenate(parts), whole)
    assert np.array_equal(
        whole[:n], sum(np.asarray(collectives._packed_decode_sum(
            p[None], n, (n,), backend=None), np.int32) for p in payloads))


# ---------------------------------------------------------------------------
# M=1 degenerate ring: bitwise the monolithic wire, under shard_map
# ---------------------------------------------------------------------------

def _m1_exchange(wire, payload, n, scale=None):
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)

    def f(p):
        return wire.exchange(p, n, (n,), scale=scale)

    g = compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                         axis_names={"data"}, check_vma=False)
    with compat.set_mesh(mesh):
        return np.asarray(g(payload))


@pytest.mark.parametrize("n", [40000, 7 * 1237])   # multi-chunk + odd shapes
def test_pack2_ring_exchange_m1_bitwise(n):
    rng = np.random.RandomState(1)
    t = jnp.asarray(rng.randint(-1, 2, n), jnp.int8)
    payload = pack2bit_op(t)
    kw = dict(axes=("data",), n_workers=1)
    mono = _m1_exchange(collectives.PackedVoteWire(**kw), payload, n)
    ring = _m1_exchange(collectives.PackedVoteWire(ring_chunk_rows=32, **kw),
                        payload, n)
    assert np.array_equal(ring, mono)
    assert np.array_equal(ring, np.asarray(t, np.int32))


@pytest.mark.parametrize("n", [40000, 7 * 1237])
def test_pack8_ring_exchange_m1_bitwise(n):
    """At M=1 there are no cross-worker adds to re-associate, so even the f32
    pack8 ring is bitwise the monolithic decode (each coordinate lives in
    exactly one chunk; the per-chunk kernel rounds it identically)."""
    rng = np.random.RandomState(2)
    g = jnp.asarray(rng.randn(n), jnp.float32)
    from repro.core.compressors import qsgd8_scale
    sc = qsgd8_scale(g)
    payload = qsgd8_pack8_op(g, sc, 3)
    kw = dict(axes=("data",), n_workers=1, backend=OTHER)
    mono = _m1_exchange(collectives.Pack8Wire(**kw), payload, n,
                        scale=jnp.float32(sc))
    ring = _m1_exchange(collectives.Pack8Wire(ring_chunk_rows=32, **kw),
                        payload, n, scale=jnp.float32(sc))
    assert np.array_equal(ring, mono)


def test_golomb_ring_exchange_m1_bitwise():
    n = 40000
    rng = np.random.RandomState(3)
    g = jnp.asarray(rng.randn(n), jnp.float32)
    comp = CompressionConfig(
        compressor="sparsign_golomb",
        budget=BudgetConfig(kind="target_sparsity", value=0.05),
        server="majority_vote")
    kw = dict(axes=("data",), n_workers=1, p=0.05, backend=OTHER)
    mono_w = collectives.GolombWire(**kw)
    msg = engine.compress_leaf(g, comp, 9, backend=OTHER, wire=mono_w)
    mono = _m1_exchange(mono_w, msg.values, n)
    ring = _m1_exchange(collectives.GolombWire(ring_chunk_rows=256, **kw),
                        msg.values, n)
    assert np.array_equal(ring, mono)


# ---------------------------------------------------------------------------
# M=1 degenerate ring, end-to-end: the ring step == the psum oracle stream
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.model import Model
    cfg = ModelConfig(name="ring-tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      pattern=(LayerSpec(mixer="attn"),), dtype="float32",
                      attn_chunk=8, q_chunk=8, loss_chunk=8, remat=False)
    return Model(cfg)


def _one_step(model, params, batch, mesh, comp, **cfg_kw):
    from repro.train.state import LrSchedule, init_state
    from repro.train.step_simple import TrainStepConfig, build_train_step
    scfg = TrainStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                           worker_axes=("data",), donate=False, **cfg_kw)
    step = build_train_step(model, scfg, mesh)
    state = init_state(params, server=comp.server, seed=7)
    with compat.set_mesh(mesh):
        out, metrics = step(state, batch)
    return jax.tree_util.tree_map(np.asarray, out.params), metrics


@pytest.mark.parametrize("bucketed", [False, True])
def test_ring_step_m1_matches_psum_oracle(bucketed):
    from repro.launch.mesh import make_host_mesh
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(4)
    batch = {
        "inputs": jnp.asarray(rng.randint(0, 64, (2, 8)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, 64, (2, 8)), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(8), (2, 8)).astype(jnp.int32),
    }
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    ref, _ = _one_step(model, params, batch, mesh, comp, vote_impl="psum")
    for backend in ("jnp", OTHER):
        got, m = _one_step(model, params, batch, mesh, comp,
                           vote_impl="allgather_packed", backend=backend,
                           bucketed=bucketed, ring_chunk_rows=32)
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(ref)[0],
                jax.tree_util.tree_flatten_with_path(got)[0]):
            assert np.array_equal(a, b), (backend, jax.tree_util.keystr(ka))
        # the residency metric is emitted from the ring wire's own model
        wire = collectives.PackedVoteWire(axes=("data",), n_workers=1,
                                          ring_chunk_rows=32)
        if bucketed:
            plan = bucketing.build_bucket_plan(
                [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in
                 jax.tree_util.tree_leaves(model.param_shapes())], "pack2")
            want = bucketing.plan_gather_hbm_bytes("votes", wire, plan)
        else:
            want = max(wire.gather_hbm_bytes(s.size) for s in
                       jax.tree_util.tree_leaves(model.param_shapes()))
        assert float(m["gather_hbm_bytes"]) == want


def test_ring_step_config_validation_is_loud():
    from repro.launch.mesh import make_host_mesh
    from repro.train.state import LrSchedule
    from repro.train.step_simple import TrainStepConfig, build_train_step
    from repro.train.step_streamed import StreamedStepConfig
    model = _tiny_model()
    mesh = make_host_mesh(1, 1)
    comp = CompressionConfig(compressor="sparsign",
                             budget=BudgetConfig(kind="fixed", value=2.0),
                             server="majority_vote")
    with pytest.raises(ValueError, match="allgather_packed"):
        build_train_step(model, TrainStepConfig(
            compression=comp, lr=LrSchedule(base=0.05), worker_axes=("data",),
            vote_impl="psum", ring_chunk_rows=32), mesh)
    # the streamed config carries the same knob
    cfg = StreamedStepConfig(compression=comp, lr=LrSchedule(base=0.05),
                             vote_impl="allgather_packed", ring_chunk_rows=64)
    assert cfg.ring_chunk_rows == 64
